"""Batch-analytics job benchmark (PR 9 tentpole).

Three measurements over one SCALE-profile snapshot (the GO-shaped
synthetic workload from ``configs/go_kge.py``, random embeddings — the
axis under test is the job subsystem, not training):

  * join parity — a bulk kNN join submitted through the job API must be
    **byte-identical** (JSON bytes of every row) to a serial per-query
    oracle driven straight at the index. The join batches query slabs
    through the block-tiled streaming kernel; identical bytes prove the
    batched path introduces no numeric or ordering drift. Gated at both
    sizes.
  * p99 under fire — interactive closest-concepts p99 from threaded
    clients while a full-table bulk join is RUNNING, vs the same probe
    quiescent. The executor yields between work slabs, so the ratio
    must stay within ``P99_RATIO`` at full size (recorded, not gated,
    at --fast: CI-sized kernels make single-request p99 noise-bound).
  * overflow fast-reject — with the job queue full, HTTP submissions
    must answer 429 + Retry-After in under ``REJECT_MEDIAN_MS`` median:
    admission control does no analytics work for a job it will not run.

Emits ``benchmarks/results/BENCH_jobs.json``.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_jobs [--fast]
"""
from __future__ import annotations

import argparse
import http.client
import json
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
P99_RATIO = 2.0        # interactive p99 under a running bulk job
REJECT_MEDIAN_MS = 5.0  # HTTP 429 fast-reject median
K = 10


def _p(lat_s, q):
    return float(np.percentile(np.asarray(lat_s) * 1e3, q))


def _probe(gw, ids, requests, clients, rng):
    """Interactive latency probe: ``clients`` threads alternating sim /
    closest-concepts on (mostly unique) random queries; per-request
    wall-clock seconds, pooled."""
    picks = rng.integers(0, len(ids), (requests, 2))
    chunks = [list(range(c, requests, clients)) for c in range(clients)]
    lat, lock, errs = [], threading.Lock(), []

    def client(mine):
        out = []
        try:
            for i in mine:
                a, b = ids[int(picks[i][0])], ids[int(picks[i][1])]
                t0 = time.perf_counter()
                if i % 2:
                    gw.similarity("go-scale", "transe", a, b)
                else:
                    gw.closest_concepts("go-scale", "transe", a, k=K)
                out.append(time.perf_counter() - t0)
        except Exception as e:                     # pragma: no cover
            errs.append(e)
        with lock:
            lat.extend(out)

    threads = [threading.Thread(target=client, args=(c,)) for c in chunks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    return lat


def run(fast: bool = False) -> dict:
    from repro.api import Gateway
    from repro.configs.go_kge import SCALE
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine
    from repro.ontology.synthetic import generate

    n = 2_000 if fast else 20_000
    d = 64 if fast else 128
    join_q = 256 if fast else 1024
    requests = 160 if fast else 400
    clients = 4
    rng = np.random.default_rng(0)

    out = {"n_classes": n, "dim": d, "k": K, "join_queries": join_q}

    with tempfile.TemporaryDirectory() as td:
        kg = generate(SCALE.spec, seed=0, n_terms=n)
        ids = list(kg.entities)
        registry = EmbeddingRegistry(td)
        emb = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go-scale", "2025-01", "transe", ids,
                         [kg.terms[e].label for e in ids], emb,
                         ontology_checksum="bench",
                         hyperparameters={"dim": d})
        engine = ServingEngine(registry)
        gw = Gateway(engine, result_cache_entries=0, result_cache_bytes=0)

        # ---- 1. byte-identity: job join vs serial per-query oracle ---- #
        classes = [ids[int(i)] for i in rng.integers(0, n, join_q)]
        sub = gw.submit_job("knn-join", "go-scale", model="transe",
                            classes=classes, k=K)
        st = gw.job_wait(sub.job_id, timeout=600)
        assert st.state == "DONE", st.error
        rows, offset = [], 0
        while offset is not None:
            page = gw.job_result(sub.job_id, offset=offset, limit=1000)
            rows.extend(page.rows)
            offset = page.next_offset
        idx = engine._index("go-scale", "transe")
        t0 = time.perf_counter()
        oracle = [[c, [[cc.identifier, cc.score]
                       for cc in idx.top_k([c], k=K)[0]]] for c in classes]
        t_oracle = time.perf_counter() - t0
        identical = json.dumps(rows) == json.dumps(oracle)
        out["join"] = {
            "byte_identical_to_serial_oracle": bool(identical),
            "job_compute_s": st.summary["compute_s"],
            "serial_oracle_s": round(t_oracle, 4),
            "slabs": st.summary["slabs"],
        }
        print(f"  jobs[join] {join_q} queries over {n} rows: "
              f"byte-identical={identical} "
              f"(job {st.summary['compute_s']:.2f}s vs serial "
              f"{t_oracle:.2f}s, {st.summary['slabs']} slabs)")

        # ---- 2. interactive p99 while a bulk join runs ---------------- #
        _probe(gw, ids, 32, clients, rng)          # warm shapes + caches
        quiescent = _probe(gw, ids, requests, clients, rng)
        # a join big enough to outlast the probe (duplicates are fine:
        # one output row per input class)
        fire_classes = ids * (8 if fast else 2)
        sub = gw.submit_job("knn-join", "go-scale", model="transe",
                            classes=fire_classes, k=K)
        deadline = time.monotonic() + 60
        while gw.job_status(sub.job_id).state == "PENDING":
            assert time.monotonic() < deadline, "join never started"
            time.sleep(0.001)
        under_fire = _probe(gw, ids, requests, clients, rng)
        still_running = gw.job_status(sub.job_id).state == "RUNNING"
        gw.job_wait(sub.job_id, timeout=600)
        q99, f99 = _p(quiescent, 99), _p(under_fire, 99)
        ratio = f99 / q99 if q99 > 0 else float("inf")
        out["p99_under_fire"] = {
            "quiescent_p50_ms": round(_p(quiescent, 50), 3),
            "quiescent_p99_ms": round(q99, 3),
            "under_fire_p50_ms": round(_p(under_fire, 50), 3),
            "under_fire_p99_ms": round(f99, 3),
            "ratio": round(ratio, 2),
            "job_running_throughout": bool(still_running),
            "gated": not fast,
        }
        print(f"  jobs[p99] interactive p99 {q99:.2f}ms quiescent -> "
              f"{f99:.2f}ms under bulk join ({ratio:.2f}x, "
              f"job running throughout: {still_running})")

        # ---- 3. HTTP overflow fast-reject ----------------------------- #
        # a separate gateway whose executor is pinned down by a slow job
        # and whose queue holds exactly one more
        from repro.api import serve_http
        slow = Gateway(ServingEngine(registry), max_jobs_queued=1,
                       jobs_slab=64, jobs_yield_s=0.05)
        server = serve_http(slow, port=0)
        try:
            slow.submit_job("knn-join", "go-scale", model="transe",
                            classes=ids, k=K)      # occupies the executor
            deadline = time.monotonic() + 60
            while slow.jobs.stats()["running"] == 0:
                assert time.monotonic() < deadline
                time.sleep(0.001)
            slow.submit_job("knn-join", "go-scale", model="transe",
                            classes=ids[:64], k=K)  # fills the queue
            body = json.dumps({"kind": "knn-join", "ontology": "go-scale",
                               "model": "transe", "classes": ids[:8],
                               "k": K})
            conn = http.client.HTTPConnection("127.0.0.1", server.port,
                                              timeout=30)
            rejects = []
            retry_after = None
            for _ in range(60):
                t0 = time.perf_counter()
                conn.request("POST", "/jobs/submit", body=body)
                resp = conn.getresponse()
                payload = resp.read()
                dt = time.perf_counter() - t0
                assert resp.status == 429, (resp.status, payload)
                retry_after = resp.getheader("Retry-After")
                rejects.append(dt)
            conn.close()
            med = _p(rejects, 50)
            out["overflow"] = {
                "rejects": len(rejects),
                "status": 429,
                "retry_after_header": retry_after,
                "reject_p50_ms": round(med, 3),
                "reject_p99_ms": round(_p(rejects, 99), 3),
            }
            print(f"  jobs[429] {len(rejects)} fast-rejects: median "
                  f"{med:.3f}ms (Retry-After: {retry_after})")
        finally:
            server.close()
            slow.close()
        gw.close()

        ok = (identical
              and retry_after is not None
              and med < REJECT_MEDIAN_MS
              and (fast or ratio <= P99_RATIO))
        out["p99_ratio_floor"] = P99_RATIO
        out["reject_median_floor_ms"] = REJECT_MEDIAN_MS
        out["pass"] = bool(ok)
        return out


def section_key(fast: bool) -> str:
    return "jobs_fast" if fast else "jobs"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_jobs.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized workload (2k classes; p99 ratio "
                         "recorded, not gated)")
    args = ap.parse_args()
    rep = run(fast=args.fast)
    out = write_results({section_key(args.fast): rep})
    print(f"[bench_jobs] wrote {out}")
    status = "PASS" if rep["pass"] else "FAIL"
    pf = rep["p99_under_fire"]
    print(f"[bench_jobs] {status}: join byte-identical="
          f"{rep['join']['byte_identical_to_serial_oracle']}, "
          f"interactive p99 under fire = {pf['ratio']:.2f}x quiescent "
          f"({'gated' if pf['gated'] else 'recorded'}, "
          f"floor {P99_RATIO}x), 429 median "
          f"{rep['overflow']['reject_p50_ms']:.3f}ms "
          f"(floor {REJECT_MEDIAN_MS}ms)")
    if not rep["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
