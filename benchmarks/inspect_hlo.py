import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb microscope: compile one (arch x shape x mesh) and report the
top collectives / dots by loop-multiplied traffic, with the jax op_name
that produced each (metadata=... in the HLO) — this is how §Perf
hypotheses are formed.

    PYTHONPATH=src python -m benchmarks.inspect_hlo --arch grok-1-314b \
        --shape train_4k [--top 15] [--override remat=none]
"""
import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))
sys.path.insert(0, str(REPO / "src"))

from benchmarks.roofline import (_COMP_HDR, _DEF_RE, _exec_counts,
                                 _parse_computations, parse_collectives,
                                 _SHAPE_RE, _CDIMS_RE, _LHS_RE)

_META_RE = re.compile(r'op_name="([^"]*)"')


def top_ops(text: str, top: int = 15):
    comps, entry = _parse_computations(text)
    counts = _exec_counts(comps, entry)
    colls, dots = [], []
    for name, comp in comps.items():
        mult = counts.get(name, 0.0)
        if mult == 0:
            continue
        for line in comp["lines"]:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            op = dm.group(3)
            meta = _META_RE.search(line)
            src = meta.group(1) if meta else "?"
            if op in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute") or \
                    op.endswith("-start"):
                ops = parse_collectives(line)
                if ops:
                    o = ops[0]
                    colls.append((o.traffic * mult, o.kind, mult,
                                  dm.group(2)[:40], src))
            elif op == "dot":
                sm = _SHAPE_RE.match(dm.group(2))
                if not sm:
                    continue
                out_numel = 1
                for d in sm.group(2).split(","):
                    if d:
                        out_numel *= int(d)
                lm = _LHS_RE.search(line[line.index("dot("):])
                cm = _CDIMS_RE.search(line)
                k = 1
                if lm and cm and lm.group(1) in comp["shapes"]:
                    lhs = comp["shapes"][lm.group(1)][1]
                    for ci in (int(x) for x in cm.group(1).split(",") if x):
                        if ci < len(lhs):
                            k *= lhs[ci]
                dots.append((2.0 * out_numel * k * mult, mult,
                             dm.group(2)[:40], src))
    return (sorted(colls, reverse=True)[:top],
            sorted(dots, reverse=True)[:top])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--override", default=None,
                    help="k=v[,k=v] ArchConfig overrides")
    args = ap.parse_args()

    override = {}
    if args.override:
        for kv in args.override.split(","):
            k, v = kv.split("=")
            override[k] = (int(v) if v.isdigit() else
                           v == "True" if v in ("True", "False") else v)

    from repro.launch import dryrun
    rec = dryrun.dryrun_one(args.arch, args.shape, args.mesh == "multi",
                            save=False, force=True,
                            override=override or None)
    print(f"== {rec['tag']} roofline: {rec['roofline']} ==")

    # recompile to get the text (dryrun_one doesn't keep it)
    # cheaper: reuse its internals — just re-lower here
    import jax
    text = dryrun._LAST_HLO
    colls, dots = top_ops(text, args.top)
    print(f"\n-- top {args.top} collectives (traffic x loop multiplier) --")
    for traffic, kind, mult, shape, src in colls:
        print(f"  {traffic/1e9:10.2f} GB  {kind:18s} x{mult:<6.0f} {shape:40s} {src[:80]}")
    print(f"\n-- top {args.top} dots --")
    for flops, mult, shape, src in dots:
        print(f"  {flops/1e12:10.2f} TF  x{mult:<6.0f} {shape:40s} {src[:80]}")


if __name__ == "__main__":
    main()
