"""KGE quality ablation — filtered link-prediction metrics for all six
paper models on a held-out split of the synthetic GO.

The paper doesn't publish link-prediction numbers (it serves embeddings);
this table validates that every model LEARNS under our JAX training loop
(vs a random-embedding floor), i.e. the served embeddings carry signal.

    PYTHONPATH=src python -m benchmarks.eval_kge [--n-terms 800] [--steps 400]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.kge import make_model
from repro.kge.eval import rank_based_eval
from repro.kge.train import KGETrainer, TrainConfig
from repro.ontology.synthetic import GO_SPEC, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-terms", type=int, default=800)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--eval-triples", type=int, default=200)
    args = ap.parse_args()

    import jax
    kg = generate(GO_SPEC, seed=0, n_terms=args.n_terms)
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(kg.triples))
    test = kg.triples[perm[:args.eval_triples]]
    train = kg.triples[perm[args.eval_triples:]]
    print(f"[eval] GO-like: {kg.num_entities} entities, "
          f"{len(train)} train / {len(test)} test triples, dim={args.dim}")

    cfg = TrainConfig(batch_size=256, num_negs=32, lr=3e-2)
    rows = {}
    for name in ("transe", "transr", "distmult", "hole", "boxe"):
        model = make_model(name, kg.num_entities, kg.num_relations,
                           dim=args.dim)
        # random floor
        p0 = model.init(jax.random.key(0))
        floor = rank_based_eval(model, p0, test, kg.triples)
        t0 = time.perf_counter()
        trainer = KGETrainer(model, cfg)
        params, _, _ = trainer.fit(train, steps=args.steps)
        dt = time.perf_counter() - t0
        res = rank_based_eval(model, params, test, kg.triples)
        rows[name] = {"mrr": res["mrr"], "hits@10": res["hits@10"],
                      "mrr_random": floor["mrr"], "train_s": round(dt, 1)}
        print(f"  {name:10s} MRR {res['mrr']:.3f} (random {floor['mrr']:.3f}) "
              f"hits@10 {res['hits@10']:.3f}  [{dt:.0f}s]")

    out = REPO / "benchmarks" / "results" / "kge_eval.json"
    out.write_text(json.dumps(rows, indent=2))
    print(f"[eval] wrote {out}")


if __name__ == "__main__":
    main()
