"""Result-cache + admission-control benchmark (PR 7 tentpole).

Four measurements over one published snapshot:

  * throughput — a Zipfian (s=1.1) mixed workload (60% closest-concepts,
    25% sim, 15% get-vector) from 8 threaded clients through
    ``gw.handle``, cache-on vs cache-off over the *same* engine. Real
    query logs are heavy-tailed; under Zipf the hot head collapses onto
    the version-keyed result cache and q/s must clear the floor.
  * byte identity — cache-on responses are byte-for-byte the cache-off
    gateway's across every cached route, including across a
    publish→invalidate edge (the stale-hit impossibility, measured).
  * burst — admission control under a 4x client spike: p99 of *accepted*
    requests stays within ``BURST_P99_RATIO`` of the quiescent p99
    (bounded intake means bounded queueing), and fast-rejects answer in
    under ``REJECT_MEDIAN_MS`` median — the scheduler never does work
    for a request it will not serve.
  * http-429 — one saturated request over a real socket: status 429
    with a Retry-After header, not a hang.

Emits ``benchmarks/results/BENCH_cache.json``.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_cache [--fast]

Acceptance floors (PR 7): cache-on >= 5x cache-off q/s at full size
(20k classes — each cache hit skips the scheduler round-trip and the
top-k kernel entirely). At --fast CI size the floor is 2x: with a
2k-class table the kernel is so cheap that dict-lookup savings shrink
toward the fixed codec cost, so CI only catches "the cache stopped
serving hits" regressions; full-size numbers are the trajectory.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import threading
import time
import urllib.error
import urllib.request
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
FLOOR = 5.0            # cache-on q/s vs cache-off, Zipf s=1.1, full size
CI_FLOOR = 2.0         # --fast: tiny kernels shrink the per-hit savings
ZIPF_S = 1.1
BURST_P99_RATIO = 3.0  # accepted p99 under 4x burst vs quiescent p99
REJECT_MEDIAN_MS = 5.0


def _zipf_ranks(rng, n, size, s=ZIPF_S):
    """``size`` ranks in [0, n) with P(rank i) ∝ (i+1)^-s."""
    p = 1.0 / np.arange(1, n + 1) ** s
    p /= p.sum()
    return rng.choice(n, size=size, p=p)


def _mixed_workload(rng, ids, total):
    """The request sequence both gateways replay: Zipf-ranked queries
    spread over a permuted id table so the hot head is not index-local."""
    n = len(ids)
    perm = rng.permutation(n)
    ranks = _zipf_ranks(rng, n, 2 * total)
    route_draw = rng.random(total)
    reqs = []
    for i in range(total):
        q = ids[int(perm[ranks[2 * i]])]
        if route_draw[i] < 0.60:
            reqs.append(("/closest-concepts/go/transe",
                         {"query": q, "k": 10}))
        elif route_draw[i] < 0.85:
            b = ids[int(perm[ranks[2 * i + 1]])]
            reqs.append(("/sim/go/transe", {"a": q, "b": b}))
        else:
            reqs.append(("/get-vector/go/transe", {"query": q}))
    return reqs


def _fanout(gw, reqs, clients):
    """Replay ``reqs`` across ``clients`` threads; (wall_s, latencies_s,
    wires). Any error wire fails the measurement loudly."""
    shards = [reqs[c::clients] for c in range(clients)]
    lat, wires, failures, lock = [], {}, [], threading.Lock()
    barrier = threading.Barrier(clients + 1)

    def client(cix):
        mine_lat, mine_wires = [], []
        barrier.wait()
        try:
            for path, payload in shards[cix]:
                t1 = time.perf_counter()
                wire = gw.handle(path, dict(payload))
                mine_lat.append(time.perf_counter() - t1)
                if wire.get("type") == "error":
                    raise RuntimeError(f"{path} -> {wire['code']}")
                mine_wires.append(wire)
        except Exception as e:
            with lock:
                failures.append(f"client {cix}: {e!r}")
            return
        with lock:
            lat.extend(mine_lat)
            wires[cix] = mine_wires

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert not failures, failures
    assert len(lat) == len(reqs), f"only {len(lat)}/{len(reqs)} completed"
    return wall, lat, wires


def _p(lat_s, q):
    return round(float(np.percentile(np.asarray(lat_s) * 1e3, q)), 3)


def run(fast: bool = False, clients: int = 8) -> dict:
    from repro.api import Gateway, serve_http
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import (BatchScheduler, ServingEngine,
                                    SimRequest, TopKRequest)

    n = 2_000 if fast else 20_000          # paper: GO > 40k classes
    d, total = 200, (1_024 if fast else 4_096)
    total = (total // clients) * clients
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        ids = [f"GO:{i:07d}" for i in range(n)]
        labels = [f"synthetic term {i}" for i in range(n)]
        emb = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go", "2025-01", "transe", ids, labels, emb,
                         ontology_checksum="bench", hyperparameters={"dim": d})
        engine = ServingEngine(registry)

        # jit-warm every power-of-two bucket shape (top-k and sim) the
        # burst can hit — a mid-burst compile would otherwise dominate
        # the accepted p99 and measure XLA, not admission control
        warm = BatchScheduler(engine, max_batch=64)
        b = 1
        while b <= 64:
            for i in range(b):
                warm.submit(TopKRequest("go", "transe", ids[i % n], k=10))
            warm.flush()
            for i in range(b):
                warm.submit(SimRequest("go", "transe", ids[i % n],
                                       ids[(i + 1) % n]))
            warm.flush()
            b <<= 1

        reqs = _mixed_workload(rng, ids, total)

        out = {"n_classes": n, "dim": d, "clients": clients,
               "total_requests": total, "zipf_s": ZIPF_S}

        # ---- throughput: cache-off vs cache-on, same workload --------- #
        gw_off = Gateway(engine, flush_after_ms=2.0, result_cache_entries=0)
        _fanout(gw_off, reqs, clients)                       # jit warmup
        wall_off, lat_off, _ = _fanout(gw_off, reqs, clients)
        qps_off = round(total / wall_off, 1)
        print(f"  cache[off] {clients} clients x {total // clients}: "
              f"{qps_off:>9,.0f} q/s  p50={_p(lat_off, 50):.3f}ms "
              f"p99={_p(lat_off, 99):.3f}ms")

        gw_on = Gateway(engine, flush_after_ms=2.0)
        _fanout(gw_on, reqs, clients)          # populate: pass 1 misses
        wall_on, lat_on, _ = _fanout(gw_on, reqs, clients)   # steady state
        qps_on = round(total / wall_on, 1)
        speedup = round(qps_on / qps_off, 2)
        rc = gw_on.result_cache.stats()
        print(f"  cache[on ] {clients} clients x {total // clients}: "
              f"{qps_on:>9,.0f} q/s ({speedup:.2f}x)  "
              f"p50={_p(lat_on, 50):.3f}ms p99={_p(lat_on, 99):.3f}ms  "
              f"hit-rate={rc['hits'] / max(1, rc['hits'] + rc['misses']):.2f}")
        out["throughput"] = {
            "qps_off": qps_off, "qps_on": qps_on, "speedup": speedup,
            "p99_off_ms": _p(lat_off, 99), "p99_on_ms": _p(lat_on, 99),
            "cache": rc}

        # ---- byte identity across routes + the invalidate edge -------- #
        sample = reqs[:: max(1, total // 64)]
        mismatches = 0
        for path, payload in sample:
            if json.dumps(gw_on.handle(path, dict(payload))) != \
               json.dumps(gw_off.handle(path, dict(payload))):
                mismatches += 1
        # publish a new version and invalidate: unpinned traffic must
        # flip to it — byte-identically to the cache-off gateway
        emb2 = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go", "2025-02", "transe", ids, labels, emb2,
                         ontology_checksum="bench2",
                         hyperparameters={"dim": d})
        engine.invalidate("go")
        stale = 0
        for path, payload in sample[:16]:
            a = gw_on.handle(path, dict(payload))
            b = gw_off.handle(path, dict(payload))
            if json.dumps(a) != json.dumps(b):
                mismatches += 1
            if a.get("version") != "2025-02":
                stale += 1
        out["byte_identity"] = {"checked": len(sample) + 16,
                                "mismatches": mismatches,
                                "stale_after_invalidate": stale}
        print(f"  identity   {out['byte_identity']['checked']} sampled wires: "
              f"{mismatches} mismatches, {stale} stale after invalidate")
        gw_on.close()
        gw_off.close()

        # ---- burst: bounded intake under a 4x client spike ------------ #
        # quiescent and burst gateways share config (flush cadence,
        # max_pending, no result cache — admission control is orthogonal
        # to caching); only the client count changes
        def burst_gw():
            return Gateway(engine, flush_after_ms=10.0, max_pending=16,
                           result_cache_entries=0)

        q_reqs = _mixed_workload(rng, ids, total // 2)
        gw_q = Gateway(engine, flush_after_ms=10.0, result_cache_entries=0)
        _fanout(gw_q, q_reqs[: total // 8], max(1, clients // 2))  # warmup
        _, lat_q, _ = _fanout(gw_q, q_reqs, max(1, clients // 2))
        gw_q.close()
        quiescent_p99 = _p(lat_q, 99)

        gw_b = burst_gw()
        b_clients = clients * 4
        b_reqs = _mixed_workload(rng, ids, total)
        shards = [b_reqs[c::b_clients] for c in range(b_clients)]
        acc_lat, rej_lat, lock = [], [], threading.Lock()
        barrier = threading.Barrier(b_clients)

        def blast(cix):
            mine_acc, mine_rej = [], []
            barrier.wait()
            for path, payload in shards[cix]:
                t1 = time.perf_counter()
                wire = gw_b.handle(path, dict(payload))
                dt = time.perf_counter() - t1
                if wire.get("type") == "error":
                    assert wire["code"] == "OVERLOADED", wire
                    mine_rej.append(dt)
                else:
                    mine_acc.append(dt)
            with lock:
                acc_lat.extend(mine_acc)
                rej_lat.extend(mine_rej)

        threads = [threading.Thread(target=blast, args=(i,))
                   for i in range(b_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        gw_b.close()
        burst_p99 = _p(acc_lat, 99) if acc_lat else float("inf")
        rej_median = _p(rej_lat, 50) if rej_lat else None
        ratio = round(burst_p99 / max(quiescent_p99, 1e-9), 2)
        out["burst"] = {
            "quiescent_clients": max(1, clients // 2),
            "burst_clients": b_clients, "max_pending": 16,
            "quiescent_p99_ms": quiescent_p99,
            "accepted_p99_ms": burst_p99, "p99_ratio": ratio,
            "accepted": len(acc_lat), "rejected": len(rej_lat),
            "reject_median_ms": rej_median}
        print(f"  burst      {b_clients} clients, max_pending=16: "
              f"accepted p99={burst_p99:.3f}ms ({ratio:.2f}x quiescent "
              f"{quiescent_p99:.3f}ms), {len(rej_lat)} rejects "
              f"median={rej_median if rej_median is not None else 'n/a'}ms")

        # ---- http-429 spot check: saturated socket answers, fast ------ #
        gw_h = Gateway(engine, max_pending=1, flush_after_ms=60_000.0,
                       result_cache_entries=0)
        server = serve_http(gw_h, port=0)
        try:
            gw_h.scheduler.submit(                  # occupies the one slot
                TopKRequest("go", "transe", ids[0], k=10))
            t1 = time.perf_counter()
            try:
                urllib.request.urlopen(
                    server.url +
                    f"/closest-concepts/go/transe?query={ids[1]}&k=10",
                    timeout=30)
                http_429 = {"status": 200, "retry_after": None}
            except urllib.error.HTTPError as e:
                http_429 = {"status": e.code,
                            "retry_after": e.headers.get("Retry-After"),
                            "reject_ms": round(
                                (time.perf_counter() - t1) * 1e3, 3)}
                e.read()
        finally:
            server.close()
            gw_h.close()
        out["http_429"] = http_429
        print(f"  http-429   status={http_429['status']} "
              f"Retry-After={http_429.get('retry_after')}")

        floor = CI_FLOOR if fast else FLOOR
        out["floor"] = floor
        out["pass"] = bool(
            speedup >= floor
            and mismatches == 0 and stale == 0
            and ratio <= BURST_P99_RATIO
            and len(rej_lat) > 0
            and rej_median is not None and rej_median < REJECT_MEDIAN_MS
            and http_429["status"] == 429
            and http_429.get("retry_after") is not None)
        return out


def floor_speedup(report: dict) -> float:
    return report.get("throughput", {}).get("speedup", 0.0)


def section_key(fast: bool) -> str:
    """Fast (CI-sized) runs record under their own key so they never
    overwrite a full-sized trajectory with smaller-n numbers."""
    return "cache_fast" if fast else "cache"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_cache.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized table (2k classes instead of 20k)")
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()

    rep = run(fast=args.fast, clients=args.clients)
    out = write_results({section_key(args.fast): rep})
    print(f"[bench_cache] wrote {out}")

    status = "PASS" if rep["pass"] else "FAIL"
    print(f"[bench_cache] {status}: cache-on = "
          f"{floor_speedup(rep):.2f}x cache-off q/s under Zipf "
          f"s={ZIPF_S} (floor {rep['floor']}x); burst accepted p99 = "
          f"{rep['burst']['p99_ratio']:.2f}x quiescent "
          f"(<= {BURST_P99_RATIO}x); {rep['burst']['rejected']} rejects "
          f"median {rep['burst']['reject_median_ms']}ms "
          f"(< {REJECT_MEDIAN_MS}ms); http {rep['http_429']['status']}")
    if not rep["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
