"""HTTP layer benchmark: the stdlib service front end vs the in-process
gateway under 16 concurrent clients, plus the conditional-GET fast path.

Three measurements over the same top-k workload:

  * gateway-inproc  — 16 threads call ``gw.closest_concepts`` directly
    (PR 4's batched mode: tickets + the background flush loop). This is
    the ceiling: no sockets, no JSON re-parse.
  * http            — the same 16 clients, each holding ONE persistent
    keep-alive ``http.client.HTTPConnection`` to a
    ``ThreadingHTTPServer`` over the *same* gateway, so the scheduler
    coalesces across sockets exactly as it does across threads. The
    clients run in a SEPARATE process: real clients do not share the
    server's GIL, and billing the server for client-side response
    parsing in the same interpreter would understate it ~2x.
  * etag-304        — single client re-fetching a pinned download page
    with ``If-None-Match``: the 304 path (no gateway, no index) vs the
    full 200 page fetch, q/s each.

Emits ``benchmarks/results/BENCH_http.json``.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_http [--fast]

Acceptance floor (PR 5): HTTP >= 0.5x the in-process gateway q/s at 16
clients at full size — the transport tax (socket + HTTP parse + JSON
codec) must stay under half the throughput, which it only does if
keep-alive and cross-socket coalescing actually work. At --fast CI size
the floor is 0.2x: with a 2k-class table the kernel work per request is
so small that the constant per-request transport cost dominates both
sides of the ratio (and the 2-core CI box runs client and server
processes on the same silicon), so the CI floor only catches
"keep-alive or coalescing stopped working" regressions; measured
full-size numbers are the recorded trajectory.
"""
from __future__ import annotations

import argparse
import http.client
import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
FLOOR = 0.5          # http q/s vs in-process gateway q/s, 16 clients
CI_FLOOR = 0.2       # --fast: transport tax dominates at tiny kernel size

# ---- multi-process (--workers) floors -------------------------------- #
# The 1.5x MP-vs-SP floor presumes the workers can actually run in
# parallel: it applies only when the box has at least workers+1 cores
# (N servers + the client fleet process). On smaller machines — the
# 1-core container this repo often runs in — N processes time-slice one
# core, MP physically cannot beat SP, and the measured ratio swings
# 0.5-0.9x run to run; the speedup is then recorded but not gated
# (parity, table sharing and publish-visibility still are).
MP_FLOOR = 1.5       # full size, enough cores
MP_CI_FLOOR = 1.05   # --fast, enough cores: tiny kernels, transport-bound
#: pool-wide PSS of the ``table.f32`` file mapping may exceed one file
#: by at most this factor. PSS bills a page shared by M workers 1/M to
#: each, so N workers mmap'ing one table sum to ~one table — copies
#: (anon memory, or COW'd private pages) would sum to ~N tables. This
#: is the zero-copy gate: per-mapping, so it is immune to the ~125MB of
#: private XLA/interpreter footprint that dominates whole-process PSS.
MP_TABLE_PSS_RATIO = 1.1

#: the out-of-process client fleet: argv = port clients per_client n k,
#: stdout = one JSON line {"wall": s, "lat": [s, ...]}
_CLIENT_DRIVER = r"""
import http.client, json, random, sys, threading, time
port, clients, per, n, k = (int(a) for a in sys.argv[1:6])
ids = [f"GO:{i:07d}" for i in range(n)]
lat, errors, lock = [], [], threading.Lock()
barrier = threading.Barrier(clients + 1)

def worker(cix):
    r = random.Random(100 + cix)
    mine = []
    try:
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        barrier.wait()
        for _ in range(per):
            q = ids[r.randrange(n)]
            t0 = time.perf_counter()
            conn.request("GET",
                         f"/closest-concepts/go/transe?query={q}&k={k}")
            resp = conn.getresponse()
            body = resp.read()
            mine.append(time.perf_counter() - t0)
            assert resp.status == 200, body[:200]
        conn.close()
    except Exception as e:
        # a dead client must fail the whole measurement, not quietly
        # inflate q/s by shortening the wall clock
        with lock:
            errors.append(f"client {cix}: {e!r}")
    with lock:
        lat.extend(mine)

threads = [threading.Thread(target=worker, args=(i,)) for i in range(clients)]
for t in threads:
    t.start()
barrier.wait()
t0 = time.perf_counter()
for t in threads:
    t.join()
if errors or len(lat) != clients * per:
    print("\n".join(errors) or f"only {len(lat)} requests completed",
          file=sys.stderr)
    sys.exit(1)
print(json.dumps({"wall": time.perf_counter() - t0, "lat": lat}))
"""


def _percentiles(lat_s):
    lat_ms = np.asarray(lat_s) * 1e3
    return (round(float(np.percentile(lat_ms, 50)), 3),
            round(float(np.percentile(lat_ms, 99)), 3))


def run(fast: bool = False, clients: int = 16, max_batch: int = 64,
        flush_after_ms: float = 2.0,
        total_requests: int | None = None) -> dict:
    from repro.api import Gateway, serve_http
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest

    n = 2_000 if fast else 20_000          # paper: GO > 40k classes
    d, k = 200, 10
    total = total_requests or (512 if fast else 2_048)
    per_client = total // clients
    total = per_client * clients
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        ids = [f"GO:{i:07d}" for i in range(n)]
        labels = [f"synthetic term {i}" for i in range(n)]
        emb = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go", "2025-01", "transe", ids, labels, emb,
                         ontology_checksum="bench", hyperparameters={"dim": d})
        engine = ServingEngine(registry)

        # jit-warm every power-of-two bucket shape either mode can hit
        warm = BatchScheduler(engine, max_batch=max_batch)
        b = 1
        while b <= max_batch:
            for _ in range(b):
                warm.submit(TopKRequest("go", "transe",
                                        ids[int(rng.integers(n))], k))
            warm.flush()
            b <<= 1

        gw = Gateway(engine, max_batch=max_batch,
                     flush_after_ms=flush_after_ms)
        out = {"n_classes": n, "dim": d, "k": k, "clients": clients,
               "max_batch": max_batch, "flush_after_ms": flush_after_ms,
               "total_requests": total, "modes": []}

        def fanout(worker):
            lat, failures, lock = [], [], threading.Lock()
            barrier = threading.Barrier(clients + 1)

            def client(cix):
                r = np.random.default_rng(100 + cix)
                barrier.wait()
                try:
                    mine = worker(cix, r)
                except Exception as e:
                    with lock:
                        failures.append(f"client {cix}: {e!r}")
                    return
                with lock:
                    lat.extend(mine)

            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(clients)]
            for t in threads:
                t.start()
            barrier.wait()
            t0 = time.perf_counter()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            # a dead client shortens the wall clock — failing loudly is
            # the only way the q/s ratio stays meaningful
            assert not failures, failures
            assert len(lat) == total, f"only {len(lat)}/{total} completed"
            return wall, lat

        # ---- mode 1: in-process batched gateway (the ceiling) --------- #
        def inproc_worker(cix, r):
            mine = []
            for _ in range(per_client):
                q = ids[int(r.integers(n))]
                t1 = time.perf_counter()
                gw.closest_concepts("go", "transe", q, k=k)
                mine.append(time.perf_counter() - t1)
            return mine

        # best-of-2 (run.py's _time does the same): one bad descheduling
        # on a small CI box otherwise dominates the ratio
        wall, lat = min((fanout(inproc_worker) for _ in range(2)),
                        key=lambda x: x[0])
        inproc_qps = round(total / wall, 1)
        p50, p99 = _percentiles(lat)
        out["modes"].append({"mode": "gateway-inproc", "clients": clients,
                             "qps": inproc_qps, "p50_ms": p50, "p99_ms": p99,
                             "wall_s": round(wall, 3)})
        print(f"  http[inproc ] {clients:2d} clients x {per_client} calls: "
              f"{inproc_qps:>9,.0f} q/s  p50={p50:.3f}ms p99={p99:.3f}ms")

        # ---- mode 2: the same clients over real sockets --------------- #
        server = serve_http(gw, port=0)
        port = server.port

        def http_fleet():
            out = subprocess.run(
                [sys.executable, "-c", _CLIENT_DRIVER, str(port),
                 str(clients), str(per_client), str(n), str(k)],
                capture_output=True, text=True, timeout=600)
            assert out.returncode == 0, out.stderr[-2000:]
            rep = json.loads(out.stdout)
            return rep["wall"], rep["lat"]

        wall, lat = min((http_fleet() for _ in range(2)),
                        key=lambda x: x[0])
        http_qps = round(total / wall, 1)
        p50, p99 = _percentiles(lat)
        row = {"mode": "http", "clients": clients, "qps": http_qps,
               "p50_ms": p50, "p99_ms": p99, "wall_s": round(wall, 3),
               "vs_inproc": round(http_qps / inproc_qps, 2)}
        out["modes"].append(row)
        print(f"  http[socket ] {clients:2d} clients x {per_client} calls: "
              f"{http_qps:>9,.0f} q/s ({row['vs_inproc']:.2f}x in-process)  "
              f"p50={p50:.3f}ms p99={p99:.3f}ms")

        # ---- mode 3: conditional GET fast path (informational) -------- #
        n_cond = min(total, 256)
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        try:
            path = "/download/go/transe?version=2025-01&offset=0&limit=100"
            conn.request("GET", path)
            resp = conn.getresponse()
            etag = resp.getheader("ETag")
            resp.read()

            t0 = time.perf_counter()
            for _ in range(n_cond):
                conn.request("GET", path)
                resp = conn.getresponse()
                resp.read()
                assert resp.status == 200
            full_qps = round(n_cond / (time.perf_counter() - t0), 1)

            t0 = time.perf_counter()
            for _ in range(n_cond):
                conn.request("GET", path, headers={"If-None-Match": etag})
                resp = conn.getresponse()
                resp.read()
                assert resp.status == 304
            cond_qps = round(n_cond / (time.perf_counter() - t0), 1)
        finally:
            conn.close()
        out["modes"].append({"mode": "etag-304", "clients": 1,
                             "full_page_qps": full_qps,
                             "not_modified_qps": cond_qps,
                             "speedup": round(cond_qps / full_qps, 2)})
        print(f"  http[etag   ] 304 fast path: {cond_qps:>9,.0f} q/s vs "
              f"{full_qps:,.0f} q/s full pages "
              f"({cond_qps / full_qps:.1f}x)")

        server.close()
        gw.close()
        assert gw.scheduler.stats["resolved"] == gw.scheduler.stats["submitted"]

        out["http_vs_inproc"] = round(http_qps / inproc_qps, 2)
        out["floor"] = CI_FLOOR if fast else FLOOR
        out["pass"] = bool(out["http_vs_inproc"] >= out["floor"])
        return out


# --------------------------------------------------------------------- #
#                multi-process serving bench (--workers N)               #
# --------------------------------------------------------------------- #

def _pss_kb(pid: int):
    """Proportional set size of ``pid`` in kB — the honest per-process
    memory number: pages shared by M processes bill 1/M to each, so a
    pool over one mmap'd table sums to ~one table, not N. Returns
    (kb, basis); falls back to VmRSS where smaps_rollup is unavailable
    (RSS double-counts shared pages — callers skip the sublinearity
    assertion on that basis)."""
    try:
        for line in Path(f"/proc/{pid}/smaps_rollup").read_text().splitlines():
            if line.startswith("Pss:"):
                return int(line.split()[1]), "pss"
    except OSError:
        pass
    try:
        for line in Path(f"/proc/{pid}/status").read_text().splitlines():
            if line.startswith("VmRSS:"):
                return int(line.split()[1]), "rss"
    except OSError:
        pass
    return 0, "unavailable"


def _table_map_kb(pid: int, suffix: str = "/table.f32"):
    """Memory accounting for ``pid``'s mmap of the raw table file, from
    /proc/<pid>/smaps. Returns {rss_kb, pss_kb, private_kb, size_kb}
    summed over every ``table.f32`` mapping, or None where smaps is
    unavailable. A read-only file mapping shared across the pool shows
    private_kb ~ 0 and pool-summed pss_kb ~ one file; a copy-based
    design shows no such mapping at all (anon memory instead)."""
    try:
        text = Path(f"/proc/{pid}/smaps").read_text()
    except OSError:
        return None
    out = {"rss_kb": 0, "pss_kb": 0, "private_kb": 0, "size_kb": 0}
    active = False
    for line in text.splitlines():
        head = line[:1]
        if head.isdigit() or head in "abcdef":   # mapping header line
            active = line.rstrip().endswith(suffix)
        elif active:
            key, _, rest = line.partition(":")
            if key == "Rss":
                out["rss_kb"] += int(rest.split()[0])
            elif key == "Pss":
                out["pss_kb"] += int(rest.split()[0])
            elif key in ("Private_Dirty", "Private_Clean"):
                out["private_kb"] += int(rest.split()[0])
            elif key == "Size":
                out["size_kb"] += int(rest.split()[0])
    return out


def _publish_bench_registry(td: str, n: int, d: int) -> list:
    """Synthetic GO table published into a fresh registry (numpy only —
    this parent later talks to forked pools, so it must not run jax)."""
    from repro.core.registry import EmbeddingRegistry
    rng = np.random.default_rng(0)
    registry = EmbeddingRegistry(td)
    ids = [f"GO:{i:07d}" for i in range(n)]
    labels = [f"synthetic term {i}" for i in range(n)]
    emb = rng.standard_normal((n, d)).astype(np.float32)
    registry.publish("go", "2025-01", "transe", ids, labels, emb,
                     ontology_checksum="bench", hyperparameters={"dim": d})
    registry.seal("go", "2025-01")
    return ids


def _launch_pool(registry_root: str, workers: int):
    """Start ``python -m repro.api.workers`` and wait for its READY line.
    Returns (proc, port, worker_pids)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.api.workers",
         "--registry", registry_root, "--workers", str(workers),
         "--watch-interval-ms", "100"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
        cwd=str(REPO))
    line = proc.stdout.readline().strip()
    if not line.startswith("READY"):
        err = proc.stderr.read()
        proc.kill()
        raise RuntimeError(f"worker pool failed to start: {line!r}\n{err}")
    port = int(line.split("port=")[1].split()[0])
    pids = [int(p) for p in line.split("pids=")[1].split()[0].split(",")]
    return proc, port, pids


def _stop_pool(proc) -> None:
    proc.send_signal(signal.SIGTERM)
    try:
        proc.communicate(timeout=30)
    except subprocess.TimeoutExpired:
        proc.kill()
        proc.communicate()


def _http_get_bytes(port: int, path: str) -> bytes:
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        body = resp.read()
        assert resp.status == 200, body[:200]
        return body
    finally:
        conn.close()


def _fleet(port: int, clients: int, per_client: int, n: int, k: int):
    out = subprocess.run(
        [sys.executable, "-c", _CLIENT_DRIVER, str(port),
         str(clients), str(per_client), str(n), str(k)],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rep = json.loads(out.stdout)
    return rep["wall"], rep["lat"]


def _publish_visible_s(registry_root: str, port: int, version: str,
                       n: int, d: int, timeout_s: float = 30.0) -> float:
    """Publish a new sealed version, then poll the pool's /versions until
    every route answer reflects it — the cross-process publish→visible
    latency (store watcher tick + invalidate + warm-build)."""
    from repro.core.registry import EmbeddingRegistry
    rng = np.random.default_rng(7)
    registry = EmbeddingRegistry(registry_root)
    ids = [f"GO:{i:07d}" for i in range(n)]
    labels = [f"synthetic term {i}" for i in range(n)]
    emb = rng.standard_normal((n, d)).astype(np.float32)
    registry.publish("go", version, "transe", ids, labels, emb,
                     ontology_checksum=f"bench-{version}",
                     hyperparameters={"dim": d})
    t0 = time.perf_counter()
    registry.seal("go", version)
    deadline = t0 + timeout_s
    while time.perf_counter() < deadline:
        body = json.loads(_http_get_bytes(port, "/versions/go"))
        if body.get("latest") == version:
            return round(time.perf_counter() - t0, 3)
        time.sleep(0.02)
    raise AssertionError(
        f"publish of {version} not visible after {timeout_s}s")


def _wire_parity(port: int, gw, ids, k: int) -> dict:
    """Byte-compare HTTP bodies from the pool against the in-process
    ``Gateway.handle`` wire dicts for a sample of every data route —
    the transport must add nothing and lose nothing."""
    from urllib.parse import parse_qsl, quote
    paths = [(f"/get-vector/go/transe?query={ids[i]}", None)
             for i in (0, 1, 7)]
    paths += [(f"/sim/go/transe?a={ids[2]}&b={ids[5]}", None),
              (f"/closest-concepts/go/transe?query={ids[3]}&k={k}", None),
              ("/download/go/transe?offset=0&limit=5", None),
              ("/autocomplete/go/transe"
               f"?prefix={quote('synthetic term 1')}&limit=5", None),
              ("/versions/go", None)]
    checked, mismatches = 0, []
    for path, _ in paths:
        body = _http_get_bytes(port, path)
        route, _, query = path.partition("?")
        payload = {}
        for key, value in parse_qsl(query):
            payload[key] = int(value) if value.isdigit() else value
        expect = json.dumps(gw.handle(route, payload)).encode("utf-8")
        checked += 1
        if body != expect:
            mismatches.append(path)
    return {"checked": checked, "mismatches": mismatches}


def run_mp(fast: bool = False, workers: int = 2, clients: int = 16,
           max_batch: int = 64, flush_after_ms: float = 2.0,
           total_requests: int | None = None) -> dict:
    """Multi-process vs single-process HTTP serving over the same
    mmap-backed store: q/s at ``clients`` concurrent connections, PSS
    sublinearity across the pool, publish→visible latency, and wire
    parity with the in-process gateway. Emits the BENCH_http_mp.json
    payload."""
    n = 2_000 if fast else 20_000
    d, k = 200, 10
    total = total_requests or (512 if fast else 2_048)
    per_client = max(1, total // clients)
    total = per_client * clients
    try:
        cores = len(os.sched_getaffinity(0))
    except AttributeError:
        cores = os.cpu_count() or 1

    out = {"n_classes": n, "dim": d, "k": k, "clients": clients,
           "workers": workers, "total_requests": total, "cpu_cores": cores,
           "table_bytes": n * d * 4, "modes": []}

    def timed_pool(n_workers: int):
        """(qps, p50, p99, pss_kb_per_worker, mem_basis, table_maps,
        visible_s)"""
        with tempfile.TemporaryDirectory() as td:
            _publish_bench_registry(td, n, d)
            proc, port, pids = _launch_pool(td, n_workers)
            try:
                wall, lat = min((_fleet(port, clients, per_client, n, k)
                                 for _ in range(2)), key=lambda x: x[0])
                mem = [_pss_kb(pid) for pid in pids]
                basis = mem[0][1] if mem else "unavailable"
                tmaps = [_table_map_kb(pid) for pid in pids]
                visible = _publish_visible_s(td, port, "2025-02", n, d)
            finally:
                _stop_pool(proc)
            p50, p99 = _percentiles(lat)
            return (round(total / wall, 1), p50, p99,
                    [m[0] for m in mem], basis, tmaps, visible)

    # ---- single-process baseline (same pool machinery, 1 worker) ------ #
    sp_qps, p50, p99, sp_mem, sp_basis, _sp_tmaps, sp_visible = timed_pool(1)
    out["modes"].append({"mode": "http-1worker", "clients": clients,
                         "qps": sp_qps, "p50_ms": p50, "p99_ms": p99,
                         "pss_kb": sp_mem, "publish_visible_s": sp_visible})
    print(f"  http[ 1 proc] {clients:2d} clients x {per_client} calls: "
          f"{sp_qps:>9,.0f} q/s  p50={p50:.3f}ms p99={p99:.3f}ms  "
          f"pss={sum(sp_mem)/1024:.0f}MB  publish->visible {sp_visible}s")

    # ---- the pool ----------------------------------------------------- #
    mp_qps, p50, p99, mp_mem, mp_basis, mp_tmaps, mp_visible = \
        timed_pool(workers)
    speedup = round(mp_qps / sp_qps, 2)
    out["modes"].append({"mode": f"http-{workers}worker", "clients": clients,
                         "qps": mp_qps, "p50_ms": p50, "p99_ms": p99,
                         "pss_kb": mp_mem, "publish_visible_s": mp_visible,
                         "vs_single": speedup})
    print(f"  http[{workers:2d} proc] {clients:2d} clients x {per_client} "
          f"calls: {mp_qps:>9,.0f} q/s ({speedup:.2f}x single)  "
          f"p50={p50:.3f}ms p99={p99:.3f}ms  "
          f"pss={sum(mp_mem)/1024:.0f}MB  publish->visible {mp_visible}s")

    # ---- memory: the table is shared pages, not copies ---------------- #
    # Gate on the table.f32 mapping itself (per-mapping smaps), not on
    # whole-process PSS: each worker carries ~125MB of private
    # XLA/interpreter footprint that drowns a 1.6MB CI-size table, so
    # the pool-vs-linear process ratio is pure noise at --fast. The
    # mapping-level numbers are exact at any size.
    maps = [m for m in mp_tmaps if m]
    mapped = [m for m in maps if m["rss_kb"] > 0]
    table_kb = max((m["size_kb"] for m in maps), default=0)
    pool_table_pss = sum(m["pss_kb"] for m in mapped)
    private_kb = sum(m["private_kb"] for m in mapped)
    mem_ok = None
    if maps:
        mem_ok = bool(
            mapped                        # served from a file mapping...
            and private_kb == 0           # ...with no COW'd copies...
            and pool_table_pss            # ...billed ~once pool-wide
            <= MP_TABLE_PSS_RATIO * table_kb + 64)
    out["memory"] = {
        "basis": mp_basis, "single_pss_kb": sum(sp_mem),
        "pool_pss_kb": sum(mp_mem),
        "linear_scaling_kb": workers * sum(sp_mem),
        "table_map_kb": table_kb,
        "table_mapped_workers": len(mapped),
        "table_pool_pss_kb": pool_table_pss,
        "table_private_kb": private_kb,
        "max_table_pss_ratio": MP_TABLE_PSS_RATIO,
        "table_shared": mem_ok}
    print(f"  http[memory ] pool PSS {sum(mp_mem)/1024:.0f}MB "
          f"(1-worker {sum(sp_mem)/1024:.0f}MB); table.f32 mapped by "
          f"{len(mapped)}/{len(mp_tmaps)} workers, pool PSS "
          f"{pool_table_pss}kB vs one file {table_kb}kB, "
          f"private {private_kb}kB -> "
          f"{'shared OK' if mem_ok else 'NOT SHARED' if mem_ok is False else 'smaps unavailable'}")

    # ---- wire parity vs the in-process gateway ------------------------ #
    # This parent runs jax now (index build for gw.handle) — AFTER every
    # fork above has already happened, so fork safety holds.
    from repro.api import Gateway
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine
    with tempfile.TemporaryDirectory() as td:
        ids = _publish_bench_registry(td, n, d)
        proc, port, _pids = _launch_pool(td, min(workers, 2))
        try:
            gw = Gateway(ServingEngine(EmbeddingRegistry(td)))
            parity = _wire_parity(port, gw, ids, k)
            gw.close()
        finally:
            _stop_pool(proc)
    out["wire_parity"] = parity
    print(f"  http[parity ] {parity['checked']} routes byte-compared, "
          f"{len(parity['mismatches'])} mismatches")

    # ---- floor -------------------------------------------------------- #
    if cores >= workers + 1:
        floor, basis = (MP_CI_FLOOR, "ci") if fast else (MP_FLOOR, "full")
        speed_ok = speedup >= floor
    else:
        # time-slicing one core: no parallel speedup is physically
        # possible and the ratio is noise — record it, don't gate on it
        floor, basis = None, f"not gated ({cores} cores < " \
            f"{workers + 1} needed for parallel speedup)"
        speed_ok = True
    out["mp_vs_sp"] = speedup
    out["floor"] = floor
    out["floor_basis"] = basis
    out["publish_visible_delta_s"] = round(mp_visible - sp_visible, 3)
    out["pass"] = bool(
        speed_ok
        and not parity["mismatches"]
        and mem_ok is not False
        and mp_visible <= max(2.0, sp_visible + 1.0))
    return out


def write_results_mp(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_http_mp.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def floor_speedup(report: dict) -> float:
    """The floor metric: HTTP q/s over in-process gateway q/s at the
    benchmark's client count."""
    return report.get("http_vs_inproc", 0.0)


def section_key(fast: bool) -> str:
    """Fast (CI-sized) runs record under their own key so they never
    overwrite a full-sized trajectory with smaller-n numbers."""
    return "http_fast" if fast else "http"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_http.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized table (2k classes instead of 20k)")
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="run the multi-process axis instead: N pre-forked "
                         "workers vs a 1-worker pool over the same "
                         "mmap-backed store; emits BENCH_http_mp.json")
    args = ap.parse_args()

    if args.workers is not None:
        rep = run_mp(fast=args.fast, workers=args.workers,
                     clients=args.clients)
        out = write_results_mp({section_key(args.fast): rep})
        print(f"[bench_http] wrote {out}")
        status = "PASS" if rep["pass"] else "FAIL"
        floor_txt = (f"floor {rep['floor']}x, " if rep["floor"] is not None
                     else "")
        print(f"[bench_http] {status}: {args.workers}-worker pool = "
              f"{rep['mp_vs_sp']:.2f}x single-process at "
              f"{rep['clients']} clients ({floor_txt}"
              f"{rep['floor_basis']}); table shared = "
              f"{rep['memory']['table_shared']}; "
              f"parity mismatches = "
              f"{len(rep['wire_parity']['mismatches'])}")
        if not rep["pass"]:
            sys.exit(1)
        return

    rep = run(fast=args.fast, clients=args.clients)
    out = write_results({section_key(args.fast): rep})
    print(f"[bench_http] wrote {out}")

    status = "PASS" if rep["pass"] else "FAIL"
    print(f"[bench_http] {status}: HTTP = {floor_speedup(rep):.2f}x the "
          f"in-process gateway at {rep['clients']} clients "
          f"(floor {rep['floor']}x)")
    if not rep["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
