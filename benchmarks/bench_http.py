"""HTTP layer benchmark: the stdlib service front end vs the in-process
gateway under 16 concurrent clients, plus the conditional-GET fast path.

Three measurements over the same top-k workload:

  * gateway-inproc  — 16 threads call ``gw.closest_concepts`` directly
    (PR 4's batched mode: tickets + the background flush loop). This is
    the ceiling: no sockets, no JSON re-parse.
  * http            — the same 16 clients, each holding ONE persistent
    keep-alive ``http.client.HTTPConnection`` to a
    ``ThreadingHTTPServer`` over the *same* gateway, so the scheduler
    coalesces across sockets exactly as it does across threads. The
    clients run in a SEPARATE process: real clients do not share the
    server's GIL, and billing the server for client-side response
    parsing in the same interpreter would understate it ~2x.
  * etag-304        — single client re-fetching a pinned download page
    with ``If-None-Match``: the 304 path (no gateway, no index) vs the
    full 200 page fetch, q/s each.

Emits ``benchmarks/results/BENCH_http.json``.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_http [--fast]

Acceptance floor (PR 5): HTTP >= 0.5x the in-process gateway q/s at 16
clients at full size — the transport tax (socket + HTTP parse + JSON
codec) must stay under half the throughput, which it only does if
keep-alive and cross-socket coalescing actually work. At --fast CI size
the floor is 0.2x: with a 2k-class table the kernel work per request is
so small that the constant per-request transport cost dominates both
sides of the ratio (and the 2-core CI box runs client and server
processes on the same silicon), so the CI floor only catches
"keep-alive or coalescing stopped working" regressions; measured
full-size numbers are the recorded trajectory.
"""
from __future__ import annotations

import argparse
import http.client
import json
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
FLOOR = 0.5          # http q/s vs in-process gateway q/s, 16 clients
CI_FLOOR = 0.2       # --fast: transport tax dominates at tiny kernel size

#: the out-of-process client fleet: argv = port clients per_client n k,
#: stdout = one JSON line {"wall": s, "lat": [s, ...]}
_CLIENT_DRIVER = r"""
import http.client, json, random, sys, threading, time
port, clients, per, n, k = (int(a) for a in sys.argv[1:6])
ids = [f"GO:{i:07d}" for i in range(n)]
lat, errors, lock = [], [], threading.Lock()
barrier = threading.Barrier(clients + 1)

def worker(cix):
    r = random.Random(100 + cix)
    mine = []
    try:
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        barrier.wait()
        for _ in range(per):
            q = ids[r.randrange(n)]
            t0 = time.perf_counter()
            conn.request("GET",
                         f"/closest-concepts/go/transe?query={q}&k={k}")
            resp = conn.getresponse()
            body = resp.read()
            mine.append(time.perf_counter() - t0)
            assert resp.status == 200, body[:200]
        conn.close()
    except Exception as e:
        # a dead client must fail the whole measurement, not quietly
        # inflate q/s by shortening the wall clock
        with lock:
            errors.append(f"client {cix}: {e!r}")
    with lock:
        lat.extend(mine)

threads = [threading.Thread(target=worker, args=(i,)) for i in range(clients)]
for t in threads:
    t.start()
barrier.wait()
t0 = time.perf_counter()
for t in threads:
    t.join()
if errors or len(lat) != clients * per:
    print("\n".join(errors) or f"only {len(lat)} requests completed",
          file=sys.stderr)
    sys.exit(1)
print(json.dumps({"wall": time.perf_counter() - t0, "lat": lat}))
"""


def _percentiles(lat_s):
    lat_ms = np.asarray(lat_s) * 1e3
    return (round(float(np.percentile(lat_ms, 50)), 3),
            round(float(np.percentile(lat_ms, 99)), 3))


def run(fast: bool = False, clients: int = 16, max_batch: int = 64,
        flush_after_ms: float = 2.0,
        total_requests: int | None = None) -> dict:
    from repro.api import Gateway, serve_http
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest

    n = 2_000 if fast else 20_000          # paper: GO > 40k classes
    d, k = 200, 10
    total = total_requests or (512 if fast else 2_048)
    per_client = total // clients
    total = per_client * clients
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        ids = [f"GO:{i:07d}" for i in range(n)]
        labels = [f"synthetic term {i}" for i in range(n)]
        emb = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go", "2025-01", "transe", ids, labels, emb,
                         ontology_checksum="bench", hyperparameters={"dim": d})
        engine = ServingEngine(registry)

        # jit-warm every power-of-two bucket shape either mode can hit
        warm = BatchScheduler(engine, max_batch=max_batch)
        b = 1
        while b <= max_batch:
            for _ in range(b):
                warm.submit(TopKRequest("go", "transe",
                                        ids[int(rng.integers(n))], k))
            warm.flush()
            b <<= 1

        gw = Gateway(engine, max_batch=max_batch,
                     flush_after_ms=flush_after_ms)
        out = {"n_classes": n, "dim": d, "k": k, "clients": clients,
               "max_batch": max_batch, "flush_after_ms": flush_after_ms,
               "total_requests": total, "modes": []}

        def fanout(worker):
            lat, failures, lock = [], [], threading.Lock()
            barrier = threading.Barrier(clients + 1)

            def client(cix):
                r = np.random.default_rng(100 + cix)
                barrier.wait()
                try:
                    mine = worker(cix, r)
                except Exception as e:
                    with lock:
                        failures.append(f"client {cix}: {e!r}")
                    return
                with lock:
                    lat.extend(mine)

            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(clients)]
            for t in threads:
                t.start()
            barrier.wait()
            t0 = time.perf_counter()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            # a dead client shortens the wall clock — failing loudly is
            # the only way the q/s ratio stays meaningful
            assert not failures, failures
            assert len(lat) == total, f"only {len(lat)}/{total} completed"
            return wall, lat

        # ---- mode 1: in-process batched gateway (the ceiling) --------- #
        def inproc_worker(cix, r):
            mine = []
            for _ in range(per_client):
                q = ids[int(r.integers(n))]
                t1 = time.perf_counter()
                gw.closest_concepts("go", "transe", q, k=k)
                mine.append(time.perf_counter() - t1)
            return mine

        # best-of-2 (run.py's _time does the same): one bad descheduling
        # on a small CI box otherwise dominates the ratio
        wall, lat = min((fanout(inproc_worker) for _ in range(2)),
                        key=lambda x: x[0])
        inproc_qps = round(total / wall, 1)
        p50, p99 = _percentiles(lat)
        out["modes"].append({"mode": "gateway-inproc", "clients": clients,
                             "qps": inproc_qps, "p50_ms": p50, "p99_ms": p99,
                             "wall_s": round(wall, 3)})
        print(f"  http[inproc ] {clients:2d} clients x {per_client} calls: "
              f"{inproc_qps:>9,.0f} q/s  p50={p50:.3f}ms p99={p99:.3f}ms")

        # ---- mode 2: the same clients over real sockets --------------- #
        server = serve_http(gw, port=0)
        port = server.port

        def http_fleet():
            out = subprocess.run(
                [sys.executable, "-c", _CLIENT_DRIVER, str(port),
                 str(clients), str(per_client), str(n), str(k)],
                capture_output=True, text=True, timeout=600)
            assert out.returncode == 0, out.stderr[-2000:]
            rep = json.loads(out.stdout)
            return rep["wall"], rep["lat"]

        wall, lat = min((http_fleet() for _ in range(2)),
                        key=lambda x: x[0])
        http_qps = round(total / wall, 1)
        p50, p99 = _percentiles(lat)
        row = {"mode": "http", "clients": clients, "qps": http_qps,
               "p50_ms": p50, "p99_ms": p99, "wall_s": round(wall, 3),
               "vs_inproc": round(http_qps / inproc_qps, 2)}
        out["modes"].append(row)
        print(f"  http[socket ] {clients:2d} clients x {per_client} calls: "
              f"{http_qps:>9,.0f} q/s ({row['vs_inproc']:.2f}x in-process)  "
              f"p50={p50:.3f}ms p99={p99:.3f}ms")

        # ---- mode 3: conditional GET fast path (informational) -------- #
        n_cond = min(total, 256)
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        try:
            path = "/download/go/transe?version=2025-01&offset=0&limit=100"
            conn.request("GET", path)
            resp = conn.getresponse()
            etag = resp.getheader("ETag")
            resp.read()

            t0 = time.perf_counter()
            for _ in range(n_cond):
                conn.request("GET", path)
                resp = conn.getresponse()
                resp.read()
                assert resp.status == 200
            full_qps = round(n_cond / (time.perf_counter() - t0), 1)

            t0 = time.perf_counter()
            for _ in range(n_cond):
                conn.request("GET", path, headers={"If-None-Match": etag})
                resp = conn.getresponse()
                resp.read()
                assert resp.status == 304
            cond_qps = round(n_cond / (time.perf_counter() - t0), 1)
        finally:
            conn.close()
        out["modes"].append({"mode": "etag-304", "clients": 1,
                             "full_page_qps": full_qps,
                             "not_modified_qps": cond_qps,
                             "speedup": round(cond_qps / full_qps, 2)})
        print(f"  http[etag   ] 304 fast path: {cond_qps:>9,.0f} q/s vs "
              f"{full_qps:,.0f} q/s full pages "
              f"({cond_qps / full_qps:.1f}x)")

        server.close()
        gw.close()
        assert gw.scheduler.stats["resolved"] == gw.scheduler.stats["submitted"]

        out["http_vs_inproc"] = round(http_qps / inproc_qps, 2)
        out["floor"] = CI_FLOOR if fast else FLOOR
        out["pass"] = bool(out["http_vs_inproc"] >= out["floor"])
        return out


def floor_speedup(report: dict) -> float:
    """The floor metric: HTTP q/s over in-process gateway q/s at the
    benchmark's client count."""
    return report.get("http_vs_inproc", 0.0)


def section_key(fast: bool) -> str:
    """Fast (CI-sized) runs record under their own key so they never
    overwrite a full-sized trajectory with smaller-n numbers."""
    return "http_fast" if fast else "http"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_http.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized table (2k classes instead of 20k)")
    ap.add_argument("--clients", type=int, default=16)
    args = ap.parse_args()

    rep = run(fast=args.fast, clients=args.clients)
    out = write_results({section_key(args.fast): rep})
    print(f"[bench_http] wrote {out}")

    status = "PASS" if rep["pass"] else "FAIL"
    print(f"[bench_http] {status}: HTTP = {floor_speedup(rep):.2f}x the "
          f"in-process gateway at {rep['clients']} clients "
          f"(floor {rep['floor']}x)")
    if not rep["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
