"""GO-scale serving benchmark: the scaling *curve*, not one point.

For each rung N (full: 10k/40k/100k classes, ``--fast``: 1k/4k/10k) a
fresh subprocess wires the whole release path end to end — synthetic
GO-profile generation → train (capped-step TransE via the Updater) →
publish (raw mmap layout + sorted-label sidecar) → serve — and records:

  * ``qps``                      batched top-k throughput (scheduler, batch 32)
  * ``publish_to_first_query_s`` cold engine → first ranked answer (includes
                                 mmap open, index build, kernel warm-up)
  * ``index_build_s``            EmbeddingIndex construction alone
  * ``peak_rss_mb``              subprocess peak RSS (rungs are isolated
                                 processes so rungs don't inherit allocations)
  * ``stream_peak_block_bytes``  largest single device transfer the
                                 streaming top-k made

Gates (the scale acceptance for PR 8):

  * **residency** — every rung's peak streamed transfer stays within the
    O(block) bound ``STREAM_BLOCK_ROWS·(d+1)·4`` bytes and the index pins
    zero table bytes on device (``device_table_bytes() == 0``): no
    full-table private device copy exists at any N.
  * **per-row cost ≤ 2x** — per-query cost normalized by N
    (``1/(qps·N)``) at the largest rung is within 2x of the smallest.  A
    brute-force scan is Θ(N) per query, so *per-row* cost is the
    scale-free number; "q/s within 2x per-query cost" from the issue is
    read this way because absolute per-query cost of an exact scan
    necessarily grows ~10x over a 10x N range.
  * **sub-linear q/s degradation** — q/s at the largest rung is strictly
    better than the linear-scaling floor ``qps_small · (N_small/N_large)``.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_scale [--fast]

Emits ``benchmarks/results/BENCH_scale.json`` (merge-write: fast runs
record under ``scale_fast`` and never clobber the full curve).
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
RUNGS_FULL = (10_000, 40_000, 100_000)
RUNGS_FAST = (1_000, 4_000, 10_000)
BATCH = 32
_MARK = "RUNG_JSON: "


def run_rung(n: int, fast: bool = False) -> dict:
    """One scale rung, in-process: generate → train → publish → serve."""
    from repro.configs.go_kge import SCALE
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest
    from repro.core.updater import SyntheticReleaseChannel, Updater
    from repro.kernels import ops as kops
    from repro.ontology.synthetic import generate

    steps = 10 if fast else 50
    k = 10
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        kg = generate(SCALE.spec, seed=0, n_terms=n)
        t_gen = time.perf_counter() - t0

        registry = EmbeddingRegistry(td)
        channel = SyntheticReleaseChannel("go-scale")
        channel.bump("2025-01-01", kg)
        updater = Updater(registry, models=SCALE.models, dim=SCALE.dim,
                          train_cfg=SCALE.train, steps_override=steps)
        report = updater.run_once(channel)
        assert report.trained_models, "train → publish produced no models"

        ids = list(kg.entities)
        model = SCALE.models[0]

        # publish → first ranked answer, cold: mmap open + index build +
        # first kernel call (jit trace) all included
        engine = ServingEngine(registry)
        t0 = time.perf_counter()
        first = engine.closest_concepts("go-scale", model, ids[0], k=k)
        t_first = time.perf_counter() - t0
        assert len(first) == k

        # index build alone, from a second cold engine
        engine2 = ServingEngine(registry)
        t0 = time.perf_counter()
        idx = engine2._index("go-scale", model)
        t_build = time.perf_counter() - t0

        # batched q/s through the scheduler, residency instrumented
        sched = BatchScheduler(engine, max_batch=BATCH)
        queries = [ids[int(i)] for i in rng.integers(0, n, BATCH)]
        for q in queries:                      # warm the batch shape
            sched.submit(TopKRequest("go-scale", model, q, k))
        sched.flush()
        kops.reset_stream_stats()
        repeats = 3 if fast else 5
        laps = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            for q in queries:
                sched.submit(TopKRequest("go-scale", model, q, k))
            res = sched.flush()
            assert len(res) == BATCH
            laps.append(time.perf_counter() - t0)
        qps = BATCH / min(laps)

        # the scale invariant: peak device allocation O(block + k), never
        # a full-table private copy — on either side of the transfer
        d = idx.embeddings.shape[1]
        block_bound = kops.STREAM_BLOCK_ROWS * (d + 1) * 4
        peak_block = kops.stream_stats["peak_block_bytes"]
        residency_ok = (0 < peak_block <= block_bound
                        and idx.device_table_bytes() == 0
                        # strictly smaller than the table once N exceeds one
                        # block — i.e. the table was streamed, not copied
                        and (n <= kops.STREAM_BLOCK_ROWS
                             or peak_block < idx.embeddings.nbytes))

        rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return {
            "n_classes": n, "dim": d, "k": k, "batch": BATCH,
            "train_steps": steps,
            "generate_s": round(t_gen, 3),
            "update_wall_s": round(report.wall_s, 3),
            "publish_to_first_query_s": round(t_first, 3),
            "index_build_s": round(t_build, 3),
            "qps": round(qps, 1),
            "per_query_ms": round(1e3 / qps * 1, 3),
            "stream_peak_block_bytes": int(peak_block),
            "stream_block_bound_bytes": int(block_bound),
            "device_table_bytes": int(idx.device_table_bytes()),
            "residency_ok": bool(residency_ok),
            "peak_rss_mb": round(rss_kb / 1024.0, 1),
        }


def _spawn_rung(n: int, fast: bool) -> dict:
    """Run one rung in a fresh subprocess so peak-RSS numbers are isolated
    per N instead of accumulating across rungs."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.bench_scale", "--rung", str(n)]
    if fast:
        cmd.append("--fast")
    proc = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"rung {n} failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(f"rung {n} produced no result line:\n"
                       f"{proc.stdout[-2000:]}")


def run(fast: bool = False) -> dict:
    rungs = RUNGS_FAST if fast else RUNGS_FULL
    out = {"batch": BATCH, "rungs": []}
    for n in rungs:
        row = _spawn_rung(n, fast)
        out["rungs"].append(row)
        print(f"  scale[N={n:>7,}]: {row['qps']:>8,.0f} q/s  "
              f"first-query {row['publish_to_first_query_s']:.2f}s  "
              f"build {row['index_build_s']:.3f}s  "
              f"rss {row['peak_rss_mb']:.0f} MB  "
              f"residency={'ok' if row['residency_ok'] else 'VIOLATED'}")

    lo, hi = out["rungs"][0], out["rungs"][-1]
    cost_row_lo = 1.0 / (lo["qps"] * lo["n_classes"])
    cost_row_hi = 1.0 / (hi["qps"] * hi["n_classes"])
    out["per_row_cost_ratio"] = round(cost_row_hi / cost_row_lo, 3)
    linear_floor = lo["qps"] * lo["n_classes"] / hi["n_classes"]
    out["qps_linear_floor"] = round(linear_floor, 1)
    out["sublinear_ok"] = hi["qps"] > linear_floor
    out["residency_ok"] = all(r["residency_ok"] for r in out["rungs"])
    return out


def section_key(fast: bool) -> str:
    return "scale_fast" if fast else "scale"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_scale.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized rungs (1k/4k/10k instead of 10k/40k/100k)")
    ap.add_argument("--rung", type=int, default=None,
                    help="internal: run one rung in-process, print JSON")
    args = ap.parse_args()

    if args.rung is not None:
        row = run_rung(args.rung, fast=args.fast)
        print(_MARK + json.dumps(row))
        return

    section = run(fast=args.fast)
    out = write_results({section_key(args.fast): section})
    print(f"[bench_scale] wrote {out}")

    ratio, floor = section["per_row_cost_ratio"], 2.0
    ok = (section["residency_ok"] and section["sublinear_ok"]
          and ratio <= floor)
    status = "PASS" if ok else "FAIL"
    print(f"[bench_scale] {status}: per-row cost ratio "
          f"{ratio:.2f}x (bound {floor}x), sub-linear "
          f"{'yes' if section['sublinear_ok'] else 'NO'}, "
          f"residency {'ok' if section['residency_ok'] else 'VIOLATED'}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
