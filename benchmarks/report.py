"""Aggregate dry-run artifacts into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.report [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "benchmarks" / "results" / "dryrun"

ARCH_ORDER = ["llava_next_34b", "falcon_mamba_7b", "h2o_danube_1_8b",
              "mistral_large_123b", "whisper_base", "olmoe_1b_7b",
              "grok_1_314b", "qwen2_72b", "recurrentgemma_2b",
              "internlm2_20b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str):
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = RESULTS / f"{arch}__{shape}__{mesh}.json"
            if not p.exists():
                continue
            rows.append(json.loads(p.read_text()))
    return rows


def fmt(x, digits=3):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) >= 0.01:
        return f"{x:.{digits}f}"
    return f"{x:.2e}"


def table(mesh: str, md: bool = True) -> str:
    rows = load(mesh)
    out = []
    hdr = ("| arch | shape | step | compute s | memory s | collective s | "
           "dominant | HLO TFLOP/dev | coll GB/dev | useful ratio | "
           "HBM GB/dev |")
    sep = "|" + "---|" * 11
    out.append(hdr)
    out.append(sep)
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['tag'].split('__')[0]} | "
                       f"{r['tag'].split('__')[1]} | - | - | - | - | "
                       f"SKIP (quadratic @524k) | - | - | - | - |")
            continue
        t = r["roofline"]
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | "
            f"{fmt(t['compute_s'])} | {fmt(t['memory_s'])} | "
            f"{fmt(t['collective_s'])} | **{t['dominant'][:-2]}** | "
            f"{r['flops_per_dev']/1e12:.2f} | "
            f"{r['collectives']['traffic_bytes']/1e9:.2f} | "
            f"{fmt(r.get('useful_ratio'), 3)} | {hbm:.2f} |")
    return "\n".join(out)


def summary(mesh: str) -> dict:
    rows = [r for r in load(mesh) if r["status"] == "ok"]
    doms = {}
    for r in rows:
        doms.setdefault(r["roofline"]["dominant"], []).append(r["tag"])
    worst_useful = sorted(
        (r for r in rows if r.get("useful_ratio")),
        key=lambda r: r["useful_ratio"])[:5]
    most_coll = sorted(rows, key=lambda r: -r["roofline"]["collective_s"])[:5]
    return {
        "n_ok": len(rows),
        "dominant_counts": {k: len(v) for k, v in doms.items()},
        "worst_useful_ratio": [(r["tag"], round(r["useful_ratio"], 4))
                               for r in worst_useful],
        "most_collective_bound": [(r["tag"],
                                   f"{r['roofline']['collective_s']:.3f}s")
                                  for r in most_coll],
    }


def compare(mesh: str) -> str:
    """baseline (results/dryrun_baseline) vs optimized (results/dryrun)."""
    base_dir = RESULTS.parent / "dryrun_baseline"
    out = ["| arch | shape | term | baseline s | optimized s | x |",
           "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            bp = base_dir / f"{arch}__{shape}__{mesh}.json"
            op = RESULTS / f"{arch}__{shape}__{mesh}.json"
            if not (bp.exists() and op.exists()):
                continue
            b, o = json.loads(bp.read_text()), json.loads(op.read_text())
            if b.get("status") != "ok" or o.get("status") != "ok":
                continue
            bb, ob = b["roofline"]["bound_s"], o["roofline"]["bound_s"]
            if bb <= 0:
                continue
            out.append(
                f"| {arch} | {shape} | {o['roofline']['dominant'][:-2]} | "
                f"{fmt(bb)} | {fmt(ob)} | {bb/ob:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--compare", action="store_true")
    args = ap.parse_args()
    if args.compare:
        print(compare(args.mesh))
    else:
        print(table(args.mesh))
        print()
        print(json.dumps(summary(args.mesh), indent=2))
