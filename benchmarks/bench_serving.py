"""Serving-throughput benchmark: per-request top_k vs the BatchScheduler.

Measures queries/sec and p50 per-query latency for each power-of-two batch
bucket (the scheduler's padding buckets), on the ref path and optionally
the Pallas path (interpret mode on CPU — a correctness proxy; compiled
numbers need a TPU). Emits ``benchmarks/results/BENCH_serving.json`` so
later PRs have a perf trajectory to beat.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_serving [--fast] [--pallas]

Acceptance floor (PR 1): scheduler >= 2x solo queries/sec at batch 32 on
the ref path.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
BUCKETS = (1, 2, 4, 8, 16, 32, 64)


def run(fast: bool = False, use_pallas: bool = False,
        buckets=BUCKETS, repeats: int | None = None) -> dict:
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest

    n = 2_000 if fast else 20_000          # paper: GO > 40k classes
    if use_pallas:
        n = min(n, 2_048)                  # interpret mode is slow on CPU
    d, k = 200, 10
    repeats = repeats or (2 if use_pallas else 8)
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        ids = [f"GO:{i:07d}" for i in range(n)]
        labels = [f"synthetic term {i}" for i in range(n)]
        emb = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go", "2025-01", "transe", ids, labels, emb,
                         ontology_checksum="bench", hyperparameters={"dim": d})
        engine = ServingEngine(registry, use_pallas=use_pallas)
        # the "solo" baseline must measure the kernel path, not the
        # gateway: engine.closest_concepts delegates to the gateway since
        # PR 4, whose result cache (PR 7) turns this bench's repeated
        # identical queries into dict hits — so the baseline goes
        # straight at the index (cache-off, scheduler-off), one query
        # per kernel call, which is what "no batching" actually costs
        idx = engine._index("go", "transe")
        idx.top_k([ids[0]], k=k)               # build index + warm jit

        out = {"n_classes": n, "dim": d, "k": k,
               "path": "pallas-interpret" if use_pallas else "ref",
               "repeats": repeats, "solo_baseline": "index-direct",
               "buckets": []}
        sched = BatchScheduler(engine, max_batch=max(buckets))
        for b in buckets:
            queries = [ids[int(i)] for i in rng.integers(0, n, b)]
            # warm both paths at this bucket shape (jit trace, caches)
            for q in queries:
                sched.submit(TopKRequest("go", "transe", q, k))
            sched.flush()
            idx.top_k([queries[0]], k=k)

            solo_lat = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                for q in queries:
                    idx.top_k([q], k=k)
                solo_lat.append(time.perf_counter() - t0)
            sched_lat = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                for q in queries:
                    sched.submit(TopKRequest("go", "transe", q, k))
                res = sched.flush()
                assert len(res) == b
                sched_lat.append(time.perf_counter() - t0)

            solo_best, sched_best = min(solo_lat), min(sched_lat)
            row = {
                "batch": b,
                "solo_qps": round(b / solo_best, 1),
                "sched_qps": round(b / sched_best, 1),
                "speedup": round(solo_best / sched_best, 2),
                "solo_p50_ms_per_query": round(
                    float(np.percentile(solo_lat, 50)) / b * 1e3, 3),
                "sched_p50_ms_per_query": round(
                    float(np.percentile(sched_lat, 50)) / b * 1e3, 3),
            }
            out["buckets"].append(row)
            print(f"  serving[{out['path']}] batch={b:3d}: "
                  f"solo {row['solo_qps']:>9,.0f} q/s  "
                  f"sched {row['sched_qps']:>9,.0f} q/s  "
                  f"({row['speedup']:.2f}x, "
                  f"p50 {row['sched_p50_ms_per_query']:.3f} ms/q)")
        b32 = [r for r in out["buckets"] if r["batch"] == 32]
        if b32:
            out["speedup_batch32"] = b32[0]["speedup"]
        return out


def section_key(path: str, fast: bool) -> str:
    """Fast (CI-sized) runs record under their own key so they never
    overwrite a full-sized trajectory with smaller-n numbers."""
    return f"{path}_fast" if fast else path


def write_results(report: dict) -> Path:
    """Merge ``report`` sections into BENCH_serving.json (a ref-only run
    must not clobber a previously recorded pallas section, and vice versa)."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serving.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized table (2k classes instead of 20k)")
    ap.add_argument("--pallas", action="store_true",
                    help="also run the Pallas path (interpret mode on CPU)")
    args = ap.parse_args()

    ref = run(fast=args.fast, use_pallas=False)
    report = {section_key("ref", args.fast): ref}
    if args.pallas:
        report[section_key("pallas_interpret", args.fast)] = run(
            fast=args.fast, use_pallas=True, buckets=(1, 8, 32))
    out = write_results(report)
    print(f"[bench_serving] wrote {out}")

    s32 = ref.get("speedup_batch32", 0.0)
    floor = 2.0
    status = "PASS" if s32 >= floor else "FAIL"
    print(f"[bench_serving] {status}: scheduler speedup at batch 32 on ref "
          f"path = {s32:.2f}x (floor {floor}x)")
    if s32 < floor:
        sys.exit(1)


if __name__ == "__main__":
    main()
