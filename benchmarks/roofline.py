"""Roofline term derivation from compiled dry-run artifacts.

Terms per (arch x shape x mesh), all in SECONDS on TPU v5e constants:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

``compiled.cost_analysis()`` is the per-device SPMD program's cost, so no
further division by chip count is needed. collective bytes are parsed from
the post-optimization HLO text: for each all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute we take
max(operand bytes, result bytes) as the traffic proxy (operand-only would
undercount all-gather, result-only would undercount reduce-scatter).

MODEL_FLOPS (the "useful compute" yardstick):
  train   6 * N * tokens        (fwd 2ND + bwd 4ND)
  prefill 2 * N * tokens
  decode  2 * N * batch         (one token per sequence)
with N = active params for MoE. The ratio MODEL_FLOPS / HLO_FLOPS exposes
remat recompute, masked-chunk waste and padding overhead.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# ---- TPU v5e hardware constants (per chip) ---- #
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int

    @property
    def traffic(self) -> int:
        return max(self.result_bytes, self.operand_bytes)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Scan post-optimization HLO for collective ops (async: -start only)."""
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        lhs, rhs = ls.split(" = ", 1)
        m = re.match(r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*) ?"
                     r"([a-z0-9-]+)(?:-start)?\(", rhs)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-start"):
            op = op[:-6]
        if op not in _COLLECTIVES:
            continue
        if "-done" in rhs.split("(")[0]:
            continue
        # result shapes: all shape literals before the op name (handles
        # tuple-result variadic collectives); operands live in the call parens
        call_at = m.end() - 1
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(rhs[:m.start(1)]))
        # operand shapes: inside the call parens (attrs after ')' have none)
        depth, end = 0, len(rhs)
        for i in range(call_at, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(rhs[call_at:end]))
        out.append(CollectiveOp(op, result_bytes, operand_bytes))
    return out


def collective_summary(hlo_text: str) -> Dict[str, float]:
    ops = parse_collectives(hlo_text)
    by_kind: Dict[str, float] = {}
    for o in ops:
        by_kind[o.kind] = by_kind.get(o.kind, 0) + o.traffic
    return {
        "n_ops": len(ops),
        "traffic_bytes": float(sum(o.traffic for o in ops)),
        "by_kind": by_kind,
    }


# --------------------------------------------------------------------- #
# Loop-aware HLO analysis
# --------------------------------------------------------------------- #
# XLA's cost_analysis() counts a while-loop body ONCE, but our models run
# layers (and attention chunks) under lax.scan — so dot FLOPs and
# collective bytes must be multiplied by loop trip counts. We parse the
# post-optimization HLO: computations, their call graph (fusion `calls=`,
# while `condition=/body=`, `to_apply=`), and while trip counts (the s32
# constant compared by the loop condition), then weight every dot and
# collective by its computation's execution count.

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z0-9\-]+)\(")
_CALL_ATTRS = (
    ("calls", re.compile(r"calls=%?([\w.\-]+)")),
    ("to_apply", re.compile(r"to_apply=%?([\w.\-]+)")),
    ("cond", re.compile(r"condition=%?([\w.\-]+)")),
    ("body", re.compile(r"body=%?([\w.\-]+)")),
)
_TRIP_RE = re.compile(r"s32\[\]\s*constant\((\d+)\)")
_LHS_RE = re.compile(r"\(\s*%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _parse_computations(text: str):
    """-> {name: {"lines": [...], "shapes": {op: (dtype, dims)}}}"""
    comps = {}
    cur = None
    entry = None
    for raw in text.splitlines():
        m = _COMP_HDR.match(raw)
        if m and raw.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = {"lines": [], "shapes": {}}
            if raw.startswith("ENTRY"):
                entry = cur
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        comps[cur]["lines"].append(raw)
        dm = _DEF_RE.match(raw)
        if dm:
            name, ty, _ = dm.groups()
            sm = _SHAPE_RE.match(ty)
            if sm:
                dims = tuple(int(x) for x in sm.group(2).split(",") if x)
                comps[cur]["shapes"][name] = (sm.group(1), dims)
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    block = "\n".join(comps.get(cond_name, {}).get("lines", []))
    consts = [int(x) for x in _TRIP_RE.findall(block)]
    return max(consts) if consts else 1


def _exec_counts(comps, entry):
    """Execution multiplier per computation (DAG accumulation)."""
    from collections import defaultdict, deque
    edges = defaultdict(list)            # caller -> [(callee, factor)]
    for name, c in comps.items():
        for line in c["lines"]:
            if " while(" in line:
                cm = _CALL_ATTRS[2][1].search(line)
                bm = _CALL_ATTRS[3][1].search(line)
                if bm:
                    n = _trip_count(comps, cm.group(1)) if cm else 1
                    edges[name].append((bm.group(1), n))
                    if cm:
                        edges[name].append((cm.group(1), n + 1))
            else:
                for _, rx in (_CALL_ATTRS[0], _CALL_ATTRS[1]):
                    for callee in rx.findall(line):
                        edges[name].append((callee, 1))
    indeg = defaultdict(int)
    for caller, outs in edges.items():
        for callee, _ in outs:
            indeg[callee] += 1
    counts = defaultdict(float)
    counts[entry] = 1.0
    q = deque([entry])
    seen_edges = defaultdict(int)
    # Kahn over the call DAG
    order = []
    q = deque([n for n in comps if indeg[n] == 0])
    while q:
        n = q.popleft()
        order.append(n)
        for callee, f in edges.get(n, []):
            indeg[callee] -= 1
            if indeg[callee] == 0:
                q.append(callee)
    for n in order:
        m = counts[n]
        if m == 0:
            continue
        for callee, f in edges.get(n, []):
            counts[callee] += m * f
    return counts


def _dot_flops(comp) -> float:
    total = 0.0
    for line in comp["lines"]:
        dm = _DEF_RE.match(line)
        if not dm or dm.group(3) != "dot":
            continue
        sm = _SHAPE_RE.match(dm.group(2))
        if not sm:
            continue
        out_dims = tuple(int(x) for x in sm.group(2).split(",") if x)
        out_numel = 1
        for d in out_dims:
            out_numel *= d
        rest = line[line.index("dot("):]
        lm = _LHS_RE.search(rest)
        cm = _CDIMS_RE.search(line)
        k = 1
        if lm and cm and lm.group(1) in comp["shapes"]:
            lhs_dims = comp["shapes"][lm.group(1)][1]
            for ci in (int(x) for x in cm.group(1).split(",") if x):
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
        total += 2.0 * out_numel * k
    return total


def analyze_hlo(text: str) -> Dict[str, float]:
    """Loop-aware per-device totals: dot FLOPs + collective traffic."""
    comps, entry = _parse_computations(text)
    if entry is None:
        return {"flops": 0.0, "collective_bytes": 0.0, "n_collectives": 0,
                "by_kind": {}}
    counts = _exec_counts(comps, entry)
    flops = 0.0
    coll_bytes = 0.0
    n_coll = 0
    by_kind: Dict[str, float] = {}
    for name, comp in comps.items():
        mult = counts.get(name, 0.0)
        if mult == 0.0:
            continue
        f = _dot_flops(comp)
        if f:
            flops += f * mult
        block = "\n".join(comp["lines"])
        for op in parse_collectives(block):
            coll_bytes += op.traffic * mult
            n_coll += mult
            by_kind[op.kind] = by_kind.get(op.kind, 0.0) + op.traffic * mult
    return {"flops": flops, "collective_bytes": coll_bytes,
            "n_collectives": int(n_coll), "by_kind": by_kind}


def memory_traffic_proxy(mem: Dict[str, int]) -> float:
    """One-step HBM traffic estimate from buffer assignment: arguments are
    read once, outputs written once, temporaries written + read."""
    return (mem.get("argument_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            + 2 * mem.get("temp_size_in_bytes", 0))


def model_flops(n_params: int, step: str, global_batch: int, seq: int,
                dec_len: Optional[int] = None) -> float:
    tokens = global_batch * (dec_len or seq)
    if step == "train":
        return 6.0 * n_params * tokens
    if step == "prefill":
        return 2.0 * n_params * tokens
    return 2.0 * n_params * global_batch          # decode: 1 token/seq


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    collective = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms
