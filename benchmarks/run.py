"""Benchmark harness — one benchmark per paper claim/functionality.

The paper is a resource paper (no numeric tables), so each benchmark
corresponds to a system capability it claims:

  B1 kge-training     six KGE models, dim=200 (paper §3): triples/s each
  B2 serving          the three endpoints (paper §4, Fig. 1): download
                      build time, similarity latency, top-k latency —
                      numpy brute force (the paper's implementation) vs
                      jnp oracle vs fused Pallas kernel (interpret on CPU),
                      solo vs batched
  B3 update-pipeline  release->retrain->publish->invalidate wall time
                      across an evolving version series (paper §4 update
                      mechanism)
  B4 rdf2vec-walks    vectorized random-walk corpus rate (paper §3 RDF2Vec)
  B5 serving-sched    BatchScheduler queries/sec + p50 latency per padding
                      bucket vs per-request top_k (benchmarks/bench_serving.py);
                      also written standalone to results/BENCH_serving.json
                      so later PRs have a perf trajectory to beat
  B6 concurrent       flush-loop throughput + p50/p99 under 1/4/16 submitter
                      threads vs the synchronous single-caller baseline
                      (benchmarks/bench_concurrent.py; floor: 2x at 16
                      threads), written to results/BENCH_concurrent.json
  B7 update-warm      cold vs warm update pipeline over a low-churn release
                      series: delta policy + warm-start vs full retrain —
                      wall-clock speedup (floor: 2x mid-series) + link-
                      prediction MRR parity (benchmarks/bench_update.py),
                      written to results/BENCH_update.json
  B8 gateway          batched gateway vs direct per-call ServingEngine at
                      16 concurrent clients (floor: 2x), plus the async
                      front end vs threaded tickets (floor: 0.9x)
                      (benchmarks/bench_gateway.py), written to
                      results/BENCH_gateway.json
  B9 http             the stdlib HTTP service layer vs the in-process
                      gateway at 16 keep-alive clients (floor: 0.5x),
                      plus the ETag/304 conditional-GET fast path
                      (benchmarks/bench_http.py), written to
                      results/BENCH_http.json
  B10 http-mp         pre-forked multi-process serving over the shared
                      mmap store vs a 1-worker pool: q/s at 16 clients
                      (floor: 1.5x with enough cores), table.f32
                      page-sharing proof (smaps PSS), wire byte-parity,
                      cross-process publish->visible latency
                      (bench_http.py --workers), written to
                      results/BENCH_http_mp.json
  B11 cache           version-keyed result cache + admission control:
                      Zipf (s=1.1) mixed workload cache-on vs cache-off
                      q/s (floor: 5x full / 2x fast), byte identity
                      across the publish->invalidate edge, burst p99
                      of accepted <= 3x quiescent, fast-reject median
                      < 5ms, HTTP 429 + Retry-After spot check
                      (benchmarks/bench_cache.py), written to
                      results/BENCH_cache.json
  B12 scale           GO-scale serving curve: generate -> train -> publish
                      -> serve per rung N (10k/40k/100k; --fast 1k/4k/10k
                      in isolated subprocesses), q/s, publish->first-query,
                      index build, peak RSS; gates: streamed O(block)
                      device residency, per-row cost ratio <= 2x,
                      sub-linear q/s degradation
                      (benchmarks/bench_scale.py), written to
                      results/BENCH_scale.json
  B13 jobs            async batch-analytics jobs: bulk kNN join byte-
                      identical to the serial per-query oracle,
                      interactive p99 <= 2x quiescent while a bulk job
                      runs (gated full-size, recorded at --fast), and
                      queue-overflow 429 + Retry-After in < 5ms median
                      (benchmarks/bench_jobs.py), written to
                      results/BENCH_jobs.json
  B14 analysis        repo-native invariant analyzer (repro.analysis)
                      over src/: must finish in < 10 s with zero
                      unsuppressed findings; full report written to
                      results/ANALYSIS_report.json

Usage:
    PYTHONPATH=src python -m benchmarks.run                # full benchmarks
    PYTHONPATH=src python -m benchmarks.run --only X       # one section
    PYTHONPATH=src python -m benchmarks.run --only X --fast  # CI-sized X
    PYTHONPATH=src python -m benchmarks.run --fast         # repo smoke:
        the fast test tier (pytest -m "not slow") plus the 16-thread
        scheduler bench bucket — hot-path regressions caught in ~2 min
        instead of the full 5-minute suite.

Roofline tables come from the dry-run artifacts: see benchmarks/report.py.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

RESULTS = REPO / "benchmarks" / "results"


def _time(fn, repeat=3):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


# ===================================================================== #
def bench_kge_training(fast: bool) -> dict:
    import jax
    from repro.kge import make_model
    from repro.kge.train import KGETrainer, TrainConfig
    from repro.ontology.synthetic import GO_SPEC, generate

    n_terms = 400 if fast else 2000
    steps = 30 if fast else 100
    kg = generate(GO_SPEC, seed=0, n_terms=n_terms)
    cfg = TrainConfig(batch_size=512, num_negs=16, lr=1e-2)
    out = {}
    for name in ("transe", "transr", "distmult", "hole", "boxe", "rdf2vec"):
        if name == "rdf2vec":
            from repro.data import corpus, skipgram_pairs
            walks, vocab, pad = corpus(kg, jax.random.key(0),
                                       walks_per_entity=4, walk_length=4)
            pairs = skipgram_pairs(walks, window=2, pad_token=pad, seed=0)
            trips = np.stack([pairs[:, 0], np.zeros(len(pairs), np.int32),
                              pairs[:, 1]], axis=1)
            model = make_model(name, vocab, 1, dim=200)
        else:
            trips = kg.triples
            model = make_model(name, kg.num_entities, kg.num_relations,
                               dim=200)
        trainer = KGETrainer(model, cfg)
        _, _, stats = trainer.fit(trips, steps=steps)
        out[name] = {"triples_per_s": round(stats["triples_per_s"]),
                     "final_loss": round(stats["final_loss"], 4)}
        print(f"  B1 {name:10s} {stats['triples_per_s']:>12,.0f} triples/s "
              f"loss={stats['final_loss']:.4f}")
    return out


# ===================================================================== #
def bench_serving(fast: bool) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    n = 5_000 if fast else 40_000        # paper: GO > 40k classes
    d, k = 200, 10
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((n, d)).astype(np.float32)
    unit = emb / np.linalg.norm(emb, axis=1, keepdims=True)
    ju = jnp.asarray(unit)

    out = {"n_classes": n}

    # --- the paper's implementation: numpy brute force, one query ------ #
    q1 = unit[:1]

    def numpy_topk():
        s = q1 @ unit.T
        idx = np.argpartition(-s[0], k)[:k]
        return idx[np.argsort(-s[0][idx])]
    t_np, _ = _time(numpy_topk)
    out["numpy_single_ms"] = round(t_np * 1e3, 3)

    # --- jnp oracle, single + batched ----------------------------------- #
    jq1 = jnp.asarray(q1)
    f_ref = jax.jit(lambda q: ref.topk_cosine_ref(q, ju, k))
    jax.block_until_ready(f_ref(jq1))
    t_ref, _ = _time(lambda: jax.block_until_ready(f_ref(jq1)))
    out["jnp_single_ms"] = round(t_ref * 1e3, 3)

    qb = jnp.asarray(unit[:64])
    f_ref_b = jax.jit(lambda q: ref.topk_cosine_ref(q, ju, k))
    jax.block_until_ready(f_ref_b(qb))
    t_ref_b, _ = _time(lambda: jax.block_until_ready(f_ref_b(qb)))
    out["jnp_batch64_ms"] = round(t_ref_b * 1e3, 3)
    out["jnp_batch64_per_query_ms"] = round(t_ref_b / 64 * 1e3, 4)

    # --- Pallas kernel in interpret mode (correctness proxy; compiled
    # path is TPU-only) ---------------------------------------------------#
    if not fast:
        from repro.kernels.topk_similarity import topk_cosine_pallas
        t_pl, _ = _time(lambda: jax.block_until_ready(
            topk_cosine_pallas(qb[:4], ju, k, interpret=True)), repeat=1)
        out["pallas_interpret_batch4_ms"] = round(t_pl * 1e3, 1)

    # --- similarity endpoint --------------------------------------------#
    t_sim, _ = _time(lambda: float(unit[3] @ unit[7]), repeat=10)
    out["similarity_ms"] = round(t_sim * 1e3, 5)

    # --- download payload ------------------------------------------------#
    ids = [f"GO:{i:07d}" for i in range(n)]
    t_dl, _ = _time(lambda: json.dumps(
        {i: [round(float(x), 6) for x in v]
         for i, v in zip(ids, emb[:1000])}), repeat=1)
    out["download_1000_classes_ms"] = round(t_dl * 1e3, 1)

    print(f"  B2 serving n={n}: numpy1={out['numpy_single_ms']}ms "
          f"jnp1={out['jnp_single_ms']}ms "
          f"jnp64={out['jnp_batch64_per_query_ms']}ms/q")
    return out


# ===================================================================== #
def bench_update_pipeline(fast: bool, tmpdir: Path) -> dict:
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine
    from repro.core.updater import Updater
    from repro.kge.train import TrainConfig
    from repro.ontology.synthetic import GO_SPEC, release_series

    n_terms = 200 if fast else 800
    versions = 3 if fast else 6           # paper hosts six versions
    series = release_series(GO_SPEC, versions, seed=0, n_terms=n_terms)
    registry = EmbeddingRegistry(tmpdir / "bench_registry")
    engine = ServingEngine(registry)
    # B3 measures the paper's recompute-everything policy; churn_threshold=0
    # pins full retrains so its numbers stay comparable across PRs (the
    # warm-start path is benchmarked separately in B7 / bench_update.py)
    upd = Updater(registry, engine=engine, models=("transe", "distmult"),
                  dim=64, train_cfg=TrainConfig(batch_size=256, num_negs=8),
                  steps_override=40 if fast else 120, churn_threshold=0.0)

    out = {"versions": []}
    for tag, kg in series:
        class _Ch:
            name = "go"

            def latest(self, tag=tag, kg=kg):
                return tag, kg
        rep = upd.run_once(_Ch())
        out["versions"].append({"version": tag, "changed": rep.changed,
                                "wall_s": round(rep.wall_s, 2),
                                "n_entities": kg.num_entities})
        print(f"  B3 release {tag}: retrain+publish {rep.wall_s:.2f}s "
              f"({kg.num_entities} classes)")
    latest = registry.store.latest_version("go")
    assert latest == series[-1][0]
    out["served_latest"] = latest
    return out


# ===================================================================== #
def bench_walks(fast: bool) -> dict:
    import jax
    from repro.data import corpus
    from repro.ontology.synthetic import GO_SPEC, generate

    n = 1000 if fast else 5000
    kg = generate(GO_SPEC, seed=1, n_terms=n)

    def run():
        walks, vocab, pad = corpus(kg, jax.random.key(0),
                                   walks_per_entity=8, walk_length=4)
        jax.block_until_ready(walks)
        return walks
    t, _ = _time(run, repeat=1)
    n_walks = n * 8
    print(f"  B4 walks: {n_walks:,} walks of len 4 in {t:.2f}s "
          f"({n_walks/t:,.0f} walks/s)")
    return {"n_walks": n_walks, "wall_s": round(t, 3),
            "walks_per_s": round(n_walks / t)}


# ===================================================================== #
def bench_analysis() -> dict:
    """B14: the invariant analyzer must stay fast and the tree clean.

    Runs repro.analysis in-process over src/ against the committed
    baseline and writes the full report to results/ANALYSIS_report.json
    (the CI artifact). Pass = zero unsuppressed findings, no stale
    baseline entries, wall time under 10 s.
    """
    from repro.analysis import run_analysis

    budget_s = 10.0
    report = run_analysis([REPO / "src"], root=REPO,
                          baseline=REPO / "analysis_baseline.json")
    out = {
        "files": report.files,
        "findings": len(report.findings),
        "suppressed": len(report.suppressed),
        "baselined": len(report.baselined),
        "stale_baseline": len(report.stale_baseline),
        "elapsed_s": round(report.elapsed_s, 2),
        "budget_s": budget_s,
        "pass": report.ok and report.elapsed_s < budget_s,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "ANALYSIS_report.json").write_text(
        json.dumps(report.to_json(), indent=2))
    print(f"  B14 analysis: {out['findings']} findings "
          f"({out['suppressed']} suppressed, {out['baselined']} baselined) "
          f"in {out['files']} files, {out['elapsed_s']}s "
          f"(budget {budget_s:.0f}s) -> "
          f"{'PASS' if out['pass'] else 'FAIL'}")
    return out


# ===================================================================== #
def run_smoke() -> int:
    """The repo smoke check: fast test tier + one scheduler bench bucket
    + a small cold-vs-warm update bucket.

    Catches hot-path (serving/scheduler/kernel) and update-pipeline
    regressions in ~2-3 minutes; the full suite and full benchmarks stay
    the tier-2 gate.
    """
    print("[smoke] fast test tier: pytest -m 'not slow'")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + ":" + env.get("PYTHONPATH", "")
    t0 = time.perf_counter()
    tests = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "not slow"],
        cwd=REPO, env=env)
    print(f"[smoke] tests done in {time.perf_counter() - t0:.0f}s "
          f"(exit {tests.returncode})")
    print("[smoke] scheduler bench bucket: 16-thread flush loop vs sync")
    from benchmarks.bench_concurrent import (FLOOR, floor_speedup,
                                             run as bench_conc_run,
                                             section_key, write_results)
    rep = bench_conc_run(fast=True, threads=(16,))
    write_results({section_key(True) + "_smoke": rep})
    s16 = floor_speedup(rep)
    print("[smoke] update bucket: CI-sized cold vs warm release series")
    from benchmarks import bench_update
    upd = bench_update.run(fast=True)
    bench_update.write_results(
        {bench_update.section_key(True) + "_smoke": upd})
    print("[smoke] gateway bucket: batched gateway vs direct per-call")
    from benchmarks import bench_gateway
    gwy = bench_gateway.run(fast=True)
    bench_gateway.write_results(
        {bench_gateway.section_key(True) + "_smoke": gwy})
    print("[smoke] cache bucket: Zipf result cache + admission control")
    from benchmarks import bench_cache
    cch = bench_cache.run(fast=True)
    bench_cache.write_results(
        {bench_cache.section_key(True) + "_smoke": cch})
    print("[smoke] jobs bucket: bulk join parity + 429 fast-reject")
    from benchmarks import bench_jobs
    jbs = bench_jobs.run(fast=True)
    bench_jobs.write_results(
        {bench_jobs.section_key(True) + "_smoke": jbs})
    print("[smoke] analysis bucket: invariant analyzer over src/")
    ana = bench_analysis()
    ok = (tests.returncode == 0 and s16 >= FLOOR and upd["pass"]
          and gwy["pass"] and cch["pass"] and jbs["pass"] and ana["pass"])
    print(f"[smoke] {'PASS' if ok else 'FAIL'}: tests "
          f"exit={tests.returncode}, 16-thread speedup={s16:.2f}x "
          f"(floor {FLOOR}x), warm update "
          f"{bench_update.floor_speedup(upd):.2f}x "
          f"(floor {upd['floor']}x, parity "
          f"{bench_update.quality_parity(upd)}), gateway "
          f"{bench_gateway.floor_speedup(gwy):.2f}x direct / async "
          f"{bench_gateway.async_ratio(gwy):.2f}x threaded "
          f"(floors {bench_gateway.FLOOR}x / {bench_gateway.ASYNC_RATIO}x), "
          f"cache {bench_cache.floor_speedup(cch):.2f}x "
          f"(floor {cch['floor']}x), jobs "
          f"{'PASS' if jbs['pass'] else 'FAIL'} "
          f"(429 median {jbs['overflow']['reject_p50_ms']:.3f}ms), "
          f"analysis {'PASS' if ana['pass'] else 'FAIL'} "
          f"({ana['findings']} findings, {ana['elapsed_s']}s)")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="with --only: CI-sized inputs; alone: repo smoke "
                         "(fast test tier + one scheduler bench bucket)")
    ap.add_argument("--only", default=None,
                    choices=["kge", "serving", "update", "walks", "sched",
                             "concurrent", "gateway", "http", "http-mp",
                             "cache", "scale", "jobs", "analysis"])
    args = ap.parse_args()

    if args.fast and args.only is None:
        sys.exit(run_smoke())

    RESULTS.mkdir(parents=True, exist_ok=True)
    t0 = time.perf_counter()
    report = {}
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        if args.only in (None, "kge"):
            print("[B1] KGE training throughput (six models, dim=200)")
            report["kge_training"] = bench_kge_training(args.fast)
        if args.only in (None, "serving"):
            print("[B2] serving endpoints")
            report["serving"] = bench_serving(args.fast)
        if args.only in (None, "update"):
            print("[B3] update pipeline (release series)")
            report["update_pipeline"] = bench_update_pipeline(
                args.fast, Path(td))
            print("[B7] delta-aware warm-start vs cold retrain")
            from benchmarks import bench_update
            upd_rep = bench_update.run(fast=args.fast)
            bench_update.write_results(
                {bench_update.section_key(args.fast): upd_rep})
            report["update_warm_start"] = upd_rep
        if args.only in (None, "walks"):
            print("[B4] RDF2Vec walk corpus")
            report["walks"] = bench_walks(args.fast)
        if args.only in (None, "sched"):
            print("[B5] serving scheduler throughput")
            from benchmarks.bench_serving import (run as bench_serving_run,
                                                  section_key, write_results)
            ref_report = bench_serving_run(fast=args.fast)
            write_results({section_key("ref", args.fast): ref_report})
            report["serving_scheduler"] = ref_report
        if args.only in (None, "concurrent"):
            print("[B6] concurrent flush-loop throughput")
            from benchmarks import bench_concurrent
            conc = bench_concurrent.run(fast=args.fast)
            bench_concurrent.write_results(
                {bench_concurrent.section_key(args.fast): conc})
            report["concurrent"] = conc
        if args.only in (None, "gateway"):
            print("[B8] gateway API throughput (batched vs direct, async)")
            from benchmarks import bench_gateway
            gwy = bench_gateway.run(fast=args.fast)
            bench_gateway.write_results(
                {bench_gateway.section_key(args.fast): gwy})
            report["gateway"] = gwy
        if args.only in (None, "http"):
            print("[B9] HTTP service layer throughput (socket vs in-process)")
            from benchmarks import bench_http
            htt = bench_http.run(fast=args.fast)
            bench_http.write_results(
                {bench_http.section_key(args.fast): htt})
            report["http"] = htt
        if args.only in (None, "cache"):
            print("[B11] result cache + admission control (Zipf s=1.1)")
            from benchmarks import bench_cache
            cch = bench_cache.run(fast=args.fast)
            bench_cache.write_results(
                {bench_cache.section_key(args.fast): cch})
            report["cache"] = cch
        if args.only in (None, "http-mp"):
            print("[B10] multi-process HTTP serving (pre-fork pool, "
                  "shared mmap store)")
            from benchmarks import bench_http
            mp_rep = bench_http.run_mp(fast=args.fast)
            bench_http.write_results_mp(
                {bench_http.section_key(args.fast): mp_rep})
            report["http_mp"] = mp_rep
        if args.only in (None, "scale"):
            print("[B12] GO-scale serving curve (subprocess rungs)")
            from benchmarks import bench_scale
            scl = bench_scale.run(fast=args.fast)
            bench_scale.write_results(
                {bench_scale.section_key(args.fast): scl})
            report["scale"] = scl
        if args.only in (None, "jobs"):
            print("[B13] async batch-analytics jobs (join parity, p99 "
                  "under fire, 429 fast-reject)")
            from benchmarks import bench_jobs
            jbs = bench_jobs.run(fast=args.fast)
            bench_jobs.write_results(
                {bench_jobs.section_key(args.fast): jbs})
            report["jobs"] = jbs
        if args.only in (None, "analysis"):
            print("[B14] invariant analyzer over src/")
            report["analysis"] = bench_analysis()

    report["total_wall_s"] = round(time.perf_counter() - t0, 1)
    out = RESULTS / ("bench_fast.json" if args.fast else "bench.json")
    out.write_text(json.dumps(report, indent=2))
    print(f"[bench] wrote {out} ({report['total_wall_s']}s total)")


if __name__ == "__main__":
    main()
