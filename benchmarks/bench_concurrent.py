"""Concurrent-serving benchmark: the background flush loop under 1/4/16
submitter threads vs the single-caller synchronous ``flush()`` baseline.

Workload model — the paper's serving reality is many independent clients,
each producing a *small* burst of queries per web request. Here every
client thread submits chunks of ``client_batch`` requests and blocks on
its tickets. The baseline is PR 1's serving mode: one caller that submits
a chunk and synchronously drives ``flush()`` itself — it can never batch
beyond its own chunk. The flush loop's win is cross-client coalescing:
with T submitters, deadline-drained micro-batches approach ``max_batch``
regardless of any single client's burst size, and the per-query kernel
cost amortizes accordingly.

Emits ``benchmarks/results/BENCH_concurrent.json`` with queries/sec and
p50/p99 per-request latency (submit → ticket resolved) per thread count.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_concurrent [--fast]

Acceptance floor (PR 2): flush-loop q/s at 16 submitter threads >= 2x the
single-caller synchronous baseline at the same client batch size.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
THREAD_COUNTS = (1, 4, 16)
FLOOR = 2.0


def _percentiles(lat_s):
    lat_ms = np.asarray(lat_s) * 1e3
    return (round(float(np.percentile(lat_ms, 50)), 3),
            round(float(np.percentile(lat_ms, 99)), 3))


def run(fast: bool = False, threads=THREAD_COUNTS, client_batch: int = 4,
        total_requests: int | None = None, max_batch: int = 64,
        flush_after_ms: float = 2.0) -> dict:
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest

    n = 2_000 if fast else 20_000          # paper: GO > 40k classes
    d, k = 200, 10
    total = total_requests or (512 if fast else 2_048)
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        ids = [f"GO:{i:07d}" for i in range(n)]
        labels = [f"synthetic term {i}" for i in range(n)]
        emb = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go", "2025-01", "transe", ids, labels, emb,
                         ontology_checksum="bench", hyperparameters={"dim": d})
        engine = ServingEngine(registry)
        engine.closest_concepts("go", "transe", ids[0], k=k)   # build index

        def req(r):
            return TopKRequest("go", "transe", ids[int(r.integers(n))], k)

        out = {"n_classes": n, "dim": d, "k": k,
               "client_batch": client_batch, "max_batch": max_batch,
               "flush_after_ms": flush_after_ms,
               "total_requests": total, "modes": []}

        # jit-warm every power-of-two bucket shape the run can hit
        warm = BatchScheduler(engine, max_batch=max_batch)
        b = 1
        while b <= max_batch:
            for _ in range(b):
                warm.submit(req(rng))
            warm.flush()
            b <<= 1

        # ---- baseline: single caller, synchronous flush per chunk ------ #
        sched = BatchScheduler(engine, max_batch=max_batch)
        r = np.random.default_rng(1)
        lat = []
        t0 = time.perf_counter()
        for _ in range(total // client_batch):
            t1 = time.perf_counter()
            tickets = [sched.submit(req(r)) for _ in range(client_batch)]
            res = sched.flush()
            assert len(res) == client_batch
            lat += [(time.perf_counter() - t1) / client_batch] * client_batch
        sync_wall = time.perf_counter() - t0
        sync_qps = round(total / sync_wall, 1)
        p50, p99 = _percentiles(lat)
        sync_row = {"mode": "sync-flush", "threads": 1, "qps": sync_qps,
                    "p50_ms": p50, "p99_ms": p99,
                    "wall_s": round(sync_wall, 3)}
        out["modes"].append(sync_row)
        print(f"  concurrent[baseline] sync flush, chunk={client_batch}: "
              f"{sync_qps:>9,.0f} q/s  p50={p50:.3f}ms p99={p99:.3f}ms")

        # ---- flush loop under T submitter threads ---------------------- #
        for T in threads:
            sched = BatchScheduler(engine, max_batch=max_batch,
                                   flush_after_ms=flush_after_ms)
            per_thread = total // (T * client_batch)
            lat_lock = threading.Lock()
            lat = []
            barrier = threading.Barrier(T + 1)

            def client(tix):
                r = np.random.default_rng(100 + tix)
                mine = []
                barrier.wait()
                for _ in range(per_thread):
                    chunk = []
                    for _ in range(client_batch):
                        ts = time.perf_counter()
                        chunk.append((sched.submit(req(r)), ts))
                    for ticket, ts in chunk:
                        ticket.result(timeout=60)
                        mine.append(time.perf_counter() - ts)
                with lat_lock:
                    lat.extend(mine)

            workers = [threading.Thread(target=client, args=(i,))
                       for i in range(T)]
            for w in workers:
                w.start()
            barrier.wait()
            t0 = time.perf_counter()
            for w in workers:
                w.join()
            wall = time.perf_counter() - t0
            sched.stop()
            n_done = T * per_thread * client_batch
            qps = round(n_done / wall, 1)
            p50, p99 = _percentiles(lat)
            row = {"mode": "flush-loop", "threads": T, "qps": qps,
                   "p50_ms": p50, "p99_ms": p99, "wall_s": round(wall, 3),
                   "speedup_vs_sync": round(qps / sync_qps, 2),
                   "loop_flushes": sched.stats["loop_flushes"],
                   "full_flushes": sched.stats["full_flushes"],
                   "deadline_flushes": sched.stats["deadline_flushes"],
                   "batches": sched.stats["batches"]}
            out["modes"].append(row)
            print(f"  concurrent[loop] {T:2d} threads x chunk "
                  f"{client_batch}: {qps:>9,.0f} q/s "
                  f"({row['speedup_vs_sync']:.2f}x sync)  "
                  f"p50={p50:.3f}ms p99={p99:.3f}ms  "
                  f"({row['batches']} batches, "
                  f"{row['full_flushes']} full / "
                  f"{row['deadline_flushes']} deadline)")

        peak_t = max(threads)
        peak = [m for m in out["modes"]
                if m["mode"] == "flush-loop" and m["threads"] == peak_t]
        if peak:
            out["peak_threads"] = peak_t
            out["peak_speedup_vs_sync"] = peak[0]["speedup_vs_sync"]
            # the floor metric is defined at 16 threads — never mislabel a
            # smaller run's number under the 16-thread key
            if peak_t == 16:
                out["speedup_16_threads_vs_sync"] = peak[0]["speedup_vs_sync"]
        return out


def floor_speedup(report: dict) -> float:
    """The floor metric: 16-thread flush-loop speedup over the sync
    baseline (0.0 when the 16-thread mode wasn't run)."""
    return report.get("speedup_16_threads_vs_sync", 0.0)


def section_key(fast: bool) -> str:
    """Fast (CI-sized) runs record under their own key so they never
    overwrite a full-sized trajectory with smaller-n numbers."""
    return "concurrent_fast" if fast else "concurrent"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_concurrent.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized table (2k classes instead of 20k)")
    args = ap.parse_args()

    rep = run(fast=args.fast)
    out = write_results({section_key(args.fast): rep})
    print(f"[bench_concurrent] wrote {out}")

    s16 = floor_speedup(rep)
    status = "PASS" if s16 >= FLOOR else "FAIL"
    print(f"[bench_concurrent] {status}: flush-loop at 16 threads = "
          f"{s16:.2f}x the synchronous single-caller baseline "
          f"(floor {FLOOR}x)")
    if s16 < FLOOR:
        sys.exit(1)


if __name__ == "__main__":
    main()
