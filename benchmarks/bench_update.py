"""Cold-vs-warm update benchmark: the delta-aware incremental pipeline.

Runs the *same* synthetic low-churn release series (≤10% entity churn per
release, like GO's monthly channel) through two update pipelines:

  cold  — ``churn_threshold=0.0``: every release retrains every model from
          scratch at the full step budget (the paper's recompute-everything
          policy, and this repo's behavior before PR 3);
  warm  — delta policy on: mid-series releases warm-start from the parent
          version's params (surviving rows carried, new rows fresh) at
          ``warm_frac`` of the full budget.

Two numbers matter, both recorded in
``benchmarks/results/BENCH_update.json``:

  * **speedup** — mean cold wall / mean warm wall over mid-series updates
    (the first release is full for both, so it is excluded).
    Acceptance floor (PR 3): >= 2x.
  * **quality parity** — filtered link-prediction MRR of the final
    version's published params, warm vs cold, on an eval sample of that
    release's triples. Tolerance (stated): warm MRR >= cold MRR -
    max(0.05, 0.15 * cold MRR). Both pipelines train on the full release
    (the updater publishes whole-graph embeddings), so this is fit-quality
    parity on the same data, not held-out generalization.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_update [--fast]
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
FLOOR = 2.0              # warm speedup floor over mid-series updates
#: at CI size (--fast: 500 steps) jit compile time — paid equally by both
#: pipelines and independent of the step budget — compresses the measured
#: ratio (observed 1.4-2.0x vs 2.5x full-size); the 2x acceptance floor is
#: the full-size bench's number, the CI floor only catches "warm path
#: stopped engaging" regressions (ratio ~1.0)
FAST_FLOOR = 1.25
MRR_TOL_ABS = 0.05       # quality parity: absolute MRR slack ...
MRR_TOL_REL = 0.15       # ... or relative, whichever is looser
#: per-release evolution knobs keeping entity churn <= ~10%
CALM = dict(add_frac=0.02, obsolete_frac=0.005, rewire_frac=0.005)


def _run_pipeline(series, models, dim, cfg, steps, churn_threshold,
                  warm_frac, engine_check=False):
    """Drive one Updater over the whole series; returns per-version rows
    and the final registry (kept open via the returned tempdir)."""
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine
    from repro.core.updater import SyntheticReleaseChannel, Updater

    td = tempfile.TemporaryDirectory()
    registry = EmbeddingRegistry(Path(td.name) / "registry")
    engine = ServingEngine(registry) if engine_check else None
    upd = Updater(registry, engine=engine, models=models, dim=dim,
                  train_cfg=cfg, steps_override=steps,
                  churn_threshold=churn_threshold, warm_frac=warm_frac)
    ch = SyntheticReleaseChannel("go")
    rows = []
    for tag, kg in series:
        ch.bump(tag, kg)
        rep = upd.run_once(ch)
        assert rep.changed, f"release {tag} did not trigger an update"
        if engine is not None:
            assert engine.latest_version("go") == tag
        rows.append({
            "version": tag,
            "mode": rep.mode,
            "wall_s": round(rep.wall_s, 3),
            "n_entities": kg.num_entities,
            "churn_fraction": (rep.delta or {}).get("churn_fraction"),
            "per_model": {m: {"mode": rep.details[m]["mode"],
                              "wall_s": round(rep.details[m]["wall_s"], 3),
                              "steps": rep.details[m]["steps"]}
                          for m in models},
        })
    return rows, registry, td


def _final_quality(series, registry, models, dim, eval_sample, seed=0):
    """Filtered link-prediction MRR of the final published snapshot."""
    from repro.kge import make_model, rank_based_eval

    tag, kg = series[-1]
    rng = np.random.default_rng(seed)
    m = kg.num_triples
    idx = rng.permutation(m)[: min(eval_sample, m)]
    eval_triples = kg.triples[idx]
    out = {}
    for name in models:
        params, _ = registry.get_params("go", name, tag)
        model = make_model(name, kg.num_entities, kg.num_relations, dim=dim)
        metrics = rank_based_eval(model, {k: np.asarray(v) for k, v in params.items()},
                                  eval_triples, kg.triples, batch_size=64)
        out[name] = round(metrics["mrr"], 4)
    return out


def run(fast: bool = False, models=("transe", "distmult")) -> dict:
    from repro.kge.train import TrainConfig
    from repro.ontology import GraphDelta
    from repro.ontology.synthetic import GO_SPEC, release_series

    n_terms = 300 if fast else 600
    steps_cold = 500 if fast else 800
    versions = 3 if fast else 4
    dim = 64
    warm_frac = 0.25
    eval_sample = 120 if fast else 250
    cfg = TrainConfig(batch_size=256, num_negs=16, lr=1e-2)

    series = release_series(GO_SPEC, versions, seed=0, n_terms=n_terms, **CALM)
    churns = [GraphDelta.compute(a, b).churn_fraction
              for (_, a), (_, b) in zip(series, series[1:])]
    assert max(churns) <= 0.10, f"series churn {churns} exceeds the <=10% contract"

    report = {
        "n_terms": n_terms, "versions": versions, "models": list(models),
        "dim": dim, "steps_cold": steps_cold, "warm_frac": warm_frac,
        "churn_fractions": [round(c, 4) for c in churns],
        "mrr_tolerance": f"warm >= cold - max({MRR_TOL_ABS}, {MRR_TOL_REL}*cold)",
    }

    print(f"  [update] cold pipeline: full retrain every release "
          f"({steps_cold} steps/model)")
    cold_rows, cold_reg, cold_td = _run_pipeline(
        series, models, dim, cfg, steps_cold,
        churn_threshold=0.0, warm_frac=warm_frac)
    print(f"  [update] warm pipeline: delta policy + warm-start "
          f"({warm_frac:.0%} budget)")
    warm_rows, warm_reg, warm_td = _run_pipeline(
        series, models, dim, cfg, steps_cold,
        churn_threshold=0.25, warm_frac=warm_frac, engine_check=True)

    for label, rows in (("cold", cold_rows), ("warm", warm_rows)):
        for r in rows:
            print(f"    {label} {r['version']} mode={r['mode']:11s} "
                  f"wall={r['wall_s']:.2f}s churn={r['churn_fraction']}")
    assert all(r["mode"] == "full" for r in cold_rows)
    assert all(r["mode"] == "incremental" for r in warm_rows[1:]), \
        "low-churn mid-series releases must take the incremental path"

    cold_mid = float(np.mean([r["wall_s"] for r in cold_rows[1:]]))
    warm_mid = float(np.mean([r["wall_s"] for r in warm_rows[1:]]))
    speedup = cold_mid / max(warm_mid, 1e-9)
    floor = FAST_FLOOR if fast else FLOOR
    report.update({
        "cold": cold_rows, "warm": warm_rows,
        "cold_mid_series_mean_s": round(cold_mid, 3),
        "warm_mid_series_mean_s": round(warm_mid, 3),
        "speedup_warm_vs_cold": round(speedup, 2),
        "floor": floor,
    })
    print(f"  [update] mid-series wall: cold {cold_mid:.2f}s vs warm "
          f"{warm_mid:.2f}s -> {speedup:.2f}x")

    quality = {}
    cold_mrr = _final_quality(series, cold_reg, models, dim, eval_sample)
    warm_mrr = _final_quality(series, warm_reg, models, dim, eval_sample)
    for name in models:
        tol = max(MRR_TOL_ABS, MRR_TOL_REL * cold_mrr[name])
        ok = warm_mrr[name] >= cold_mrr[name] - tol
        quality[name] = {"cold_mrr": cold_mrr[name], "warm_mrr": warm_mrr[name],
                         "tolerance": round(tol, 4), "parity": bool(ok)}
        print(f"  [update] {name}: cold MRR {cold_mrr[name]:.4f} vs warm "
              f"{warm_mrr[name]:.4f} (tol {tol:.4f}) "
              f"{'OK' if ok else 'FAIL'}")
    report["quality"] = quality
    report["pass"] = bool(speedup >= floor
                          and all(q["parity"] for q in quality.values()))
    cold_td.cleanup()
    warm_td.cleanup()
    return report


def floor_speedup(report: dict) -> float:
    return report.get("speedup_warm_vs_cold", 0.0)


def quality_parity(report: dict) -> bool:
    return all(q.get("parity") for q in report.get("quality", {}).values())


def section_key(fast: bool) -> str:
    """Fast (CI-sized) runs record under their own key so they never
    overwrite a full-sized trajectory with smaller-n numbers."""
    return "update_fast" if fast else "update"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_update.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized series (300 terms, 3 versions)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    rep = run(fast=args.fast)
    out = write_results({section_key(args.fast): rep})
    print(f"[bench_update] wrote {out} ({time.perf_counter() - t0:.0f}s)")

    s = floor_speedup(rep)
    ok = rep["pass"]
    print(f"[bench_update] {'PASS' if ok else 'FAIL'}: warm update "
          f"{s:.2f}x cold (floor {rep['floor']}x), quality parity "
          f"{quality_parity(rep)}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
