"""Gateway benchmark: batched gateway vs direct per-call ServingEngine
under concurrent clients, plus the async front end vs threaded tickets.

Three modes over the same top-k workload (16 concurrent clients by
default):

  * engine-direct    — each client thread calls
    ``engine.closest_concepts`` per request: the pre-gateway serving
    mode, one private kernel launch per call (the deprecated delegates
    drive a submit + synchronous flush — no cross-client coalescing
    beyond accidental flush races);
  * gateway-batched  — one shared ``Gateway`` with the flush loop
    running; clients block on their tickets while the loop drains
    coalesced micro-batches;
  * gateway-async    — ``AsyncGateway`` over the same batched gateway
    design: the same client count as coroutines on one event loop,
    awaiting the loop-safe ticket bridge.

Emits ``benchmarks/results/BENCH_gateway.json``.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_gateway [--fast]

Acceptance floor (PR 4): batched gateway >= 2x engine-direct q/s at 16
clients, async within 10% of the threaded-ticket gateway throughput.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

RESULTS = REPO / "benchmarks" / "results"
FLOOR = 2.0          # batched gateway vs engine-direct, 16 clients
ASYNC_RATIO = 0.9    # async q/s >= 0.9x threaded gateway q/s


def _percentiles(lat_s):
    lat_ms = np.asarray(lat_s) * 1e3
    return (round(float(np.percentile(lat_ms, 50)), 3),
            round(float(np.percentile(lat_ms, 99)), 3))


def run(fast: bool = False, clients: int = 16, max_batch: int = 64,
        flush_after_ms: float = 2.0,
        total_requests: int | None = None) -> dict:
    from repro.api import AsyncGateway, Gateway
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest

    n = 2_000 if fast else 20_000          # paper: GO > 40k classes
    d, k = 200, 10
    total = total_requests or (512 if fast else 2_048)
    per_client = total // clients
    total = per_client * clients
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        ids = [f"GO:{i:07d}" for i in range(n)]
        labels = [f"synthetic term {i}" for i in range(n)]
        emb = rng.standard_normal((n, d)).astype(np.float32)
        registry.publish("go", "2025-01", "transe", ids, labels, emb,
                         ontology_checksum="bench", hyperparameters={"dim": d})
        engine = ServingEngine(registry)

        # jit-warm every power-of-two bucket shape any mode can hit
        warm = BatchScheduler(engine, max_batch=max_batch)
        b = 1
        while b <= max_batch:
            for _ in range(b):
                warm.submit(TopKRequest("go", "transe",
                                        ids[int(rng.integers(n))], k))
            warm.flush()
            b <<= 1

        out = {"n_classes": n, "dim": d, "k": k, "clients": clients,
               "max_batch": max_batch, "flush_after_ms": flush_after_ms,
               "total_requests": total, "modes": []}

        def fanout(worker):
            """Run ``clients`` threads of ``worker(client_idx)``; returns
            (wall_s, per-request latencies)."""
            lat, lock = [], threading.Lock()
            barrier = threading.Barrier(clients + 1)

            def client(cix):
                r = np.random.default_rng(100 + cix)
                barrier.wait()
                mine = worker(cix, r)
                with lock:
                    lat.extend(mine)

            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(clients)]
            for t in threads:
                t.start()
            barrier.wait()
            t0 = time.perf_counter()
            for t in threads:
                t.join()
            return time.perf_counter() - t0, lat

        # ---- mode 1: direct per-call ServingEngine -------------------- #
        def direct_worker(cix, r):
            mine = []
            for _ in range(per_client):
                q = ids[int(r.integers(n))]
                t1 = time.perf_counter()
                engine.closest_concepts("go", "transe", q, k=k)
                mine.append(time.perf_counter() - t1)
            return mine

        wall, lat = fanout(direct_worker)
        direct_qps = round(total / wall, 1)
        p50, p99 = _percentiles(lat)
        out["modes"].append({"mode": "engine-direct", "clients": clients,
                             "qps": direct_qps, "p50_ms": p50, "p99_ms": p99,
                             "wall_s": round(wall, 3)})
        print(f"  gateway[direct ] {clients:2d} clients x "
              f"{per_client} calls: {direct_qps:>9,.0f} q/s  "
              f"p50={p50:.3f}ms p99={p99:.3f}ms")

        # ---- mode 2: batched gateway (threads + flush loop) ----------- #
        # modes 2/3 feed the tight async-vs-threaded ratio, so take the
        # best of two passes each (run.py's _time does the same): one bad
        # descheduling on the 2-core box otherwise dominates the metric
        gw = Gateway(engine, max_batch=max_batch,
                     flush_after_ms=flush_after_ms)

        def gateway_worker(cix, r):
            mine = []
            for _ in range(per_client):
                q = ids[int(r.integers(n))]
                t1 = time.perf_counter()
                gw.closest_concepts("go", "transe", q, k=k)
                mine.append(time.perf_counter() - t1)
            return mine

        wall, lat = min(
            (fanout(gateway_worker) for _ in range(2)), key=lambda x: x[0])
        sched_stats = dict(gw.scheduler.stats)
        gw_qps = round(total / wall, 1)
        p50, p99 = _percentiles(lat)
        row = {"mode": "gateway-batched", "clients": clients, "qps": gw_qps,
               "p50_ms": p50, "p99_ms": p99, "wall_s": round(wall, 3),
               "speedup_vs_direct": round(gw_qps / direct_qps, 2),
               "batches": sched_stats["batches"],
               "full_flushes": sched_stats["full_flushes"],
               "deadline_flushes": sched_stats["deadline_flushes"]}
        out["modes"].append(row)
        print(f"  gateway[batched] {clients:2d} clients x "
              f"{per_client} calls: {gw_qps:>9,.0f} q/s "
              f"({row['speedup_vs_direct']:.2f}x direct)  "
              f"p50={p50:.3f}ms p99={p99:.3f}ms  "
              f"({row['batches']} batches)")

        # ---- mode 3: async front end over the same gateway ------------ #
        ag = AsyncGateway(gw, flush_after_ms=flush_after_ms)

        async def async_client(cix):
            r = np.random.default_rng(500 + cix)
            mine = []
            for _ in range(per_client):
                q = ids[int(r.integers(n))]
                t1 = time.perf_counter()
                await ag.closest_concepts("go", "transe", q, k=k)
                mine.append(time.perf_counter() - t1)
            return mine

        async def async_main():
            return await asyncio.gather(
                *(async_client(i) for i in range(clients)))

        wall, lat = float("inf"), []
        for _ in range(2):
            t0 = time.perf_counter()
            per_client_lat = asyncio.run(async_main())
            w = time.perf_counter() - t0
            if w < wall:
                wall = w
                lat = [x for mine in per_client_lat for x in mine]
        async_qps = round(total / wall, 1)
        p50, p99 = _percentiles(lat)
        row = {"mode": "gateway-async", "clients": clients, "qps": async_qps,
               "p50_ms": p50, "p99_ms": p99, "wall_s": round(wall, 3),
               "speedup_vs_direct": round(async_qps / direct_qps, 2),
               "vs_threaded_gateway": round(async_qps / gw_qps, 2)}
        out["modes"].append(row)
        print(f"  gateway[async  ] {clients:2d} clients x "
              f"{per_client} calls: {async_qps:>9,.0f} q/s "
              f"({row['vs_threaded_gateway']:.2f}x threaded gateway)  "
              f"p50={p50:.3f}ms p99={p99:.3f}ms")

        gw.close()
        assert gw.scheduler.stats["resolved"] == gw.scheduler.stats["submitted"]

        out["speedup_batched_vs_direct"] = round(gw_qps / direct_qps, 2)
        out["async_vs_threaded"] = round(async_qps / gw_qps, 2)
        out["floor"] = FLOOR
        out["async_ratio_floor"] = ASYNC_RATIO
        out["pass"] = bool(out["speedup_batched_vs_direct"] >= FLOOR
                           and out["async_vs_threaded"] >= ASYNC_RATIO)
        return out


def floor_speedup(report: dict) -> float:
    """The floor metric: batched-gateway speedup over direct per-call
    ServingEngine at the benchmark's client count."""
    return report.get("speedup_batched_vs_direct", 0.0)


def async_ratio(report: dict) -> float:
    return report.get("async_vs_threaded", 0.0)


def section_key(fast: bool) -> str:
    """Fast (CI-sized) runs record under their own key so they never
    overwrite a full-sized trajectory with smaller-n numbers."""
    return "gateway_fast" if fast else "gateway"


def write_results(report: dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_gateway.json"
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except json.JSONDecodeError:
            merged = {}
    merged.update(report)
    out.write_text(json.dumps(merged, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized table (2k classes instead of 20k)")
    ap.add_argument("--clients", type=int, default=16)
    args = ap.parse_args()

    rep = run(fast=args.fast, clients=args.clients)
    out = write_results({section_key(args.fast): rep})
    print(f"[bench_gateway] wrote {out}")

    s = floor_speedup(rep)
    a = async_ratio(rep)
    status = "PASS" if rep["pass"] else "FAIL"
    print(f"[bench_gateway] {status}: batched gateway = {s:.2f}x direct "
          f"per-call ServingEngine at {rep['clients']} clients "
          f"(floor {FLOOR}x); async = {a:.2f}x threaded gateway "
          f"(floor {ASYNC_RATIO}x)")
    if not rep["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
