"""Device-sharded top-k vs the single-device ref oracle.

The sharded path (table P("data", None) across devices, per-shard local
top-k through the existing kernel contract, global candidate merge) must
return the same (scores, indices, valid) as the unsharded oracle over the
parity grid — including k > N, k == N, exclusion of the last valid row,
and tables whose row count doesn't divide the shard count (zero-pad +
post-top-k masking). A dropped shard offset, a pad row leaking into the
candidates, or an exclusion applied in the wrong shard all fail it — and
all of those pass trivially on one device, so this runs in a subprocess
with 4 forced host devices.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels import ops, ref

    mesh = jax.make_mesh((4,), ("data",))
    assert ops.mesh_data_shards(mesh) == 4
    rng = np.random.default_rng(0)

    def unit(n, d):
        x = rng.standard_normal((n, d)).astype(np.float32)
        return x / np.linalg.norm(x, axis=1, keepdims=True)

    # (Q, N, d, k): k > N, k == N, ragged N (pad path), block-multiple N
    GRID = [(1, 7, 8, 10), (2, 3, 4, 9), (1, 16, 8, 16), (3, 101, 16, 10),
            (4, 64, 32, 5), (2, 130, 200, 10)]
    # pallas-in-shard_map runs interpret mode on CPU (slow): a subset with
    # every edge class keeps the subprocess inside the fast-tier budget
    PALLAS_GRID = [(2, 3, 4, 9), (3, 101, 16, 10), (2, 130, 200, 10)]
    checked = 0
    for use_pallas in (False, True):
        for (Q, N, d, k) in (PALLAS_GRID if use_pallas else GRID):
            q, e = unit(Q, d), unit(N, d)
            # exclusion hits the LAST valid row on even queries
            excl = jnp.array([N - 1 if i % 2 == 0 else -1 for i in range(Q)],
                             jnp.int32)
            es, n_valid = ops.shard_table(e, mesh)
            assert es.shape[0] % 4 == 0 and n_valid == N
            s, i, v = ops.topk_cosine_sharded(
                jnp.asarray(q), es, k, exclude_rows=excl, mesh=mesh,
                n_valid=n_valid, use_pallas=use_pallas)
            sr, ir, vr = ref.topk_cosine_ref(jnp.asarray(q), jnp.asarray(e),
                                             k, exclude_rows=excl)
            s, i, v = np.asarray(s), np.asarray(i), np.asarray(v)
            sr, ir, vr = np.asarray(sr), np.asarray(ir), np.asarray(vr)
            assert (v == vr).all(), (use_pallas, Q, N, d, k, v, vr)
            assert s.shape == sr.shape == (Q, min(k, N))
            for r in range(Q):
                np.testing.assert_allclose(s[r, :v[r]], sr[r, :v[r]],
                                           rtol=1e-5, atol=1e-5)
                np.testing.assert_array_equal(i[r, :v[r]], ir[r, :v[r]])
                assert (s[r, v[r]:] < -1e29).all()       # sentinel tail
                assert (i[r, :v[r]] < N).all()           # no pad row leaks
                if r % 2 == 0:
                    assert N - 1 not in i[r, :v[r]]      # exclusion held
            checked += 1

    # raw table + folded norms (PR 8): shard_table_raw ships raw rows and
    # per-row norms; in-kernel normalization must match the oracle over a
    # host-normalized copy exactly (the kernel performs the same float32
    # division), across the same edge grid and both backends
    raw_checked = 0
    for use_pallas in (False, True):
        for (Q, N, d, k) in (PALLAS_GRID if use_pallas else GRID):
            q = unit(Q, d)
            raw = (rng.standard_normal((N, d)) * 3.0).astype(np.float32)
            nrm = np.linalg.norm(raw, axis=1).astype(np.float32)
            excl = jnp.array([N - 1 if i % 2 == 0 else -1 for i in range(Q)],
                             jnp.int32)
            es, ns, n_valid = ops.shard_table_raw(raw, nrm, mesh)
            assert es.shape[0] % 4 == 0 and n_valid == N
            s, i, v = ops.topk_cosine_sharded(
                jnp.asarray(q), es, k, exclude_rows=excl, mesh=mesh,
                n_valid=n_valid, use_pallas=use_pallas, norms=ns)
            unit_t = raw / np.maximum(nrm[:, None], 1e-12)
            sr, ir, vr = ref.topk_cosine_ref(jnp.asarray(q),
                                             jnp.asarray(unit_t), k,
                                             exclude_rows=excl)
            s, i, v = np.asarray(s), np.asarray(i), np.asarray(v)
            sr, ir, vr = np.asarray(sr), np.asarray(ir), np.asarray(vr)
            assert (v == vr).all(), (use_pallas, Q, N, d, k, v, vr)
            for r in range(Q):
                np.testing.assert_allclose(s[r, :v[r]], sr[r, :v[r]],
                                           rtol=1e-5, atol=1e-5)
                np.testing.assert_array_equal(i[r, :v[r]], ir[r, :v[r]])
                assert (i[r, :v[r]] < N).all()
            raw_checked += 1

    # end-to-end: a sharded ServingEngine serves the same answers
    import tempfile
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine
    reg = EmbeddingRegistry(tempfile.mkdtemp())
    ids = [f"GO:{i:07d}" for i in range(33)]
    reg.publish("go", "v1", "transe", ids, [f"t {i}" for i in range(33)],
                rng.standard_normal((33, 12)).astype(np.float32),
                ontology_checksum="x", hyperparameters={"dim": 12})
    sharded = ServingEngine(reg, mesh=mesh)
    solo = ServingEngine(reg)
    for query, k in ((ids[5], 40), (ids[0], 10), (ids[32], 1)):
        a = sharded.closest_concepts("go", "transe", query, k=k)
        b = solo.closest_concepts("go", "transe", query, k=k)
        assert [(c.identifier, round(c.score, 5)) for c in a] == \\
               [(c.identifier, round(c.score, 5)) for c in b]
    print(json.dumps({"devices": jax.device_count(), "checked": checked,
                      "raw_checked": raw_checked}))
""")


def test_sharded_topk_matches_ref_on_4_devices():
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("JAX_PLATFORMS", None)          # subprocess sets its own flags
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    report = json.loads(out.stdout.strip().splitlines()[-1])
    assert report["devices"] == 4
    assert report["checked"] == 9           # 6 ref + 3 pallas grid points
    assert report["raw_checked"] == 9       # same grid, raw table + norms
