"""MoE block: routing correctness, capacity behavior, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import blocks
from repro.models.config import ArchConfig, MoEConfig

# LM-zoo/trainer tests: tier-2 only (run with plain `pytest`)
pytestmark = pytest.mark.slow


def _cfg(E=4, k=2, cf=8.0, d=32, ff=64):
    return ArchConfig(arch_id="moe-t", family="moe", n_layers=1, d_model=d,
                      n_heads=4, n_kv_heads=2, d_ff=ff, vocab=64,
                      dtype="float32",
                      moe=MoEConfig(n_experts=E, top_k=k, capacity_factor=cf))


def _dense_reference(p, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    w = w / w.sum(-1, keepdims=True)
    # compute all experts for all tokens (reference only)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["gate"]))
    u = jnp.einsum("td,edf->tef", xf, p["up"])
    o = jnp.einsum("tef,efd->ted", g * u, p["down"])      # (T,E,d)
    sel = jnp.take_along_axis(o, idx[..., None], axis=1)  # (T,k,d)
    y = (sel * w[..., None]).sum(1)
    return y.reshape(B, S, d)


def test_moe_matches_dense_reference_with_big_capacity():
    cfg = _cfg(cf=8.0)   # capacity >> tokens => nothing dropped
    p = blocks.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    y, aux = blocks.moe_apply(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_are_partial_not_nan():
    cfg = _cfg(cf=0.25)  # brutally small capacity => most slots dropped
    p = blocks.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    y, aux = blocks.moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # dropped tokens contribute zero -> output norm smaller than reference
    ref = _dense_reference(p, x, cfg)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(ref))


def test_moe_aux_loss_penalizes_imbalance():
    cfg = _cfg(E=4, k=1)
    p = blocks.moe_init(jax.random.key(0), cfg)
    # force all tokens to expert 0
    p = dict(p)
    router = np.zeros((cfg.d_model, 4), np.float32)
    router[:, 0] = 10.0 / cfg.d_model
    p["router"] = jnp.asarray(router)
    x = jnp.abs(jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model)))
    _, aux_skew = blocks.moe_apply(p, x, cfg)
    # uniform router
    p["router"] = jnp.zeros_like(p["router"])
    _, aux_unif = blocks.moe_apply(p, x, cfg)
    assert float(aux_skew) > float(aux_unif)
    assert abs(float(aux_unif) - 1.0) < 0.2   # balanced => ~1


def test_moe_grad_flows_to_router_and_experts():
    cfg = _cfg()
    p = blocks.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model), jnp.float32)

    def loss(p):
        y, aux = blocks.moe_apply(p, x, cfg)
        return jnp.sum(y ** 2) + aux
    g = jax.grad(loss)(p)
    for name in ("router", "gate", "up", "down"):
        assert float(jnp.abs(g[name]).max()) > 0, name
