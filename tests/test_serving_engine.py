"""ServingEngine + BatchScheduler end-to-end: version pinning, atomic
invalidation during an in-flight batch, LRU eviction stats, monotonic
ticket IDs, and the no-sentinel guarantee for any k.

Snapshots are published directly (no training) so these stay fast.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.serving import (BatchScheduler, EmbeddingIndex, LRUIndexCache,
                                ServingEngine, TopKRequest, _bucket_size)

N, D = 40, 12


def _publish(registry, ontology, version, model="transe", n=N, seed=0):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, D)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}-{seed}",
                     hyperparameters={"dim": D})
    return ids


@pytest.fixture()
def engine(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    _publish(registry, "go", "2024-02", seed=2)
    eng = ServingEngine(registry, cache_capacity=4)
    return eng, ids


# --------------------------- version pinning --------------------------- #
def test_version_pinned_endpoints(engine):
    eng, ids = engine
    assert eng.latest_version("go") == "2024-02"
    s_latest = eng.similarity("go", "transe", ids[0], ids[1])
    s_old = eng.similarity("go", "transe", ids[0], ids[1], version="2024-01")
    s_pin = eng.similarity("go", "transe", ids[0], ids[1], version="2024-02")
    assert s_latest == s_pin and s_latest != s_old

    top_old = eng.closest_concepts("go", "transe", ids[3], k=5,
                                   version="2024-01")
    top_new = eng.closest_concepts("go", "transe", ids[3], k=5)
    assert [c.identifier for c in top_old] != [c.identifier for c in top_new]

    # download honors the pin too
    assert eng.download("go", "transe", "2024-01") != eng.download("go", "transe")


def test_invalidate_is_atomic_pointer_swap(engine, registry):
    eng, ids = engine
    eng.similarity("go", "transe", ids[0], ids[1])      # build 2024-02 index
    _publish(registry, "go", "2024-03", seed=3)
    # not yet invalidated: the engine still serves its pinned latest
    assert eng.latest_version("go") == "2024-02"
    eng.invalidate("go", "2024-03")
    assert eng.latest_version("go") == "2024-03"
    # the old index is NOT wiped — pinned in-flight queries stay consistent
    assert ("go", "transe", "2024-02") in eng.cache
    s = eng.similarity("go", "transe", ids[0], ids[1], version="2024-02")
    assert isinstance(s, float)


def test_invalidation_during_flight(engine, registry):
    """Requests submitted before an update must be answered from the version
    that was latest at submit time, even if the update lands pre-flush."""
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=8)
    tickets = [sched.submit(TopKRequest("go", "transe", q, 5))
               for q in ids[:6]]
    expected = [eng.closest_concepts("go", "transe", q, k=5,
                                     version="2024-02") for q in ids[:6]]
    # update lands while the batch is in flight
    _publish(registry, "go", "2024-03", seed=3)
    eng.invalidate("go", "2024-03")
    results = sched.flush()
    for t, exp in zip(tickets, expected):
        assert [c.identifier for c in results[t]] == [c.identifier for c in exp]
    # a fresh submit sees the new version
    t_new = sched.submit(TopKRequest("go", "transe", ids[0], 5))
    got = sched.flush()[t_new]
    exp_new = eng.closest_concepts("go", "transe", ids[0], k=5,
                                   version="2024-03")
    assert [c.identifier for c in got] == [c.identifier for c in exp_new]


# ------------------------------ LRU cache ------------------------------ #
def test_lru_eviction_and_stats(registry):
    for v in ("v1", "v2", "v3"):
        _publish(registry, "go", v, seed=hash(v) % 100)
    eng = ServingEngine(registry, cache_capacity=2)
    ids = [f"GO:{i:07d}" for i in range(N)]
    eng.similarity("go", "transe", ids[0], ids[1], version="v1")
    eng.similarity("go", "transe", ids[0], ids[1], version="v2")
    # a *distinct* pair on v2: the gateway's result cache would answer a
    # repeat of the identical request without touching the index — this
    # test is about the index LRU, so the second v2 read must miss there
    eng.similarity("go", "transe", ids[0], ids[2], version="v2")   # hit
    eng.similarity("go", "transe", ids[0], ids[1], version="v3")   # evicts v1
    stats = eng.cache_stats()
    assert stats["size"] == 2 and stats["capacity"] == 2
    assert stats["hits"] == 1 and stats["misses"] == 3
    assert stats["evictions"] == 1
    assert ("go", "transe", "v1") not in eng.cache
    # re-touching the evicted version rebuilds it (miss + eviction again);
    # again a fresh pair, so the result cache can't answer it
    eng.similarity("go", "transe", ids[0], ids[3], version="v1")
    assert eng.cache_stats()["evictions"] == 2
    assert eng.cache_stats()["bytes"] > 0


def test_lru_cache_unit():
    cache = LRUIndexCache(capacity=2)
    mk = lambda seed: EmbeddingIndex(
        ["a", "b"], ["la", "lb"],
        np.random.default_rng(seed).standard_normal((2, 4)))
    cache.put(("o", "m", "v1"), mk(1))
    cache.put(("o", "m", "v2"), mk(2))
    assert cache.get(("o", "m", "v1")) is not None     # v1 now most recent
    cache.put(("o", "m", "v3"), mk(3))                 # evicts v2 (LRU)
    assert cache.get(("o", "m", "v2")) is None
    assert cache.get(("o", "m", "v1")) is not None
    assert cache.stats()["evictions"] == 1
    with pytest.raises(ValueError):
        LRUIndexCache(capacity=0)


# ------------------------------ scheduler ------------------------------ #
def test_ticket_ids_monotonic_across_flushes(engine):
    """The seed's RequestBatcher reset tickets to 0 every flush — a ticket
    held across a flush collided with the next batch's first request."""
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=4)
    seen = []
    for round_ in range(3):
        tickets = [sched.submit(TopKRequest("go", "transe", q, 3))
                   for q in ids[:5]]
        res = sched.flush()
        assert set(res) == set(tickets)
        seen.extend(tickets)
    assert seen == sorted(seen) and len(set(seen)) == len(seen)


def test_scheduler_padding_buckets(engine):
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=16)
    for q in ids[:5]:                                   # 5 -> bucket 8
        sched.submit(TopKRequest("go", "transe", q, 3))
    res = sched.flush()
    assert len(res) == 5
    assert sched.stats["batches"] == 1
    assert sched.stats["padded_queries"] == 3
    # padded results must not leak into the response set
    assert sorted(res) == list(range(5))
    assert _bucket_size(1, 64) == 1 and _bucket_size(5, 64) == 8
    assert _bucket_size(65, 64) == 64 and _bucket_size(33, 64) == 64


def test_scheduler_unknown_query_fails_only_its_ticket(engine):
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=8)
    t_ok = sched.submit(TopKRequest("go", "transe", ids[0], 3))
    t_bad = sched.submit(TopKRequest("go", "transe", "GO:9999999", 3))
    res = sched.flush()
    assert t_ok in res and len(res[t_ok]) == 3
    assert t_bad not in res and t_bad in sched.errors
    assert sched.stats["failed"] == 1


def test_scheduler_broken_queue_fails_only_its_tickets(engine):
    """A queue that can't build its index (unpublished model / bad version)
    or can't execute (k < 1) must not poison other queues in the flush."""
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=8)
    t_ok = sched.submit(TopKRequest("go", "transe", ids[0], 3))
    t_nomodel = sched.submit(TopKRequest("go", "no-such-model", ids[0], 3))
    t_badver = sched.submit(TopKRequest("go", "transe", ids[0], 3,
                                        version="1999-01"))
    t_badk = sched.submit(TopKRequest("go", "transe", ids[1], 0))
    res = sched.flush()
    assert t_ok in res and len(res[t_ok]) == 3
    for t in (t_nomodel, t_badver, t_badk):
        assert t not in res and t in sched.errors
    assert sched.stats["failed"] == 3


def test_scheduler_unknown_ontology_fails_ticket_not_accept_loop(engine):
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=8)
    t_bad = sched.submit(TopKRequest("no-such-ontology", "transe", ids[0], 3))
    t_ok = sched.submit(TopKRequest("go", "transe", ids[0], 3))
    assert t_bad in sched.errors                       # failed at submit
    res = sched.flush()
    assert t_ok in res and t_bad not in res


def test_scheduler_errors_are_bounded(engine):
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=8, max_errors=4)
    tickets = [sched.submit(TopKRequest("go", "transe", f"BOGUS-{i}", 3))
               for i in range(7)]
    sched.flush()
    assert len(sched.errors) == 4                      # oldest dropped
    assert all(t in sched.errors for t in tickets[-4:])
    assert sched.stats["failed"] == 7                  # counter still exact


def test_scheduler_respects_exact_max_batch_cap(engine):
    """max_batch is a hard cap on kernel batch size: buckets stay powers of
    two below it, and a non-power-of-two cap is honored, not rounded up."""
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=12)
    assert sched.max_batch == 12
    for i in range(30):                        # 12 + 12 + 6->bucket 8
        sched.submit(TopKRequest("go", "transe", ids[i % len(ids)], 3))
    res = sched.flush()
    assert len(res) == 30
    assert sched.stats["batches"] == 3
    assert sched.stats["padded_queries"] == 2  # only the tail pads, to 8
    assert _bucket_size(10, 12) == 12          # capped at the exact max


def test_scheduler_groups_by_version_and_k(engine):
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=32)
    sched.submit(TopKRequest("go", "transe", ids[0], 3))
    sched.submit(TopKRequest("go", "transe", ids[1], 3, version="2024-01"))
    sched.submit(TopKRequest("go", "transe", ids[2], 7))
    res = sched.flush()
    assert sched.stats["batches"] == 3                  # three distinct keys
    assert len(res) == 3


# ------------------------ no-sentinel guarantee ------------------------ #
@settings(max_examples=12, deadline=None)
@given(k=st.integers(1, 3 * N), n=st.integers(2, 25), seed=st.integers(0, 99))
def test_closest_concepts_never_returns_sentinels(tmp_path_factory, k, n, seed):
    """For ANY k >= 1 — including k far beyond the table size — results
    contain only real entities, unique, self-excluded, score-sorted."""
    from repro.core.registry import EmbeddingRegistry
    registry = EmbeddingRegistry(tmp_path_factory.mktemp("reg"))
    ids = _publish(registry, "hp", "v1", n=n, seed=seed)
    eng = ServingEngine(registry)
    res = eng.closest_concepts("hp", "transe", ids[0], k=k)
    assert len(res) == min(k, n - 1)                    # self excluded
    got = [c.identifier for c in res]
    assert len(set(got)) == len(got)
    assert ids[0] not in got
    assert all(g in set(ids) for g in got)
    scores = [c.score for c in res]
    assert scores == sorted(scores, reverse=True)
    assert all(-1.001 <= s <= 1.001 for s in scores)    # real cosine, no -1e30


# ---------------- autocomplete: bisect range lookup -------------------- #
def _naive_autocomplete(idx, prefix, limit):
    from repro.core.serving import _norm_label
    p = _norm_label(prefix)
    hits = [lbl for lbl in idx._sorted_labels if lbl.startswith(p)][:limit]
    return [idx.labels[idx._label_to_row[lbl]] for lbl in hits]


def test_autocomplete_bisect_matches_naive_scan():
    """The O(log n) bisect range must return exactly what a full
    startswith scan returns — including unicode edges, case/whitespace
    normalization collisions, and prefixes at the codepoint maximum."""
    labels = ["Apoptosis", "apoptotic process", "  Apoptotic   Signaling ",
              "ápoptosis", "zz\U0010FFFF", "zz\U0010FFFFa", "zz",
              "Zz top", "ZZ", "heart development", "heart", "hear",
              "héart", "\U0010FFFF\U0010FFFF", "a", "A b", "ab", "a c"]
    rng = np.random.default_rng(0)
    # plus bulk labels with heavy shared prefixes
    labels += ["".join(rng.choice(list("abc "), size=rng.integers(1, 7)))
               for _ in range(150)]
    ids = [f"X:{i:05d}" for i in range(len(labels))]
    emb = rng.standard_normal((len(labels), 6)).astype(np.float32)
    idx = EmbeddingIndex(ids, labels, emb)

    prefixes = ["", " ", "a", "A", "ap", "Apop", "apoptotic p", "z", "zz",
                "zz\U0010FFFF", "\U0010FFFF", "h", "he", "hea", "heart",
                "heart ", "b", "ba", "c", "ab", "a ", "nope", "é",
                "á", "aa", "ca", "cb", "ac"]
    prefixes += [lbl[:j] for lbl in labels[:30] for j in (1, 2, 3)]
    for p in prefixes:
        for limit in (1, 3, 10, 10_000):
            assert idx.autocomplete(p, limit) == _naive_autocomplete(
                idx, p, limit), (p, limit)


def test_prefix_upper_bound_edges():
    from repro.core.serving import _prefix_upper_bound
    assert _prefix_upper_bound("") is None
    assert _prefix_upper_bound("\U0010FFFF") is None
    assert _prefix_upper_bound("a") == "b"
    assert _prefix_upper_bound("az") == "a{"
    # last char at the max: bump the previous one and truncate
    assert _prefix_upper_bound("a\U0010FFFF") == "b"


# -------------- warm-build before the latest-pointer swap -------------- #
def test_invalidate_warm_builds_new_version_before_swap(engine, registry):
    """The new version's index must be cache-resident BEFORE the latest
    pointer moves, so the first post-publish query never pays the build."""
    eng, ids = engine
    eng.similarity("go", "transe", ids[0], ids[1])      # cache 2024-02
    _publish(registry, "go", "2024-03", seed=3)

    calls = []
    orig = eng._index

    def spy(ontology, model, version=None):
        calls.append((ontology, model, version, eng.latest_version("go")))
        return orig(ontology, model, version)

    eng._index = spy
    try:
        eng.invalidate("go", "2024-03")
    finally:
        eng._index = orig
    # warm-built while the pointer still said 2024-02
    assert ("go", "transe", "2024-03", "2024-02") in calls
    assert ("go", "transe", "2024-03") in eng.cache
    # the first post-swap query is a pure cache hit
    before = eng.cache.stats()["hits"]
    eng.similarity("go", "transe", ids[0], ids[1])
    assert eng.cache.stats()["hits"] == before + 1
    assert eng.cache.stats()["misses"] == eng.cache.stats()["misses"]


def test_invalidate_warm_build_tolerates_missing_model(engine, registry):
    """A model absent from the new version must not break the swap."""
    eng, ids = engine
    eng.similarity("go", "transe", ids[0], ids[1])
    # 2024-03 exists but has no transe snapshot (different model name)
    rng = np.random.default_rng(9)
    emb = rng.standard_normal((N, D)).astype(np.float32)
    registry.publish("go", "2024-03", "distmult",
                     [f"GO:{i:07d}" for i in range(N)],
                     [f"go term {i}" for i in range(N)], emb,
                     ontology_checksum="ck-3", hyperparameters={"dim": D})
    eng.invalidate("go", "2024-03")
    assert eng.latest_version("go") == "2024-03"
    assert ("go", "transe", "2024-03") not in eng.cache
