"""Version-keyed result cache (PR 7 tentpole): LFU/LRU bounds, the
gateway hit path serving byte-identical responses on every cached
route, bool/int key canonicalisation, and the publish→invalidate edge
never serving stale bytes. Snapshots are published directly — fast
tier."""
import asyncio
import json

import numpy as np
import pytest

from repro.api import AsyncGateway, Gateway, ResultCache
from repro.api.gateway import CACHED_ROUTES
from repro.core.serving import ServingEngine

N, D = 40, 12


def _publish(registry, ontology, version, model="transe", n=N, seed=0):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, D)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}-{seed}",
                     hyperparameters={"dim": D})
    return ids


@pytest.fixture()
def pair(registry):
    """(cached gateway, cache-off gateway, engine, ids) over one store —
    the oracle setup: cache-on responses must be byte-identical to the
    cache-off gateway's."""
    ids = _publish(registry, "go", "2024-01", seed=1)
    engine = ServingEngine(registry, cache_capacity=4)
    gw_on = Gateway(engine)
    gw_off = Gateway(engine, result_cache_entries=0)
    yield gw_on, gw_off, engine, ids
    gw_off.close()
    gw_on.close()


# --------------------------- unit: ResultCache ------------------------- #
def test_entry_bound_evicts_and_counts():
    c = ResultCache(max_entries=4, max_bytes=1 << 20)
    for i in range(6):
        c.put(("r", "go", "m", "v", str(i)), i, nbytes=10)
    s = c.stats()
    assert s["entries"] == 4 and s["evictions"] == 2
    assert s["bytes"] == 40
    assert c.get(("r", "go", "m", "v", "5")) == 5


def test_byte_bound_evicts_independently_of_entry_bound():
    c = ResultCache(max_entries=100, max_bytes=100)
    for i in range(5):
        c.put(("r", "go", "m", "v", str(i)), i, nbytes=30)
    s = c.stats()
    assert s["bytes"] <= 100 and s["entries"] == 3
    assert s["evictions"] == 2


def test_lfu_window_keeps_hot_head_over_one_hit_wonders():
    """A scan of fresh keys must not flush a frequently-hit entry: the
    evictor prefers the least-*frequently*-used entry within its cold
    window."""
    c = ResultCache(max_entries=8, max_bytes=1 << 20)
    hot = ("r", "go", "m", "v", "hot")
    c.put(hot, "hot", nbytes=1)
    for _ in range(50):
        assert c.get(hot) == "hot"
    # hot is at the LRU cold end after these inserts, but its hit count
    # shields it inside the eviction window
    for i in range(8):
        c.put(("r", "go", "m", "v", f"scan{i}"), i, nbytes=1)
        c.get(hot)                      # stays warm the way real traffic is
    assert c.get(hot) == "hot"
    assert c.stats()["evictions"] >= 1


def test_oversize_entry_refused_not_cached():
    c = ResultCache(max_entries=8, max_bytes=100)
    assert c.put(("r", "go", "m", "v", "big"), "x", nbytes=101) is False
    assert len(c) == 0
    assert c.stats()["oversize_rejects"] == 1


def test_invalidate_ontology_drops_only_that_ontology():
    c = ResultCache(max_entries=8, max_bytes=1 << 20)
    c.put(("r", "go", "m", "v", "a"), 1, nbytes=1)
    c.put(("r", "hp", "m", "v", "b"), 2, nbytes=1)
    assert c.invalidate_ontology("go") == 1
    assert c.get(("r", "go", "m", "v", "a")) is None
    assert c.get(("r", "hp", "m", "v", "b")) == 2
    assert c.stats()["invalidations"] == 1


# ----------------------- gateway hit-path parity ----------------------- #
def test_cached_routes_byte_identical_to_cache_off(pair):
    """The acceptance criterion: for every cached route, a cache-on
    gateway's repeat response is byte-for-byte the cache-off gateway's
    response — same store, same wire codec."""
    gw_on, gw_off, engine, ids = pair
    cases = {
        "get-vector": ("/get-vector/go/transe", {"query": ids[3]}),
        "sim": ("/sim/go/transe", {"a": ids[0], "b": ids[1]}),
        "closest-concepts": ("/closest-concepts/go/transe",
                             {"query": ids[2], "k": 5}),
    }
    assert set(cases) == set(CACHED_ROUTES)
    for route, (path, payload) in cases.items():
        cold = json.dumps(gw_on.handle(path, dict(payload)))
        hot = json.dumps(gw_on.handle(path, dict(payload)))    # cache hit
        off = json.dumps(gw_off.handle(path, dict(payload)))
        assert cold == hot == off, route
    s = gw_on.result_cache.stats()
    assert s["hits"] == len(cases) and s["misses"] >= len(cases)


def test_hit_skips_scheduler_but_still_counts_request(pair):
    gw_on, _, engine, ids = pair
    gw_on.closest_concepts("go", "transe", ids[1], k=3)
    submitted = gw_on.scheduler.stats["submitted"]
    requests = gw_on.counters["requests"]
    lat = gw_on.latency["closest-concepts"].snapshot()["count"]
    gw_on.closest_concepts("go", "transe", ids[1], k=3)        # hit
    assert gw_on.scheduler.stats["submitted"] == submitted     # no submit
    assert gw_on.counters["requests"] == requests + 1          # still a req
    assert gw_on.latency["closest-concepts"].snapshot()["count"] == lat + 1


def test_bool_int_payloads_do_not_alias(pair):
    """``True == 1`` in Python: a raw-tuple cache key would serve the
    cached k=1 page for k=True, which the validator must 400. The
    canonical-JSON key keeps them distinct."""
    gw_on, _, engine, ids = pair
    ok = gw_on.handle("/closest-concepts/go/transe",
                      {"query": ids[0], "k": 1})
    assert ok["type"] == "closest_concepts_response"
    bad = gw_on.handle("/closest-concepts/go/transe",
                       {"query": ids[0], "k": True})
    assert bad["type"] == "error" and bad["code"] == "BAD_REQUEST"


def test_unpinned_and_pinned_to_latest_share_one_entry(pair):
    """version=None resolves to latest before keying, so the explicit
    pin of the same version is the same entry (identical bytes)."""
    gw_on, _, engine, ids = pair
    a = gw_on.handle("/sim/go/transe", {"a": ids[0], "b": ids[1]})
    b = gw_on.handle("/sim/go/transe", {"a": ids[0], "b": ids[1],
                                        "version": "2024-01"})
    assert json.dumps(a) == json.dumps(b)
    assert gw_on.result_cache.stats()["hits"] == 1


def test_publish_invalidate_edge_never_serves_stale_bytes(pair):
    """The tentpole's correctness clause: across a publish→invalidate, an
    unpinned request must serve the *new* version — and stay
    byte-identical to a cache-off gateway — while pinned reads of the
    old version stay correct (immutable snapshot)."""
    gw_on, gw_off, engine, ids = pair
    payload = {"query": ids[4], "k": 5}
    old = gw_on.handle("/closest-concepts/go/transe", dict(payload))
    assert old["version"] == "2024-01"
    _publish(engine.registry, "go", "2024-02", seed=7)
    engine.invalidate("go")
    fresh = gw_on.handle("/closest-concepts/go/transe", dict(payload))
    assert fresh["version"] == "2024-02"
    assert json.dumps(fresh) == json.dumps(
        gw_off.handle("/closest-concepts/go/transe", dict(payload)))
    # the old version remains servable via an explicit pin — and the
    # purge means this is a fresh miss, not a stale entry
    pinned = gw_on.handle("/closest-concepts/go/transe",
                          {**payload, "version": "2024-01"})
    assert json.dumps(pinned) == json.dumps(old | {"version": "2024-01"})
    assert gw_on.result_cache.stats()["invalidations"] >= 1


def test_closed_gateway_does_not_serve_cached_hits(pair):
    gw_on, _, engine, ids = pair
    gw_on.get_vector("go", "transe", ids[0])
    gw_on.close()
    wire = gw_on.handle("/get-vector/go/transe", {"query": ids[0]})
    assert wire["type"] == "error" and wire["code"] == "SHUTTING_DOWN"


def test_result_cache_stats_in_stats_route(pair):
    gw_on, gw_off, engine, ids = pair
    gw_on.get_vector("go", "transe", ids[0])
    gw_on.get_vector("go", "transe", ids[0])
    rc = gw_on.stats().gateway["result_cache"]
    assert rc["hits"] == 1 and rc["entries"] == 1
    assert "result_cache" not in gw_off.stats().gateway


def test_async_path_populates_and_serves_the_cache(pair):
    gw_on, gw_off, engine, ids = pair

    async def run():
        async with AsyncGateway(gw_on) as ag:
            first = await ag.closest_concepts("go", "transe", ids[6], k=4)
            submitted = gw_on.scheduler.stats["submitted"]
            second = await ag.closest_concepts("go", "transe", ids[6], k=4)
            assert gw_on.scheduler.stats["submitted"] == submitted
            return first, second

    first, second = asyncio.run(run())
    from repro.api import to_wire
    assert json.dumps(to_wire(first)) == json.dumps(to_wire(second)) \
        == json.dumps(gw_off.handle("/closest-concepts/go/transe",
                                    {"query": ids[6], "k": 4}))
