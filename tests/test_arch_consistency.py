"""Per-arch smoke + the strongest correctness check we have on CPU:

  prefill(tokens[:, :S])            last-position logits
        == prefill(tokens[:, :S-1]) then decode_step(token S-1)

This exercises the KV cache write/read path, RoPE at absolute positions,
rolling SWA buffers, mamba conv/ssm state carry and RG-LRU state carry —
any off-by-one in cache plumbing fails it. Run in float32 reduced configs
for tight tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, build, get_config

# LM-zoo/trainer tests: tier-2 only (run with plain `pytest`)
pytestmark = pytest.mark.slow

TOL = dict(rtol=2e-3, atol=2e-3)


def _f32(cfg):
    return cfg.with_(dtype="float32")


def _make_batch(model, cfg, B, S, seed=0):
    key = jax.random.key(seed)
    spec = model.batch_spec(B, S)
    batch = {}
    for k, v in spec.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(jax.random.fold_in(key, hash(k) % 100),
                                          v.shape, 0, cfg.vocab, jnp.int32)
        else:
            batch[k] = jax.random.normal(jax.random.fold_in(key, 3),
                                         v.shape, v.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """Reduced variant: one forward + one optimizer step, finite loss,
    params actually change."""
    from repro.models.steps import make_train_step
    cfg = _f32(get_config(arch, reduced=True))
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(model, cfg, B=2, S=32)
    step, optimizer = make_train_step(model, lr=1e-3)
    opt_state = optimizer.init(params)
    new_params, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # at least one leaf moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params))
    assert moved
    # output embedding table shape is the padded vocab
    assert params["embed"].shape[0] == cfg.padded_vocab


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = _f32(get_config(arch, reduced=True))
    if cfg.moe is not None:
        # capacity dropping is data-dependent (prefill-over-S and
        # prefill-over-(S-1)+decode route different token sets once slots
        # overflow), so the exact-equivalence claim needs no-drop capacity.
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build(cfg)
    params = model.init(jax.random.key(1))
    B = 2
    # long enough to wrap danube's reduced SWA window (64) and rg's (64)
    S = 80 if cfg.attention == "sliding_window" or cfg.family == "hybrid" else 48
    if cfg.family == "audio":
        S = 256  # decoder length = S//8 = 32 <= cap
    batch = _make_batch(model, cfg, B, S)

    full_logits, _ = jax.jit(model.prefill)(params, batch)

    # drop the last *text* token, prefill, then decode it
    tok_key = "tokens"
    toks = batch[tok_key]
    batch_m1 = dict(batch)
    batch_m1[tok_key] = toks[:, :-1]
    batch_m1["labels"] = batch["labels"][:, :-1]

    if cfg.family == "audio":
        pos = toks.shape[1] - 1
    elif cfg.family == "vlm":
        pos = batch["image_embeds"].shape[1] + toks.shape[1] - 1
    else:
        pos = S - 1
    _, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=pos + 1))(params, batch_m1)
    dec_logits, _ = jax.jit(model.decode_step)(
        params, cache, toks[:, -1:], jnp.asarray(pos, jnp.int32))

    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32), **TOL)


@pytest.mark.parametrize("arch", ["h2o_danube_1_8b", "recurrentgemma_2b",
                                  "falcon_mamba_7b"])
def test_long_context_decode_state_is_constant_size(arch):
    """The long_500k-capable archs must have O(1)-in-seq decode state."""
    cfg = get_config(arch, reduced=True)
    model = build(cfg)
    small = model.cache_spec(1, 1_000)
    big = model.cache_spec(1, 1_000_000)
    sizes = lambda t: sorted(np.prod(l.shape) for l in jax.tree.leaves(t))
    assert sizes(small) == sizes(big)
    assert model.supports_long_context()


@pytest.mark.parametrize("arch", ["qwen2_72b", "mistral_large_123b",
                                  "grok_1_314b", "llava_next_34b"])
def test_full_attention_archs_skip_long500k(arch):
    from repro.configs.shapes import SHAPES, applicable
    cfg = get_config(arch)
    assert not applicable(cfg, SHAPES["long_500k"])
    assert applicable(cfg, SHAPES["decode_32k"])


def test_published_dims_match_assignment():
    """The exact numbers from the assignment table."""
    expect = {
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "falcon_mamba_7b": (64, 4096, 0, 1, 0, 65024),
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
        "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
    }
    for arch, (L, d, H, kv, ff, V) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == (L, d, H, kv, ff, V), (arch, got)
    # extras
    assert get_config("olmoe_1b_7b").moe.n_experts == 64
    assert get_config("olmoe_1b_7b").moe.top_k == 8
    assert get_config("grok_1_314b").moe.n_experts == 8
    assert get_config("grok_1_314b").moe.top_k == 2
    assert get_config("falcon_mamba_7b").ssm.d_state == 16
    assert get_config("qwen2_72b").qkv_bias
    assert get_config("recurrentgemma_2b").hybrid.pattern == (
        "recurrent", "recurrent", "attention")


def test_param_counts_are_plausible():
    """n_params() should land near the published sizes."""
    approx = {
        "falcon_mamba_7b": 7.3e9,
        "mistral_large_123b": 123e9,
        "qwen2_72b": 72e9,
        "grok_1_314b": 314e9,
        "internlm2_20b": 20e9,
        "olmoe_1b_7b": 7e9,
        "recurrentgemma_2b": 2.7e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).n_params()
        assert 0.6 * target < n < 1.6 * target, (arch, n, target)
    # olmoe active ~1.3B
    a = get_config("olmoe_1b_7b").n_active_params()
    assert 0.8e9 < a < 2.0e9
