"""Mamba selective scan and RG-LRU vs naive sequential references, plus
prefill->decode state handoff."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks
from repro.models.config import ArchConfig, HybridConfig, SSMConfig
import pytest

# LM-zoo/trainer tests: tier-2 only (run with plain `pytest`)
pytestmark = pytest.mark.slow


def _ssm_cfg(d=32, st=4):
    return ArchConfig(arch_id="ssm-t", family="ssm", n_layers=1, d_model=d,
                      n_heads=0, n_kv_heads=1, d_ff=0, vocab=64,
                      dtype="float32", attention="none",
                      ssm=SSMConfig(d_state=st, d_conv=4, expand=2))


def _hyb_cfg(d=32):
    return ArchConfig(arch_id="hyb-t", family="hybrid", n_layers=3, d_model=d,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab=64,
                      dtype="float32", act="gelu",
                      hybrid=HybridConfig(lru_width=d, conv_width=4, window=8))


def test_mamba_chunked_scan_matches_stepwise_decode():
    """Prefill over S steps == decoding the same S tokens one at a time."""
    cfg = _ssm_cfg()
    p = blocks.mamba_init(jax.random.key(0), cfg)
    B, S = 2, 24
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)

    y_full, h_full, conv_full = blocks.mamba_prefill(p, x, cfg)

    d_in = cfg.ssm.expand * cfg.d_model
    h = jnp.zeros((B, d_in, cfg.ssm.d_state), jnp.float32)
    conv = jnp.zeros((B, cfg.ssm.d_conv - 1, d_in), jnp.float32)
    ys = []
    for t in range(S):
        y, h, conv = blocks.mamba_decode(p, x[:, t:t + 1], h, conv, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(conv), np.asarray(conv_full),
                               rtol=1e-5, atol=1e-5)


def test_mamba_apply_equals_prefill_output():
    cfg = _ssm_cfg()
    p = blocks.mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(2), (1, 40, cfg.d_model), jnp.float32)
    y1 = blocks.mamba_apply(p, x, cfg, chunk=8)
    y2, _, _ = blocks.mamba_prefill(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_mamba_chunk_size_invariance():
    cfg = _ssm_cfg()
    p = blocks.mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(3), (1, 33, cfg.d_model), jnp.float32)
    y8 = blocks.mamba_apply(p, x, cfg, chunk=8)
    y16 = blocks.mamba_apply(p, x, cfg, chunk=16)
    y33 = blocks.mamba_apply(p, x, cfg, chunk=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y33), rtol=1e-5, atol=1e-5)


def test_rglru_prefill_matches_stepwise_decode():
    cfg = _hyb_cfg()
    p = blocks.rglru_init(jax.random.key(0), cfg)
    B, S = 2, 20
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)

    y_full, h_full, conv_full = blocks.rglru_apply(p, x, cfg, return_state=True)

    w = cfg.hybrid.lru_width
    h = jnp.zeros((B, w), jnp.float32)
    conv = jnp.zeros((B, cfg.hybrid.conv_width - 1, w), jnp.float32)
    ys = []
    for t in range(S):
        y, h, conv = blocks.rglru_decode(p, x[:, t:t + 1], h, conv, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)


def test_rglru_state_decays():
    """RG-LRU is a contraction: |a| < 1 so state from old inputs decays."""
    cfg = _hyb_cfg()
    p = blocks.rglru_init(jax.random.key(0), cfg)
    x = jnp.zeros((1, 50, cfg.d_model), jnp.float32)
    h0 = 100.0 * jnp.ones((1, cfg.hybrid.lru_width), jnp.float32)
    _, h_end = blocks._rglru_scan(p, jnp.zeros((1, 50, cfg.hybrid.lru_width)),
                                  h0)
    assert float(jnp.abs(h_end).max()) < float(jnp.abs(h0).max())
