"""Infrastructure: roofline HLO parser, sharding specs, data pipeline,
optimizers, walks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from benchmarks.roofline import (collective_summary, model_flops,
                                 parse_collectives, roofline_terms)


HLO_SAMPLE = """
HloModule jit_step
fused_computation {
  p0 = bf16[8,128]{1,0} parameter(0)
  ROOT add = bf16[8,128]{1,0} add(p0, p0)
}
ENTRY main {
  %p = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[128,128]{1,0} all-gather(bf16[8,128]{1,0} %p), dimensions={0}
  %ar = f32[64]{0} all-reduce(f32[64]{0} %x), replica_groups={}
  %rs = bf16[8,64]{1,0} reduce-scatter(bf16[8,128]{1,0} %y), dimensions={1}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %z)
  %a2a = (f32[2,8]{1,0}, f32[2,8]{1,0}) all-to-all(f32[2,8]{1,0} %a, f32[2,8]{1,0} %b)
  %ars = bf16[16]{0} all-reduce-start(bf16[16]{0} %w)
  %ard = bf16[16]{0} all-reduce-done(bf16[16]{0} %ars)
  ROOT %t = tuple()
}
"""


def test_parse_collectives_kinds_and_bytes():
    ops = parse_collectives(HLO_SAMPLE)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-reduce",
                     "all-to-all", "collective-permute", "reduce-scatter"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.result_bytes == 128 * 128 * 2
    assert ag.operand_bytes == 8 * 128 * 2
    assert ag.traffic == 128 * 128 * 2            # max(result, operand)
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    assert rs.traffic == 8 * 128 * 2              # operand side
    a2a = next(o for o in ops if o.kind == "all-to-all")
    assert a2a.result_bytes == 2 * (2 * 8 * 4)


def test_parse_ignores_non_collectives_and_done_ops():
    ops = parse_collectives(HLO_SAMPLE)
    # all-reduce-start counted once, -done not double counted
    n_ar = sum(1 for o in ops if o.kind == "all-reduce")
    assert n_ar == 2


def test_collective_summary():
    s = collective_summary(HLO_SAMPLE)
    assert s["n_ops"] == 6
    assert s["traffic_bytes"] > 0
    assert set(s["by_kind"]) == {"all-gather", "all-reduce", "reduce-scatter",
                                 "all-to-all", "collective-permute"}


def test_roofline_terms_pick_dominant():
    t = roofline_terms(197e12, 10e9, 1e9)         # 1s compute, tiny rest
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(1e9, 819e9, 1e9)           # 1s memory
    assert t["dominant"] == "memory_s"


def test_model_flops_semantics():
    n = 1_000_000
    assert model_flops(n, "train", 4, 128) == 6 * n * 4 * 128
    assert model_flops(n, "prefill", 4, 128) == 2 * n * 4 * 128
    assert model_flops(n, "decode", 4, 128) == 2 * n * 4
    assert model_flops(n, "train", 4, 4096, dec_len=448) == 6 * n * 4 * 448


# ------------------------- sharding specs ------------------------- #
def test_param_pspec_rules():
    from repro.models import get_config
    from repro.models.sharding import param_pspec
    cfg = get_config("qwen2_72b").with_(kv_groups=16)
    assert param_pspec(cfg, ("embed",), 2, 16) == P(None, "model")
    assert param_pspec(cfg, ("lm_head",), 2, 16) == P(None, "model")
    assert param_pspec(cfg, ("layers", "attn", "wq", "w"), 3, 16) == \
        P(None, None, "model")
    assert param_pspec(cfg, ("layers", "attn", "wo", "w"), 3, 16) == \
        P(None, "model", None)
    assert param_pspec(cfg, ("layers", "mlp", "down", "w"), 3, 16) == \
        P(None, "model", None)
    assert param_pspec(cfg, ("layers", "ln1", "scale"), 2, 16) == P(None, None)

    moe64 = get_config("olmoe_1b_7b").with_(kv_groups=16)
    assert param_pspec(moe64, ("layers", "moe", "gate"), 4, 16) == \
        P(None, "model", None, None)          # expert-parallel (64 % 16 == 0)
    moe8 = get_config("grok_1_314b").with_(kv_groups=16)
    assert param_pspec(moe8, ("layers", "moe", "gate"), 4, 16) == \
        P(None, None, None, "model")          # tensor-parallel inside expert
    assert param_pspec(moe8, ("layers", "moe", "down"), 4, 16) == \
        P(None, None, "model", None)

    ssm = get_config("falcon_mamba_7b")
    assert param_pspec(ssm, ("layers", "mamba", "in_proj", "w"), 3, 16) == \
        P(None, None, "model")
    assert param_pspec(ssm, ("layers", "mamba", "A_log"), 3, 16) == \
        P(None, "model", None)


def test_batch_pspec_replicates_indivisible_batch():
    import os
    from repro.models.sharding import batch_pspec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert batch_pspec(mesh, 4, 2) == P(("data",), None)
    # batch=1 on 16-way data axis -> replicate (long_500k)
    # emulate via divisibility logic directly
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    assert batch_pspec(FakeMesh(), 1, 2) == P(None, None)
    assert batch_pspec(FakeMesh(), 256, 2) == P(("data",), None)


# ------------------------- data pipeline ------------------------- #
def test_triple_loader_epochs_cover_all():
    from repro.data.triples import TripleLoader
    trips = np.arange(30).reshape(10, 3)
    loader = TripleLoader(trips, batch_size=4, seed=0)
    it = iter(loader)
    seen = set()
    for _ in range(loader.steps_per_epoch):
        b = next(it)
        assert b.shape == (4, 3)
        seen.update(b[:, 0].tolist())
    assert len(seen) >= 8          # shuffled coverage (padding may repeat)


def test_walks_corpus(tiny_go):
    from repro.data import corpus, skipgram_pairs
    walks, vocab, pad = corpus(tiny_go, jax.random.key(0),
                               walks_per_entity=2, walk_length=3)
    w = np.asarray(walks)
    assert w.ndim == 2
    assert vocab >= tiny_go.num_entities
    pairs = skipgram_pairs(walks, window=2, pad_token=pad, seed=0)
    assert pairs.shape[1] == 2
    assert (pairs != pad).all()


@pytest.mark.slow
def test_adam_converges_quadratic():
    from repro.optim import adam
    opt = adam(0.1)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(200):
        g = {"x": 2 * params["x"]}
        params, state = opt.update(g, state, params)
    assert abs(float(params["x"])) < 1e-2


def test_snapshot_store_roundtrip(tmp_path):
    from repro.checkpoint import SnapshotStore
    store = SnapshotStore(tmp_path)
    arrays = {"embeddings": np.random.rand(5, 4).astype(np.float32),
              "entity_ids": np.asarray(["a", "b", "c", "d", "e"])}
    store.save("go", "v1", "transe", arrays, {"dim": 4})
    arrs, meta = store.load("go", "v1", "transe")
    np.testing.assert_array_equal(arrs["embeddings"], arrays["embeddings"])
    assert meta["dim"] == 4
    assert store.versions("go") == ["v1"]
    assert store.models("go", "v1") == ["transe"]


def test_lr_schedules():
    from repro.optim.schedules import constant, inverse_sqrt, linear_warmup_cosine
    import jax.numpy as jnp
    c = constant(0.1)
    assert float(c(0)) == float(c(1000)) == pytest.approx(0.1)
    s = linear_warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, rel=1e-5)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-4)   # final_frac
    assert float(s(55)) < float(s(20))                     # decaying
    i = inverse_sqrt(1.0, warmup_steps=16)
    assert float(i(16)) == pytest.approx(1.0, rel=1e-5)
    assert float(i(64)) == pytest.approx(0.5, rel=1e-4)
