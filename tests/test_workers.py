"""Pre-forked multi-process HTTP serving (PR 6 tentpole, layer 3).

One real worker pool (``python -m repro.api.workers``, 2 workers over a
sealed registry) is launched once for the module; the tests drive it
over real sockets: wire byte-parity with the in-process gateway, /stats
merged across workers, cross-process publish→visible via the store
watcher, and SIGKILL crash-restart under client load. Slow tier — the
pool subprocess pays a full jax import per worker.
"""
import http.client
import json
import os
import signal
import subprocess
import sys
import time
import urllib.parse
from pathlib import Path

import numpy as np
import pytest

pytestmark = pytest.mark.slow

REPO = Path(__file__).resolve().parents[1]
N, D = 48, 16


def _publish(root, version, seed):
    from repro.core.registry import EmbeddingRegistry
    rng = np.random.default_rng(seed)
    registry = EmbeddingRegistry(root)
    ids = [f"GO:{i:07d}" for i in range(N)]
    labels = [f"go term {i}" for i in range(N)]
    emb = rng.standard_normal((N, D)).astype(np.float32)
    registry.publish("go", version, "transe", ids, labels, emb,
                     ontology_checksum=f"ck-{version}",
                     hyperparameters={"dim": D})
    registry.seal("go", version)
    return ids, emb


def _get(port, path, timeout=30):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def _stats(port):
    status, body = _get(port, "/stats")
    assert status == 200
    return json.loads(body)


@pytest.fixture(scope="module")
def pool(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("wreg"))
    ids, emb = _publish(root, "2024-01", seed=1)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.api.workers", "--registry", root,
         "--workers", "2", "--watch-interval-ms", "100",
         "--stats-interval-ms", "200"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
        cwd=str(REPO))
    line = proc.stdout.readline().strip()
    if not line.startswith("READY"):
        err = proc.stderr.read()
        proc.kill()
        raise RuntimeError(f"pool failed to start: {line!r}\n{err}")
    port = int(line.split("port=")[1].split()[0])
    yield {"proc": proc, "port": port, "root": root, "ids": ids, "emb": emb}
    proc.send_signal(signal.SIGTERM)
    try:
        proc.communicate(timeout=30)
    except subprocess.TimeoutExpired:
        proc.kill()
        proc.communicate()


def test_wire_parity_with_inprocess_gateway(pool):
    """Bodies over the pool's socket are byte-identical to the wire dicts
    ``Gateway.handle`` produces in-process over the same registry."""
    from repro.api import Gateway
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine
    ids = pool["ids"]
    paths = [f"/get-vector/go/transe?query={ids[0]}",
             f"/sim/go/transe?a={ids[1]}&b={ids[2]}",
             f"/closest-concepts/go/transe?query={ids[3]}&k=5",
             "/download/go/transe?offset=0&limit=4",
             "/autocomplete/go/transe?prefix=go%20term%201&limit=5",
             "/versions/go"]
    gw = Gateway(ServingEngine(EmbeddingRegistry(pool["root"])))
    try:
        for path in paths:
            status, body = _get(pool["port"], path)
            assert status == 200, (path, body[:200])
            route, _, query = path.partition("?")
            payload = {}
            for k, v in urllib.parse.parse_qsl(query):
                payload[k] = int(v) if v.isdigit() else v
            expect = json.dumps(gw.handle(route, payload)).encode()
            assert body == expect, path
    finally:
        gw.close()


def test_stats_merged_across_workers(pool):
    """/stats answered by either worker reports the whole pool: a
    ``workers`` block with both pids and counters summed from the
    per-worker state dumps."""
    ids = pool["ids"]
    for i in range(12):
        status, _ = _get(pool["port"],
                         f"/get-vector/go/transe?query={ids[i % N]}")
        assert status == 200
    deadline = time.time() + 15
    while time.time() < deadline:
        st = _stats(pool["port"])
        w = st.get("workers", {})
        if w.get("count") == 2 and st["gateway"]["requests"] >= 12:
            break
        time.sleep(0.2)
    assert w["count"] == 2
    assert len(w["pids"]) == 2
    assert st["type"] == "stats_response"
    assert st["gateway"]["requests"] >= 12      # summed, not per-worker
    assert "latency" in st and "scheduler" in st

    # transport-level 304s are pool-visible too (PR 7 satellite): a
    # conditional re-fetch answered before dispatch must still surface
    # in the merged workers.http block, with a latency histogram
    conn = http.client.HTTPConnection("127.0.0.1", pool["port"], timeout=30)
    try:
        conn.request("GET", "/download/go/transe?limit=3")
        resp = conn.getresponse()
        resp.read()
        etag = resp.getheader("ETag")
        conn.request("GET", "/download/go/transe?limit=3",
                     headers={"If-None-Match": etag})
        resp = conn.getresponse()
        resp.read()
        assert resp.status == 304
    finally:
        conn.close()
    deadline = time.time() + 15
    nm, lat = 0, None
    while time.time() < deadline:
        http_block = _stats(pool["port"])["workers"].get("http", {})
        nm = http_block.get("not_modified", 0)
        lat = (http_block.get("latency_ms") or {}).get("not_modified")
        # the serving worker's own /stats sees it live; a sibling's view
        # waits for the next periodic state dump — poll either way
        if nm >= 1 and lat and lat.get("count", 0) >= 1:
            break
        time.sleep(0.2)
    assert nm >= 1
    assert lat["count"] >= 1 and lat["p50_ms"] >= 0


def test_publish_visible_across_processes(pool):
    """A publish+seal from THIS process becomes servable in the pool's
    workers without any signal besides the store itself."""
    ids, emb2 = _publish(pool["root"], "2024-02", seed=2)
    deadline = time.time() + 20
    latest = None
    while time.time() < deadline:
        _, body = _get(pool["port"], "/versions/go")
        latest = json.loads(body).get("latest")
        if latest == "2024-02":
            break
        time.sleep(0.1)
    assert latest == "2024-02"
    # and the vectors served are the new version's, bit-exact
    _, body = _get(pool["port"], f"/get-vector/go/transe?query={ids[5]}")
    got = np.asarray(json.loads(body)["vector"], dtype=np.float32)
    np.testing.assert_array_equal(got, emb2[5])


def test_sigkill_worker_is_restarted_under_load(pool):
    """SIGKILL one worker mid-traffic: the supervisor respawns it, the
    pool keeps answering (at most one retryable client error), and
    /stats shows the restart."""
    ids = pool["ids"]
    victim = _stats(pool["port"])["workers"]["pids"][0]
    os.kill(victim, signal.SIGKILL)
    errors = 0
    for i in range(40):
        try:
            status, _ = _get(pool["port"],
                             f"/sim/go/transe?a={ids[i % N]}&b={ids[0]}",
                             timeout=10)
            if status != 200:
                errors += 1
        except OSError:
            errors += 1
        time.sleep(0.05)
    assert errors <= 1, f"{errors} client errors after SIGKILL"
    deadline = time.time() + 20
    while time.time() < deadline:
        w = _stats(pool["port"])["workers"]
        if w["count"] == 2 and w["restarts"] >= 1 \
                and victim not in w["pids"]:
            break
        time.sleep(0.2)
    assert w["count"] == 2
    assert w["restarts"] >= 1
    assert victim not in w["pids"]
