"""LatencyHistogram: fixed log-spaced buckets, derivable percentiles,
mergeable snapshots, thread safety. Fast tier."""
import threading

from repro.core.metrics import BUCKET_BOUNDS_MS, LatencyHistogram


def test_bucket_layout_is_fixed_and_log_spaced():
    assert len(BUCKET_BOUNDS_MS) == 24
    assert BUCKET_BOUNDS_MS[0] == 0.01
    for lo, hi in zip(BUCKET_BOUNDS_MS, BUCKET_BOUNDS_MS[1:]):
        assert hi == lo * 2                       # exact x2 spacing
    # the layout covers the serving range: 10us .. ~84s
    assert BUCKET_BOUNDS_MS[-1] > 60_000


def test_observe_lands_in_the_right_bucket():
    h = LatencyHistogram()
    h.observe(0.001)                              # 1 ms
    snap = h.snapshot()
    assert snap["count"] == 1 and sum(snap["bucket_counts"]) == 1
    # 1 ms falls in the (0.64, 1.28] bucket
    i = snap["bucket_counts"].index(1)
    assert snap["bucket_le_ms"][i] == 1.28
    # overflow goes to the +Inf bucket, not out of range
    h.observe(1000.0)                             # 1000 s
    snap = h.snapshot()
    assert snap["bucket_counts"][-1] == 1
    assert snap["bucket_le_ms"][-1] == "inf"
    assert snap["min_ms"] == 1.0 and snap["max_ms"] == 1e6


def test_percentiles_derivable_from_any_snapshot():
    h = LatencyHistogram()
    assert h.percentile(50) is None               # empty: no answer
    for _ in range(100):
        h.observe(0.001)                          # all in (0.64, 1.28]
    snap = h.snapshot()
    assert 0.64 <= snap["p50_ms"] <= 1.28
    assert 0.64 <= snap["p99_ms"] <= 1.28
    # bimodal: 90 fast (1ms) + 10 slow (100ms) -> p50 fast, p99 slow
    h2 = LatencyHistogram()
    for _ in range(90):
        h2.observe(0.001)
    for _ in range(10):
        h2.observe(0.1)
    assert h2.percentile(50) <= 1.28
    assert h2.percentile(99) > 50.0
    # snapshots merge by adding counts — p99 derivable from the merge
    merged = [a + b for a, b in zip(h.snapshot()["bucket_counts"],
                                    h2.snapshot()["bucket_counts"])]
    p99 = LatencyHistogram.percentile_from(merged, 99)
    assert p99 > 50.0


def test_negative_and_zero_observations_clamp_to_first_bucket():
    h = LatencyHistogram()
    h.observe(0.0)
    h.observe(-1.0)                               # clock skew guard
    snap = h.snapshot()
    assert snap["bucket_counts"][0] == 2 and snap["min_ms"] == 0.0


def test_concurrent_observe_loses_nothing():
    h = LatencyHistogram()
    n_threads, per = 8, 500

    def worker(i):
        for j in range(per):
            h.observe((i + j % 7) * 1e-4)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = h.snapshot()
    assert snap["count"] == n_threads * per
    assert sum(snap["bucket_counts"]) == n_threads * per
