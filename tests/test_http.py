"""HTTP service layer over the gateway (PR 5 tentpole).

End-to-end over a real socket: wire parity with in-process
``Gateway.handle`` on every paper endpoint, ``ApiError`` -> HTTP status
mapping, ETag/If-None-Match 304s with zero gateway/index work, chunked
streaming download that never buffers the full body, keep-alive, and
concurrent HTTP clients sharing one scheduler. Fast tier — snapshots
are published directly, servers bind ephemeral loopback ports."""
import http.client
import json
import threading
import urllib.error
import urllib.parse
import urllib.request

import numpy as np
import pytest

from repro.api import Gateway, serve_http
from repro.core.serving import ServingEngine

N, D = 40, 12


def _publish(registry, ontology, version, model="transe", n=N, seed=0):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, D)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}", hyperparameters={"dim": D})
    return ids


@pytest.fixture()
def served(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    _publish(registry, "go", "2024-02", seed=2)
    engine = ServingEngine(registry, cache_capacity=4)
    gateway = Gateway(engine)
    server = serve_http(gateway, port=0, stream_page_rows=16)
    yield server, gateway, engine, ids
    server.close()
    gateway.close()


def _get(server, path, headers=None):
    req = urllib.request.Request(server.url + path, headers=headers or {})
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, dict(r.headers), r.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def _post(server, path, payload, headers=None):
    req = urllib.request.Request(
        server.url + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json", **(headers or {})})
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, dict(r.headers), r.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


# --------------------------- wire parity ------------------------------- #
def test_every_endpoint_wire_identical_to_in_process_handle(served):
    """The acceptance criterion: a body served over the socket is the
    same JSON document ``Gateway.handle`` returns in-process — all five
    paper endpoints plus the deterministic ops endpoints."""
    server, gateway, engine, ids = served
    cases = [
        ("/get-vector/go/transe", {"query": ids[3]}),
        ("/sim/go/transe", {"a": ids[0], "b": ids[1]}),
        ("/closest-concepts/go/transe", {"query": ids[2], "k": 5}),
        ("/download/go/transe", {"version": "2024-02", "offset": 3,
                                 "limit": 7}),
        ("/autocomplete/go/transe", {"prefix": "go term 1", "limit": 4}),
        ("/health", {}),
        ("/versions/go", {}),
        ("/lineage/go", {}),
    ]
    for route, payload in cases:
        query = urllib.parse.urlencode(payload)
        status, headers, body = _get(server, route + ("?" + query
                                                      if query else ""))
        assert status == 200, (route, body)
        assert headers["Content-Type"] == "application/json"
        assert json.loads(body) == gateway.handle(route, dict(payload)), route


def test_post_json_body_parity_with_get(served):
    server, gateway, engine, ids = served
    payload = {"a": ids[0], "b": ids[1]}
    st_g, _, body_g = _get(server,
                           "/sim/go/transe?" + urllib.parse.urlencode(payload))
    st_p, _, body_p = _post(server, "/sim/go/transe", payload)
    assert st_g == st_p == 200
    assert json.loads(body_g) == json.loads(body_p)


def test_query_string_types_coerced_like_typed_payloads(served):
    server, gateway, engine, ids = served
    st, _, body = _get(server, f"/closest-concepts/go/transe?"
                               f"query={ids[0]}&k=3&fuzzy=false")
    assert st == 200 and len(json.loads(body)["results"]) == 3
    # an unparseable int passes through and fails structured, not a 500
    st, _, body = _get(server, f"/closest-concepts/go/transe?"
                               f"query={ids[0]}&k=banana")
    assert st == 400 and json.loads(body)["code"] == "BAD_REQUEST"
    # `stream` is a download-only transport flag: on any other route it
    # is an unknown field, exactly as the in-process entry point says
    st, _, body = _get(server, f"/sim/go/transe?"
                               f"a={ids[0]}&b={ids[1]}&stream=true")
    wire = json.loads(body)
    assert st == 400 and wire["details"]["unknown_fields"] == ["stream"]
    # conflicting duplicate query params are a 400, not a silent
    # last-wins; an agreeing duplicate is fine
    st, _, body = _get(server, f"/sim/go/transe?"
                               f"a={ids[0]}&a={ids[1]}&b={ids[2]}")
    wire = json.loads(body)
    assert st == 400 and wire["details"]["conflicting_fields"] == ["a"]
    st, _, body = _get(server, f"/sim/go/transe?"
                               f"a={ids[0]}&a={ids[0]}&b={ids[2]}")
    assert st == 200


# ------------------------- error status mapping ------------------------ #
def test_apierror_status_and_code_map_onto_http(served):
    server, gateway, engine, ids = served
    cases = [
        ("/no/such/route", 404, "NOT_FOUND"),
        ("/sim/mars/transe?a=x&b=y", 404, "UNKNOWN_ONTOLOGY"),
        ("/sim/go/no-model?a=x&b=y", 404, "UNKNOWN_MODEL"),
        ("/sim/go/transe?a=x&b=y&version=1999-01", 404, "UNKNOWN_VERSION"),
        (f"/get-vector/go/transe?query=NOPE", 404, "UNKNOWN_CLASS"),
        (f"/closest-concepts/go/transe?query={ids[0]}&k=0", 400,
         "BAD_REQUEST"),
        (f"/sim/go/transe?a={ids[0]}&b={ids[1]}&bogus=1", 400,
         "BAD_REQUEST"),
    ]
    for path, want_status, want_code in cases:
        status, _, body = _get(server, path)
        wire = json.loads(body)
        assert (status, wire["type"], wire["code"]) == \
               (want_status, "error", want_code), path


def test_malformed_post_body_is_structured_400(served):
    server, gateway, engine, ids = served
    req = urllib.request.Request(
        server.url + "/sim/go/transe", data=b"{not json",
        headers={"Content-Type": "application/json"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=30)
    assert ei.value.code == 400
    assert json.loads(ei.value.read())["code"] == "BAD_REQUEST"
    # a JSON array body is equally structured
    st, _, body = _post(server, "/sim/go/transe", [1, 2, 3])
    assert st == 400 and json.loads(body)["code"] == "BAD_REQUEST"


def test_shutting_down_maps_to_503(served):
    server, gateway, engine, ids = served
    # grab a live validator first: even a matching If-None-Match must
    # answer 503 once the gateway drains (a 304 would keep load
    # balancers routing to a dying instance)
    _, headers, _ = _get(server, "/download/go/transe?version=2024-02"
                                 "&limit=5")
    gateway.close()
    st, _, body = _get(server, f"/sim/go/transe?a={ids[0]}&b={ids[1]}")
    assert st == 503 and json.loads(body)["code"] == "SHUTTING_DOWN"
    st, _, body = _get(server, "/download/go/transe?version=2024-02"
                               "&limit=5",
                       headers={"If-None-Match": headers["ETag"]})
    assert st == 503 and json.loads(body)["code"] == "SHUTTING_DOWN"


def test_post_honors_and_conflict_checks_query_params(served):
    """POST query params are part of the resource identity: they merge
    into the body payload (a cache keys on the full URL, so dropping
    them would associate the wrong body with it); a disagreement is a
    400, never a silent winner."""
    server, gateway, engine, ids = served
    st, _, body = _post(server,
                        "/download/go/transe?version=2024-01&limit=5", {})
    page = json.loads(body)
    assert st == 200 and page["version"] == "2024-01"
    assert len(page["rows"]) == 5
    # an agreeing duplicate is fine; a conflict is rejected
    st, _, body = _post(server, "/sim/go/transe?fuzzy=false",
                        {"a": ids[0], "b": ids[1], "fuzzy": False})
    assert st == 200
    st, _, body = _post(server, "/download/go/transe?version=2024-01",
                        {"version": "2024-02"})
    wire = json.loads(body)
    assert st == 400 and wire["details"]["conflicting_fields"] == ["version"]


def test_close_without_serving_never_hangs(registry):
    """close() before the accept loop ever ran must return, not block
    in BaseServer.shutdown() waiting on an event only serve_forever
    sets."""
    _publish(registry, "go", "2024-01", seed=1)
    gateway = Gateway(ServingEngine(registry))
    server = serve_http(gateway, port=0, start=False)
    closer = threading.Thread(target=server.close, daemon=True)
    closer.start()
    closer.join(timeout=10)
    assert not closer.is_alive(), "close() deadlocked without serve loop"
    gateway.close()


# ------------------------- ETag / If-None-Match ------------------------ #
def test_pinned_page_refetch_is_304_with_no_gateway_or_index_work(served):
    server, gateway, engine, ids = served
    path = "/download/go/transe?version=2024-02&offset=0&limit=10"
    status, headers, body = _get(server, path)
    page = json.loads(body)
    assert status == 200 and headers["ETag"] == page["etag"]

    routed_before = gateway.counters["by_route"]["download"]
    cache_before = engine.cache_stats()
    status, headers2, body2 = _get(server, path,
                                   headers={"If-None-Match": page["etag"]})
    assert status == 304 and body2 == b""
    assert headers2["ETag"] == page["etag"]
    # the 304 never entered the gateway or touched the index cache
    assert gateway.counters["by_route"]["download"] == routed_before
    cache_after = engine.cache_stats()
    assert (cache_after["hits"], cache_after["misses"]) == \
           (cache_before["hits"], cache_before["misses"])
    assert server.http_stats["not_modified"] == 1
    # a stale validator (other coordinates) is NOT a match
    status, _, body3 = _get(server, path,
                            headers={"If-None-Match": '"deadbeef"'})
    assert status == 200 and json.loads(body3) == page


def test_unpinned_304_tracks_the_latest_pointer(served, registry):
    server, gateway, engine, ids = served
    path = "/download/go/transe?limit=5"             # no version pin
    status, headers, body = _get(server, path)
    etag = json.loads(body)["etag"]
    assert status == 200
    status, _, _ = _get(server, path, headers={"If-None-Match": etag})
    assert status == 304                             # latest unchanged
    # a release lands; the same validator must now MISS
    _publish(registry, "go", "2024-03", seed=9)
    engine.invalidate("go", "2024-03")
    status, _, body = _get(server, path, headers={"If-None-Match": etag})
    fresh = json.loads(body)
    assert status == 200 and fresh["version"] == "2024-03"
    assert fresh["etag"] != etag


def test_etag_shortcut_never_hides_validation_errors(served):
    from repro.api.gateway import download_etag
    server, gateway, engine, ids = served
    # bogus coordinates with a hopeful If-None-Match still 404 properly
    st, _, body = _get(server, "/download/mars/transe?version=v1",
                       headers={"If-None-Match": '"whatever"'})
    assert st == 404 and json.loads(body)["code"] == "UNKNOWN_ONTOLOGY"
    st, _, body = _get(server, "/download/go/transe?limit=0",
                       headers={"If-None-Match": '"whatever"'})
    assert st == 400 and json.loads(body)["code"] == "BAD_REQUEST"
    # ETags are deterministic over public coordinates, so a cache can
    # hold a MATCHING validator for a version that does not exist — the
    # shortcut must not vouch for coordinates the gateway would reject
    forged = download_etag("go", "transe", "2024-99", 0, 10)
    st, _, body = _get(server, "/download/go/transe?version=2024-99&limit=10",
                       headers={"If-None-Match": forged})
    assert st == 404 and json.loads(body)["code"] == "UNKNOWN_VERSION"
    forged = download_etag("go", "no-model", "2024-02", 0, 10)
    st, _, body = _get(server, "/download/go/no-model?version=2024-02&limit=10",
                       headers={"If-None-Match": forged})
    assert st == 404 and json.loads(body)["code"] == "UNKNOWN_MODEL"
    # default-limit requests hit the fast path too (the shortcut derives
    # the default from the schema, not a re-typed literal)
    st, headers, body = _get(server, "/download/go/transe")
    st2, _, body2 = _get(server, "/download/go/transe",
                         headers={"If-None-Match": headers["ETag"]})
    assert (st, st2) == (200, 304) and body2 == b""
    # the shortcut is exactly as strict as the full path: a payload the
    # gateway would 400 (unknown field, route conflict) never 304s even
    # with a matching validator
    st, _, body = _get(server, "/download/go/transe?bogus=1",
                       headers={"If-None-Match": headers["ETag"]})
    assert st == 400 and json.loads(body)["code"] == "BAD_REQUEST"
    # 304 is a GET/HEAD concept (RFC 9110): a POST with a matching
    # validator executes the method and returns the page
    st, _, body = _post(server, "/download/go/transe", {},
                        headers={"If-None-Match": headers["ETag"]})
    assert st == 200 and json.loads(body)["type"] == "download_page"
    st, _, body = _get(server, "/download/go/transe?ontology=hp",
                       headers={"If-None-Match": headers["ETag"]})
    assert st == 400 and json.loads(body)["code"] == "BAD_REQUEST"


def test_malformed_content_length_is_400_and_closes_connection(served):
    """A negative Content-Length must never reach rfile.read (read(-1)
    blocks until the client hangs up = a leaked handler thread), and a
    non-numeric one leaves the body unread, so keep-alive would parse
    garbage — both answer 400 and drop the connection."""
    import socket
    server, gateway, engine, ids = served
    for bad in (b"-5", b"abc", str(1 << 22).encode()):
        with socket.create_connection(("127.0.0.1", server.port),
                                      timeout=10) as s:
            s.sendall(b"POST /sim/go/transe HTTP/1.1\r\n"
                      b"Host: t\r\nContent-Length: " + bad + b"\r\n\r\n")
            s.settimeout(10)
            chunks = []
            while True:
                try:
                    data = s.recv(65536)
                except socket.timeout:                # pragma: no cover
                    raise AssertionError(f"no response for {bad!r}")
                if not data:
                    break                             # server closed: good
                chunks.append(data)
            raw = b"".join(chunks)
            assert raw.startswith(b"HTTP/1.1 400"), (bad, raw[:80])
            assert b"BAD_REQUEST" in raw
            assert b"Connection: close" in raw    # client told, not reset


def test_chunked_request_body_is_refused_and_connection_dropped(served):
    """A Transfer-Encoding body has no Content-Length; reading it is
    unsupported, and leaving it in the pipe would desync keep-alive —
    the server answers 400 and closes."""
    import socket
    server, gateway, engine, ids = served
    with socket.create_connection(("127.0.0.1", server.port),
                                  timeout=10) as s:
        s.sendall(b"POST /sim/go/transe HTTP/1.1\r\nHost: t\r\n"
                  b"Transfer-Encoding: chunked\r\n\r\n"
                  b"5\r\n{\"a\":\r\n0\r\n\r\n")
        s.settimeout(10)
        chunks = []
        while True:
            data = s.recv(65536)
            if not data:
                break                                 # connection closed
            chunks.append(data)
        raw = b"".join(chunks)
        assert raw.startswith(b"HTTP/1.1 400")
        assert b"Transfer-Encoding" in raw


# ------------------------- streaming download -------------------------- #
def test_stream_download_is_chunked_paged_and_byte_identical(served):
    server, gateway, engine, ids = served
    routed_before = gateway.counters["by_route"]["download"]
    status, headers, body = _get(server, "/download/go/transe?stream=true")
    assert status == 200
    assert headers.get("Transfer-Encoding") == "chunked"
    assert "Content-Length" not in headers
    assert headers["X-Bio-KGvec2go-Version"] == "2024-02"
    assert int(headers["X-Bio-KGvec2go-Total"]) == N
    # stream_page_rows=16 over 40 rows -> exactly 3 cursor pages
    assert gateway.counters["by_route"]["download"] == routed_before + 3
    # the paper's download payload, byte-identical to the legacy
    # full-body endpoints (wire-fidelity satellite covers the precision)
    assert body.decode() == engine.download("go", "transe")
    assert body.decode() == engine.registry.to_json("go", "transe",
                                                    "2024-02")
    # the server never held the whole body: the largest single chunk is
    # one page, strictly smaller than the full payload
    assert 0 < server.http_stats["max_chunk_bytes"] < len(body)
    assert server.http_stats["streams"] == 1


def test_stream_honors_offset_limit_and_version(served):
    """offset/limit select rows [offset, offset+limit) like the page
    endpoint; no limit streams to the end of the table (streaming's
    reason to exist — it is not subject to page_limit_max)."""
    server, gateway, engine, ids = served
    st, _, body = _get(server, "/download/go/transe"
                               "?stream=true&version=2024-01&offset=30&limit=4")
    rows = json.loads(body)
    assert st == 200 and list(rows) == ids[30:34]    # rows [30, 34)
    idx = engine._index("go", "transe", "2024-01")
    assert rows[ids[30]] == [float(x) for x in idx.embeddings[30]]
    # a cap above the page size spans pages but still caps the total
    st, _, body = _get(server, "/download/go/transe?stream=true&limit=20")
    assert list(json.loads(body)) == ids[:20]        # stream_page_rows=16
    # no limit -> offset to end of table
    st, _, body = _get(server, "/download/go/transe?stream=true&offset=30")
    assert list(json.loads(body)) == ids[30:]
    # bad stream coordinates fail structured before any chunk is sent
    st, _, body = _get(server, "/download/mars/transe?stream=true")
    assert st == 404 and json.loads(body)["code"] == "UNKNOWN_ONTOLOGY"
    st, _, body = _get(server, "/download/go/transe?stream=true&k=5")
    assert st == 400 and json.loads(body)["code"] == "BAD_REQUEST"
    st, _, body = _get(server, "/download/go/transe?stream=true&limit=0")
    assert st == 400 and json.loads(body)["code"] == "BAD_REQUEST"
    # a typo'd stream flag is a loud 400, not a quietly served page
    st, _, body = _get(server, "/download/go/transe?stream=ture")
    wire = json.loads(body)
    assert st == 400 and wire["details"]["field"] == "stream"
    # stream follows the same conflict rules as every other field: a
    # route/payload coordinate clash and a body/query stream
    # disagreement are 400s, never a silent winner
    st, _, body = _get(server, "/download/go/transe"
                               "?stream=true&ontology=hp&limit=2")
    wire = json.loads(body)
    assert st == 400 and wire["details"]["conflicting_fields"] == ["ontology"]
    st, _, body = _post(server, "/download/go/transe?stream=true",
                        {"stream": False})
    wire = json.loads(body)
    assert st == 400 and wire["details"]["conflicting_fields"] == ["stream"]
    # agreeing values are fine
    st, headers, body = _post(server, "/download/go/transe?stream=true",
                              {"stream": True, "limit": 3})
    assert st == 200 and headers.get("Transfer-Encoding") == "chunked"
    assert list(json.loads(body)) == ids[:3]


# ----------------------- keep-alive + concurrency ---------------------- #
def test_keep_alive_serves_many_requests_per_connection(served):
    server, gateway, engine, ids = served
    conn = http.client.HTTPConnection("127.0.0.1", server.port, timeout=30)
    try:
        for i in range(5):
            conn.request("GET", f"/sim/go/transe?a={ids[i]}&b={ids[i + 1]}")
            resp = conn.getresponse()
            assert resp.status == 200
            json.loads(resp.read())                  # drain for reuse
        # mixed framing on one connection: chunked stream then a 304
        conn.request("GET", "/download/go/transe?stream=true")
        resp = conn.getresponse()
        assert resp.status == 200 and len(json.loads(resp.read())) == N
        page = gateway.download("go", "transe", version="2024-02", limit=3)
        conn.request("GET",
                     "/download/go/transe?version=2024-02&limit=3",
                     headers={"If-None-Match": page.etag})
        resp = conn.getresponse()
        assert resp.status == 304 and resp.read() == b""
    finally:
        conn.close()


def test_concurrent_http_clients_share_one_scheduler(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    engine = ServingEngine(registry)
    # result cache off: this test counts scheduler submissions, and the
    # client index pattern repeats queries — a cache hit wouldn't submit
    gateway = Gateway(engine, flush_after_ms=2.0,     # real flush loop
                      result_cache_entries=0)
    server = serve_http(gateway, port=0)
    n_clients, per = 8, 6
    failures = []

    def client(cix):
        conn = http.client.HTTPConnection("127.0.0.1", server.port,
                                          timeout=30)
        try:
            for j in range(per):
                q = ids[(cix * per + j) % N]
                conn.request("GET",
                             f"/closest-concepts/go/transe?query={q}&k=5")
                resp = conn.getresponse()
                body = json.loads(resp.read())
                if resp.status != 200 or len(body["results"]) != 5:
                    failures.append((cix, j, resp.status))
        except Exception as e:                        # pragma: no cover
            failures.append((cix, "exc", repr(e)))
        finally:
            conn.close()

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert failures == []
        st = gateway.scheduler.stats
        assert st["submitted"] >= n_clients * per
        assert st["resolved"] == st["submitted"]
        # the HTTP transport's traffic shows up in /stats histograms
        stats = gateway.stats()
        assert stats.latency["closest-concepts"]["count"] >= n_clients * per
        assert stats.scheduler["latency_ms"]["count"] == st["resolved"]
    finally:
        server.close()
        gateway.close()


# ------------------- HTTP/1.1 pipelining (PR 6) ------------------------ #
def test_http11_pipelining_on_one_connection(served):
    """Several requests written back-to-back on one connection before any
    response is read: HTTP/1.1 requires in-order responses, each complete
    and byte-identical to its non-pipelined equivalent."""
    import socket
    server, gateway, _, ids = served
    paths = [f"/get-vector/go/transe?query={ids[0]}",
             "/versions/go",
             f"/sim/go/transe?a={ids[1]}&b={ids[2]}",
             f"/autocomplete/go/transe?prefix=go%20term&limit=5",
             f"/closest-concepts/go/transe?query={ids[3]}&k=4"]
    blob = b"".join(f"GET {p} HTTP/1.1\r\nHost: t\r\n\r\n".encode()
                    for p in paths)

    with socket.create_connection(("127.0.0.1", server.port),
                                  timeout=30) as s:
        s.sendall(blob)                        # all five, no reads between
        f = s.makefile("rb")
        bodies = []
        for _ in paths:
            status = f.readline()
            assert b" 200 " in status, status
            clen = None
            while True:
                line = f.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                key, _, val = line.partition(b":")
                if key.strip().lower() == b"content-length":
                    clen = int(val)
            assert clen is not None
            bodies.append(f.read(clen))

    for path, body in zip(paths, bodies):
        route, _, query = path.partition("?")
        payload = {}
        for k, v in urllib.parse.parse_qsl(query):
            payload[k] = int(v) if v.isdigit() else v
        expect = json.dumps(gateway.handle(route, payload)).encode()
        assert body == expect, path
