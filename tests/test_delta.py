"""GraphDelta: exact diffs between ontology releases + churn policy signal."""
import numpy as np
import pytest

from repro.ontology import GraphDelta, KnowledgeGraph, TermMeta
from repro.ontology.synthetic import GO_SPEC, HP_SPEC, evolve, generate, release_series


def _kg(triples, labels=None):
    terms = {}
    for h, _, t in triples:
        for e in (h, t):
            terms.setdefault(e, TermMeta(e, (labels or {}).get(e, f"label {e}")))
    return KnowledgeGraph.from_triples(triples, terms)


def test_identity_delta_is_empty(tiny_go):
    d = GraphDelta.compute(tiny_go, tiny_go)
    assert d.is_empty
    assert d.churn_fraction == 0.0
    assert d.stats()["touched_entities"] == 0


def test_known_delta_counts():
    old = _kg([("A", "is_a", "B"), ("C", "is_a", "B"), ("C", "part_of", "A")])
    new = _kg([("A", "is_a", "B"), ("D", "is_a", "B"), ("D", "regulates", "A")],
              labels={"A": "renamed a"})
    d = GraphDelta.compute(old, new)
    assert d.added_entities == ["D"]
    assert d.removed_entities == ["C"]
    assert d.relabeled_entities == ["A"]
    assert d.added_relations == ["regulates"]
    assert d.removed_relations == ["part_of"]
    assert ("D", "is_a", "B") in d.added_triples
    assert ("C", "is_a", "B") in d.removed_triples
    # touched: A (relabel + triple endpoints), C, D — B is an endpoint of
    # both added and removed is_a triples, so it's touched too
    assert set(d.touched_entities) == {"A", "B", "C", "D"}
    assert d.n_universe == 4
    assert d.churn_fraction == 1.0


def test_delta_is_antisymmetric(tiny_go):
    kg2 = evolve(tiny_go, GO_SPEC, seed=11)
    fwd = GraphDelta.compute(tiny_go, kg2)
    bwd = GraphDelta.compute(kg2, tiny_go)
    assert fwd.added_entities == bwd.removed_entities
    assert fwd.removed_entities == bwd.added_entities
    assert fwd.added_triples == bwd.removed_triples
    assert fwd.churn_fraction == bwd.churn_fraction
    assert not fwd.is_empty


def test_delta_stable_under_id_shift():
    """Inserting an entity early in sort order shifts every integer id;
    the string-level delta must see only the insertion."""
    old = _kg([("M:2", "is_a", "M:9")])
    new = _kg([("M:2", "is_a", "M:9"), ("M:0", "is_a", "M:2")])
    d = GraphDelta.compute(old, new)
    assert d.added_entities == ["M:0"]
    assert d.removed_entities == []
    assert d.added_triples == [("M:0", "is_a", "M:2")]
    assert d.removed_triples == []


def test_evolve_relabel_frac_generates_relabels(tiny_go):
    kg2 = evolve(tiny_go, GO_SPEC, seed=5, add_frac=0.0, obsolete_frac=0.0,
                 rewire_frac=0.0, relabel_frac=0.05)
    d = GraphDelta.compute(tiny_go, kg2)
    assert len(d.relabeled_entities) >= 1
    assert d.added_entities == [] and d.removed_entities == []
    assert d.added_triples == [] and d.removed_triples == []
    # relabel-only churn: exactly the renamed terms
    assert d.stats()["touched_entities"] == len(d.relabeled_entities)


def test_release_series_low_churn_knobs():
    """The warm-start benchmark's contract: evolve fracs dial the churn."""
    series = release_series(GO_SPEC, 3, seed=0, n_terms=300,
                            add_frac=0.02, obsolete_frac=0.005,
                            rewire_frac=0.005)
    for (_, prev), (_, cur) in zip(series, series[1:]):
        d = GraphDelta.compute(prev, cur)
        assert 0.0 < d.churn_fraction <= 0.10, d.stats()


def test_release_series_passthrough_changes_series():
    calm = release_series(HP_SPEC, 2, seed=3, n_terms=80, add_frac=0.01,
                          obsolete_frac=0.0, rewire_frac=0.0)
    wild = release_series(HP_SPEC, 2, seed=3, n_terms=80, add_frac=0.2,
                          obsolete_frac=0.0, rewire_frac=0.0)
    d_calm = GraphDelta.compute(calm[0][1], calm[1][1])
    d_wild = GraphDelta.compute(wild[0][1], wild[1][1])
    assert len(d_wild.added_entities) > len(d_calm.added_entities)
    assert d_wild.churn_fraction > d_calm.churn_fraction
