"""BIO004 seeded violations: a mini schema + route table that drifted —
an error code missing from _LEGACY, a wire dataclass missing from
_TYPES, a route whose handler does not exist, and a raised code with no
HTTP status."""
import dataclasses

CODE_STATUS = {
    "BAD_REQUEST": 400,
    "NOT_FOUND": 404,          # -> BIO004: no _LEGACY mapping
}

_LEGACY = {
    "BAD_REQUEST": ValueError,
}


@dataclasses.dataclass
class PingRequest:
    payload: str = ""


@dataclasses.dataclass
class PingResponse:            # -> BIO004: not registered in _TYPES
    payload: str = ""


_TYPES = {
    PingRequest: "ping-request",
}


class ApiError(Exception):
    def __init__(self, code, message):
        self.code, self.message = code, message


class MiniGateway:
    def __init__(self):
        self._routes = (
            ("ping", ("ping",), PingRequest, self._handle_ping),
            ("gone", ("gone",), PingRequest, self._handle_gone),  # no method
        )

    def _handle_ping(self, req):
        raise ApiError("TEAPOT", "no status mapping")   # -> BIO004
