"""GEN002 negative: placeholders present (including nested specs)."""


def greet(name: str, width: int) -> str:
    return f"hello, {name:>{width}}"
