"""BIO005 seeded violation: a broad except swallowing silently, with no
comment justifying why dropping the resolution path is safe."""


def resolve_all(tickets):
    for t in tickets:
        try:
            t.resolve()
        except Exception:
            pass
