"""BIO004 negative: the same mini schema with every map in lock-step."""
import dataclasses

CODE_STATUS = {
    "BAD_REQUEST": 400,
    "NOT_FOUND": 404,
}

_LEGACY = {
    "BAD_REQUEST": ValueError,
    "NOT_FOUND": KeyError,
}


@dataclasses.dataclass
class PingRequest:
    payload: str = ""


@dataclasses.dataclass
class PingResponse:
    payload: str = ""


_TYPES = {
    PingRequest: "ping-request",
    PingResponse: "ping-response",
}


class ApiError(Exception):
    def __init__(self, code, message):
        self.code, self.message = code, message


class MiniGateway:
    def __init__(self):
        self._routes = (
            ("ping", ("ping",), PingRequest, self._handle_ping),
        )

    def _handle_ping(self, req):
        raise ApiError("NOT_FOUND", "no such thing")
