"""GEN001 negative: the import is used."""
import zlib


def crc(data: bytes) -> int:
    return zlib.crc32(data)
