"""BIO003 seeded violation: a forking module imports jax at top level
and runs a device op in the pre-fork parent path."""
import os

import jax


def spawn(table):
    warm = jax.device_put(table)          # parent-side device op -> BIO003
    pid = os.fork()
    if pid == 0:
        serve(warm)
    return pid


def serve(table):
    raise SystemExit(0)
