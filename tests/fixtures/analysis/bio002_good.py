# bioan: module-scope[BIO002]
"""BIO002 negative: the same write through the tmp+os.replace idiom."""
import json
import os
from pathlib import Path


def persist(state_dir: Path, payload: dict) -> None:
    path = state_dir / "state.json"
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(payload))
    os.replace(tmp, path)


def _atomic_write_text(path: Path, payload: str) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(payload)
    os.replace(tmp, path)
