"""BIO003 negative: the forking module defers every jax touch into the
post-fork child (the PR 6 pre-warm pattern: import modules in the
parent if you must, run device ops only after the fork)."""
import os


def spawn(table):
    pid = os.fork()
    if pid == 0:
        serve(table)
    return pid


def serve(table):
    import jax

    jax.device_put(table)
    raise SystemExit(0)
