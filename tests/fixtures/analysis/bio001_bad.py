"""BIO001 seeded violation: 'count' is written under the lock in one
method and without it in another."""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1

    def reset(self):
        self.count = 0          # unguarded write -> BIO001
