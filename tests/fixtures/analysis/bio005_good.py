"""BIO005 negatives: the three accepted shapes — narrow type, a written
justification, and an actual resolution in the handler."""


def resolve_all(tickets):
    for t in tickets:
        try:
            t.resolve()
        except KeyError:
            pass
        except Exception:
            # the drain loop re-rejects this ticket on the next pass, so
            # dropping the first failure loses nothing
            pass


def reject_on_error(ticket):
    try:
        ticket.resolve()
    except Exception as e:
        ticket.reject(str(e))
