# bioan: module-scope[BIO002]
"""BIO002 seeded violation: a state file published with a direct write
instead of the tmp+os.replace idiom."""
import json
from pathlib import Path


def persist(state_dir: Path, payload: dict) -> None:
    (state_dir / "state.json").write_text(json.dumps(payload))  # -> BIO002
