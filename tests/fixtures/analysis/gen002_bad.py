"""GEN002 seeded violation: an f-string interpolating nothing."""


def greet(name: str) -> str:
    return f"hello, stranger"
