"""GEN001 seeded violation: a dead module-level binding."""
import zlib


def crc(data: bytes) -> int:
    return sum(data)
