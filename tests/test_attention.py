"""Chunked / SWA / decode attention vs the plain reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    plain_attention, swa_attention)

# LM attention tests: tier-2 only (run with plain `pytest`)
pytestmark = pytest.mark.slow


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def _make_qkv(seed, B, S, G, H, hd, Sk=None):
    ks = jax.random.split(jax.random.key(seed), 3)
    Sk = Sk or S
    return (_rand(ks[0], B, S, G, H, hd),
            _rand(ks[1], B, Sk, G, hd),
            _rand(ks[2], B, Sk, G, hd))


@pytest.mark.parametrize("B,S,G,H,hd,cq,ck", [
    (2, 64, 2, 2, 16, 16, 16),
    (1, 96, 1, 3, 8, 32, 16),     # S not a multiple of cq
    (2, 33, 2, 1, 16, 16, 16),    # ragged both ways
    (1, 128, 4, 2, 32, 128, 128), # single chunk
])
def test_chunked_causal_matches_plain(B, S, G, H, hd, cq, ck):
    q, k, v = _make_qkv(0, B, S, G, H, hd)
    out = chunked_attention(q, k, v, causal=True, chunk_q=cq, chunk_kv=ck)
    ref = plain_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_chunked_noncausal_matches_plain():
    q, k, v = _make_qkv(1, 2, 48, 2, 2, 16, Sk=80)
    out = chunked_attention(q, k, v, causal=False, chunk_q=16, chunk_kv=32)
    ref = plain_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,W,cq", [(64, 16, 16), (100, 24, 32), (32, 64, 16)])
def test_swa_matches_masked_plain(S, W, cq):
    B, G, H, hd = 2, 2, 2, 16
    q, k, v = _make_qkv(2, B, S, G, H, hd)
    out = swa_attention(q, k, v, window=W, chunk_q=cq)

    # reference: plain attention with a (q - k < W) band mask
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqghd,bkgd->bghqk", q * scale, k)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    ok = (kpos <= qpos) & (qpos - kpos < W)
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bghqk,bkgd->bghqd", p, v)
    ref = jnp.moveaxis(ref, 3, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_matches_last_row_of_plain():
    B, S, G, H, hd = 2, 40, 2, 2, 16
    q, k, v = _make_qkv(3, B, S, G, H, hd)
    full = plain_attention(q, k, v, causal=True)
    # decode: query = last position, cache = all S positions
    out = decode_attention(q[:, -1:], jnp.moveaxis(k, 1, 2),
                           jnp.moveaxis(v, 1, 2), jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1:]),
                               rtol=1e-5, atol=1e-5)


def test_decode_respects_n_valid():
    B, S, G, H, hd = 1, 32, 1, 1, 8
    q, k, v = _make_qkv(4, B, S, G, H, hd)
    kc, vc = jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)
    out_10 = decode_attention(q[:, -1:], kc, vc, jnp.asarray(10))
    # garbage beyond slot 10 must not matter
    kc2 = kc.at[:, :, 10:].set(99.0)
    vc2 = vc.at[:, :, 10:].set(-99.0)
    out_10b = decode_attention(q[:, -1:], kc2, vc2, jnp.asarray(10))
    np.testing.assert_allclose(np.asarray(out_10), np.asarray(out_10b),
                               rtol=1e-6, atol=1e-6)


def test_fully_masked_rows_are_zero_not_nan():
    # causal with q_offset far beyond k range would mask everything for
    # early rows; emulate with window so row 0 sees only itself.
    B, S, G, H, hd = 1, 8, 1, 1, 4
    q, k, v = _make_qkv(5, B, S, G, H, hd)
    out = swa_attention(q, k, v, window=1, chunk_q=4)
    assert np.isfinite(np.asarray(out)).all()
