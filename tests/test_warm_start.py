"""Vocab remapping + warm-start param carry-over for all six KGE models."""
import jax
import numpy as np
import pytest

from repro.data import corpus, skipgram_pairs, token_vocab
from repro.kge import make_model, remap_params, vocab_remap
from repro.kge.train import KGETrainer, TrainConfig, make_train_step
from repro.ontology.synthetic import GO_SPEC, evolve, generate

ALL_MODELS = ("transe", "transr", "distmult", "hole", "boxe", "rdf2vec")


# --------------------------- vocab_remap --------------------------- #
def test_vocab_remap_by_name():
    old = ["A", "B", "C", "D"]
    new = ["B", "E", "A"]
    m = vocab_remap(old, new)
    assert m.tolist() == [1, -1, 0]
    assert m.dtype == np.int32


def test_vocab_remap_disjoint_and_empty():
    assert vocab_remap([], ["X"]).tolist() == [-1]
    assert vocab_remap(["X"], []).tolist() == []
    assert vocab_remap(["A"], ["B", "C"]).tolist() == [-1, -1]


def test_token_vocab_alignment(tiny_go):
    """token_vocab names must align with corpus() integer ids."""
    toks = token_vocab(tiny_go)
    _, vocab_size, pad = corpus(tiny_go, jax.random.key(0),
                                walks_per_entity=1, walk_length=2)
    assert len(toks) == vocab_size
    assert toks[pad] == "%pad%"
    assert toks[: tiny_go.num_entities] == tiny_go.entities
    assert toks[tiny_go.num_entities].startswith("%rel%")


# --------------------------- remap_params --------------------------- #
@pytest.mark.parametrize("name", ALL_MODELS)
def test_remap_carries_surviving_rows(name):
    n_old, n_new, n_rel, dim = 12, 13, 3, 8
    old = make_model(name, n_old, n_rel, dim=dim)
    prev = old.init(jax.random.key(0))
    # entity 0 removed, new entity appended at row 5, rest shifted
    e_map = np.asarray([1, 2, 3, 4, -1, 5, 6, 7, 8, 9, 10, 11, -1], np.int32)
    r_map = np.asarray([0, 2, -1], np.int32)
    new = make_model(name, n_new, n_rel, dim=dim)
    params, stats = remap_params(new, jax.random.key(1), prev, e_map, r_map)
    roles = new.param_roles()
    assert stats["entity_carried"] == 11 and stats["entity_fresh"] == 2
    assert stats["tables_carried"] >= 1
    for pname, table in params.items():
        role = roles[pname]
        if role is None:
            continue
        mapping = e_map if role == "entity" else r_map
        prev_t = np.asarray(prev[pname])
        new_t = np.asarray(table)
        assert new_t.shape[0] == len(mapping)
        for i, src in enumerate(mapping):
            if src >= 0:
                np.testing.assert_array_equal(
                    new_t[i], prev_t[src],
                    err_msg=f"{name}.{pname} row {i} (from old {src})")


def test_remap_fresh_rows_differ_from_any_old_row():
    old = make_model("transe", 6, 1, dim=8)
    prev = old.init(jax.random.key(0))
    e_map = np.asarray([0, 1, 2, -1], np.int32)
    new = make_model("transe", 4, 1, dim=8)
    params, _ = remap_params(new, jax.random.key(99), prev, e_map,
                             np.asarray([0], np.int32))
    fresh_row = np.asarray(params["entity"][3])
    for r in np.asarray(prev["entity"]):
        assert not np.allclose(fresh_row, r)


def test_remap_dim_change_falls_back_to_fresh():
    old = make_model("distmult", 5, 2, dim=8)
    prev = old.init(jax.random.key(0))
    new = make_model("distmult", 5, 2, dim=16)
    params, stats = remap_params(new, jax.random.key(1), prev,
                                 np.arange(5, dtype=np.int32),
                                 np.arange(2, dtype=np.int32))
    assert stats["tables_carried"] == 0
    assert params["entity"].shape == (5, 16)


def test_remap_missing_table_is_fresh():
    new = make_model("boxe", 5, 2, dim=8)
    params, stats = remap_params(new, jax.random.key(1), {"entity": np.zeros((5, 8))},
                                 np.arange(5, dtype=np.int32),
                                 np.arange(2, dtype=np.int32))
    assert set(params) == set(new.init(jax.random.key(0)))
    assert stats["tables_carried"] == 1      # only "entity" survived


# --------------------------- warm_init ------------------------------ #
def test_warm_init_beats_fresh_init_loss():
    """A warm-started model must start with a lower training loss on the
    evolved graph than a fresh init — the whole point of carrying params."""
    kg1 = generate(GO_SPEC, seed=3, n_terms=80)
    kg2 = evolve(kg1, GO_SPEC, seed=4)
    cfg = TrainConfig(batch_size=128, num_negs=8, lr=5e-2, seed=0)
    m1 = make_model("transe", kg1.num_entities, kg1.num_relations, dim=16)
    t1 = KGETrainer(m1, cfg)
    prev_params, _, _ = t1.fit(kg1.triples, steps=200)

    m2 = make_model("transe", kg2.num_entities, kg2.num_relations, dim=16)
    t2 = KGETrainer(m2, cfg)
    e_map = vocab_remap(kg1.entities, kg2.entities)
    r_map = vocab_remap(kg1.relations, kg2.relations)
    warm, _, carry = t2.warm_init(prev_params, e_map, r_map)
    assert carry["entity_carried"] >= int(0.9 * kg2.num_entities)
    cold, _ = t2.init()

    _, loss_of = make_train_step(m2, t2.optimizer, cfg)
    key = jax.random.key(42)
    import jax.numpy as jnp
    trips = jnp.asarray(kg2.triples)
    warm_loss = float(loss_of(warm, trips, key))
    cold_loss = float(loss_of(cold, trips, key))
    assert warm_loss < cold_loss


def test_warm_init_rdf2vec_token_carry():
    kg1 = generate(GO_SPEC, seed=3, n_terms=60)
    kg2 = evolve(kg1, GO_SPEC, seed=4)
    toks1, toks2 = token_vocab(kg1), token_vocab(kg2)
    cfg = TrainConfig(batch_size=64, num_negs=4, seed=0)
    m1 = make_model("rdf2vec", len(toks1), 1, dim=8)
    prev = m1.init(jax.random.key(0))
    m2 = make_model("rdf2vec", len(toks2), 1, dim=8)
    t2 = KGETrainer(m2, cfg)
    e_map = vocab_remap(toks1, toks2)
    params, _, carry = t2.warm_init(prev, e_map, np.full(1, -1, np.int32))
    # both SGNS matrices are token-rowed; surviving tokens carry both
    surv = [i for i, s in enumerate(e_map) if s >= 0]
    assert len(surv) >= kg1.num_entities - 5
    i = surv[0]
    np.testing.assert_array_equal(np.asarray(params["entity"][i]),
                                  np.asarray(prev["entity"][e_map[i]]))
    np.testing.assert_array_equal(np.asarray(params["context"][i]),
                                  np.asarray(prev["context"][e_map[i]]))
    # pad token survives by name
    assert e_map[toks2.index("%pad%")] == toks1.index("%pad%")
