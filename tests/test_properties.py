"""Property-based tests on system invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kge.losses import bce, margin_ranking, nssa, softplus_loss
from repro.models.attention import chunked_attention, plain_attention
from repro.models.layers import apply_norm, norm_init, rope_qk


# --------------------- RoPE ---------------------- #
@settings(max_examples=10, deadline=None)
@given(shift=st.integers(0, 512), seed=st.integers(0, 2**16))
def test_rope_relative_position_invariance(shift, seed):
    """RoPE scores depend only on relative positions: shifting q AND k
    positions by the same offset leaves q·k unchanged."""
    ks = jax.random.split(jax.random.key(seed), 2)
    q = jax.random.normal(ks[0], (1, 4, 1, 2, 32))
    k = jax.random.normal(ks[1], (1, 4, 1, 32))
    pos = jnp.arange(4)
    q1, k1 = rope_qk(q, k, pos, pos, 10_000.0)
    q2, k2 = rope_qk(q, k, pos + shift, pos + shift, 10_000.0)
    s1 = jnp.einsum("bqghd,bkgd->bghqk", q1, k1)
    s2 = jnp.einsum("bqghd,bkgd->bghqk", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_rope_preserves_norm():
    q = jax.random.normal(jax.random.key(0), (2, 8, 2, 3, 64))
    k = jax.random.normal(jax.random.key(1), (2, 8, 2, 64))
    pos = jnp.arange(8)
    q2, k2 = rope_qk(q, k, pos, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(q2), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=1e-5)


# --------------------- attention ---------------------- #
@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), s=st.integers(4, 40))
def test_causal_attention_ignores_future(seed, s):
    """Changing k/v at positions > t must not change output at t."""
    ks = jax.random.split(jax.random.key(seed), 3)
    B, G, H, hd = 1, 1, 2, 16
    q = jax.random.normal(ks[0], (B, s, G, H, hd))
    k = jax.random.normal(ks[1], (B, s, G, hd))
    v = jax.random.normal(ks[2], (B, s, G, hd))
    t = s // 2
    out1 = plain_attention(q, k, v, causal=True)
    k2 = k.at[:, t + 1:].set(99.0)
    v2 = v.at[:, t + 1:].set(-99.0)
    out2 = plain_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :t + 1]),
                               np.asarray(out2[:, :t + 1]),
                               rtol=1e-5, atol=1e-5)


def test_attention_output_is_convex_combination():
    """Softmax attention output lies in the convex hull of v rows: within
    [min(v), max(v)] per dim."""
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (1, 16, 1, 1, 8))
    k = jax.random.normal(ks[1], (1, 16, 1, 8))
    v = jax.random.normal(ks[2], (1, 16, 1, 8))
    out = np.asarray(chunked_attention(q, k, v, causal=False, chunk_q=8,
                                       chunk_kv=8))
    vmin, vmax = np.asarray(v).min(), np.asarray(v).max()
    assert (out >= vmin - 1e-5).all() and (out <= vmax + 1e-5).all()


# --------------------- norms ---------------------- #
@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.1, 100.0), seed=st.integers(0, 2**16))
def test_rmsnorm_scale_invariance(scale, seed):
    """RMSNorm(c*x) == RMSNorm(x) for any positive c."""
    p = norm_init(32, jnp.float32)
    x = jax.random.normal(jax.random.key(seed), (2, 5, 32))
    y1 = apply_norm(p, x)
    y2 = apply_norm(p, x * scale)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


# --------------------- KGE losses ---------------------- #
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), margin=st.floats(0.1, 5.0))
def test_margin_loss_zero_when_separated(seed, margin):
    ks = jax.random.split(jax.random.key(seed), 2)
    pos = jax.random.uniform(ks[0], (16,), minval=10.0, maxval=20.0)
    neg = jax.random.uniform(ks[1], (16, 4), minval=-20.0, maxval=-10.0)
    l = margin_ranking(pos, neg, margin=margin)
    assert float(l) == 0.0
    # and positive when inverted
    l2 = margin_ranking(-pos, -neg + 1, margin=margin)
    assert float(l2) > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_losses_monotone_in_pos_score(seed):
    """Every loss decreases (weakly) as the positive score increases."""
    k = jax.random.key(seed)
    neg = jax.random.normal(k, (8, 4))
    lows, highs = jnp.full((8,), -1.0), jnp.full((8,), 3.0)
    for fn in (margin_ranking, nssa, softplus_loss, bce):
        l_low = float(fn(lows, neg))
        l_high = float(fn(highs, neg))
        assert l_high <= l_low + 1e-6, fn.__name__
