"""Wire-codec round trips for every gateway request/response type
(including error payloads), plus the ApiError contract."""
import json

import pytest

from repro.api import schema
from repro.api.schema import (ApiError, AutocompleteRequest,
                              AutocompleteResponse, ClosestConceptsRequest,
                              ClosestConceptsResponse, ConceptHit,
                              DownloadPage, DownloadRequest, GetVectorRequest,
                              HealthRequest, HealthResponse, LineageRequest,
                              LineageResponse, SimilarityRequest,
                              SimilarityResponse, StatsRequest, StatsResponse,
                              VectorResponse, VersionsRequest,
                              VersionsResponse, from_wire, payload_to,
                              to_wire)

HIT = ConceptHit("GO:0000002", "some label", 0.91, "https://x/GO:0000002")

EXAMPLES = [
    GetVectorRequest("go", "transe", "GO:0000001"),
    GetVectorRequest("go", "transe", "kinase", fuzzy=True, version="2024-01"),
    SimilarityRequest("go", "transe", "GO:0000001", "GO:0000002"),
    SimilarityRequest("hp", "rdf2vec", "a", "b", fuzzy=True, version="v3"),
    ClosestConceptsRequest("go", "transe", "GO:0000001", k=25),
    DownloadRequest("go", "transe", version="2024-01", offset=100, limit=50),
    AutocompleteRequest("go", "transe", "posi", limit=5),
    HealthRequest(),
    StatsRequest(),
    VersionsRequest("go"),
    LineageRequest("go", version="2024-02"),
    VectorResponse("go", "transe", "2024-01", "GO:0000001", "lbl",
                   [0.25, -1.5, 3.0]),
    SimilarityResponse("go", "transe", "2024-01", "a", "b", 0.5),
    ClosestConceptsResponse("go", "transe", "2024-01", "GO:0000001", 2,
                            [HIT, ConceptHit("GO:3", "l3", 0.5, "u3")]),
    DownloadPage("go", "transe", "2024-01", offset=0, limit=2, total=5,
                 rows=[["GO:1", [0.1, 0.2]], ["GO:2", [0.3, 0.4]]],
                 next_offset=2, requested_limit=2, etag='"abc123"'),
    DownloadPage("go", "transe", "2024-01", offset=4, limit=2, total=5,
                 rows=[["GO:5", [0.5, 0.5]]], next_offset=None),
    DownloadPage("go", "transe", "2024-01", offset=0, limit=100, total=5000,
                 rows=[], next_offset=100, requested_limit=20_000),
    AutocompleteResponse("go", "transe", "2024-01", "posi", ["positive reg"]),
    HealthResponse("ok", "v1", ["go", "hp"], True),
    StatsResponse({"submitted": 4}, {"hits": 1}, {"requests": 9}),
    StatsResponse({"submitted": 4}, {"hits": 1}, {"requests": 9},
                  latency={"sim": {"count": 2, "p50_ms": 0.5,
                                   "bucket_counts": [0, 2]}}),
    VersionsResponse("go", ["2024-01", "2024-02"], "2024-02", ["transe"]),
    LineageResponse("go", "2024-02",
                    {"transe": {"parent_version": "2024-01",
                                "mode": "incremental", "delta": {"n": 3}},
                     "boxe": None}),
]


@pytest.mark.parametrize("obj", EXAMPLES, ids=lambda o: type(o).__name__)
def test_round_trip_through_json(obj):
    wire = to_wire(obj)
    assert isinstance(wire["type"], str)
    # must survive an actual JSON serialization, not just dict identity
    back = from_wire(json.loads(json.dumps(wire)))
    assert back == obj and type(back) is type(obj)


def test_error_round_trip():
    e = ApiError("UNKNOWN_CLASS", "unknown class(es): 'a', 'b'",
                 details={"missing": ["a", "b"]})
    wire = json.loads(json.dumps(to_wire(e)))
    assert wire["type"] == "error" and wire["status"] == 404
    back = from_wire(wire)
    assert isinstance(back, ApiError)      # returned, not raised
    assert back == e
    assert back.details["missing"] == ["a", "b"]


def test_every_code_has_status_and_legacy_mapping():
    assert set(schema.CODE_STATUS) == {
        "UNKNOWN_ONTOLOGY", "UNKNOWN_MODEL", "UNKNOWN_VERSION",
        "UNKNOWN_CLASS", "NOT_FOUND", "BAD_REQUEST", "TIMEOUT",
        "OVERLOADED", "SHUTTING_DOWN", "INTERNAL",
        "JOB_NOT_FOUND", "JOB_CANCELLED"}
    for code in schema.CODE_STATUS:
        err = ApiError(code, "m")
        assert err.status == schema.CODE_STATUS[code]
        assert isinstance(err.legacy(), Exception)
    assert isinstance(ApiError("UNKNOWN_CLASS", "m").legacy(), KeyError)
    assert isinstance(ApiError("NOT_FOUND", "m").legacy(), KeyError)
    assert ApiError("NOT_FOUND", "m").status == 404
    assert isinstance(ApiError("BAD_REQUEST", "m").legacy(), ValueError)
    assert isinstance(ApiError("TIMEOUT", "m").legacy(), TimeoutError)
    assert isinstance(ApiError("SHUTTING_DOWN", "m").legacy(), RuntimeError)
    assert isinstance(ApiError("OVERLOADED", "m").legacy(), RuntimeError)
    assert ApiError("OVERLOADED", "m").status == 429
    assert isinstance(ApiError("JOB_NOT_FOUND", "m").legacy(), KeyError)
    assert ApiError("JOB_NOT_FOUND", "m").status == 404
    assert isinstance(ApiError("JOB_CANCELLED", "m").legacy(), RuntimeError)
    assert ApiError("JOB_CANCELLED", "m").status == 409
    with pytest.raises(ValueError):
        ApiError("NO_SUCH_CODE", "m")


def test_from_wire_malformed_payloads():
    with pytest.raises(ApiError) as ei:
        from_wire({"no_type": 1})
    assert ei.value.code == "BAD_REQUEST"
    with pytest.raises(ApiError):
        from_wire({"type": "no_such_type"})
    with pytest.raises(ApiError):
        from_wire([1, 2, 3])
    with pytest.raises(ApiError):
        from_wire({"type": "error", "code": 42})
    with pytest.raises(ApiError):
        from_wire({"type": "error", "code": "NOT_A_CODE"})
    # non-dict details / non-int status are BAD_REQUEST, not TypeError
    with pytest.raises(ApiError):
        from_wire({"type": "error", "code": "INTERNAL", "details": 123})
    with pytest.raises(ApiError):
        from_wire({"type": "error", "code": "INTERNAL", "status": {}})
    with pytest.raises(ApiError):
        from_wire({"type": "error", "code": "INTERNAL", "status": True})


def test_payload_to_rejects_unknown_and_missing_fields():
    with pytest.raises(ApiError) as ei:
        payload_to(SimilarityRequest,
                   {"ontology": "go", "model": "m", "a": "x", "b": "y",
                    "bogus": 1})
    assert ei.value.details["unknown_fields"] == ["bogus"]
    with pytest.raises(ApiError) as ei:
        payload_to(SimilarityRequest, {"ontology": "go", "model": "m"})
    assert ei.value.details["missing_fields"] == ["a", "b"]
    # optional fields may be omitted
    req = payload_to(ClosestConceptsRequest,
                     {"ontology": "go", "model": "m", "query": "q"})
    assert req.k == 10 and req.version is None and req.fuzzy is False


def test_nested_hits_reconstructed():
    wire = to_wire(ClosestConceptsResponse("go", "m", "v", "q", 1, [HIT]))
    back = from_wire(json.loads(json.dumps(wire)))
    assert isinstance(back.results[0], ConceptHit)
    assert back.results[0].score == pytest.approx(0.91)
