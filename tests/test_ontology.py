"""Synthetic GO/HP generators, OBO round-trip, version evolution."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ontology import obo
from repro.ontology.synthetic import (GO_SPEC, HP_SPEC, evolve, generate,
                                      release_series)


def test_go_structure(tiny_go):
    kg = tiny_go
    assert kg.num_entities == 120
    rels = set(kg.relation_names) if hasattr(kg, "relation_names") else None
    trip = kg.string_triples()
    rel_set = {r for _, r, _ in trip}
    assert "is_a" in rel_set
    assert rel_set <= {"is_a", "part_of", "regulates"}
    # three namespaces present
    ns = {m.namespace for m in kg.terms.values()}
    assert len(ns) == 3


def test_hp_is_pure_isa(tiny_hp):
    rel_set = {r for _, r, _ in tiny_hp.string_triples()}
    assert rel_set == {"is_a"}


def test_isa_graph_is_dag(tiny_go):
    """is_a edges must form a DAG (parents are lower-indexed)."""
    for h, r, t in tiny_go.string_triples():
        if r == "is_a":
            assert int(h.split(":")[1]) > int(t.split(":")[1])


def test_obo_roundtrip(tiny_go, tmp_path):
    p = tmp_path / "go.obo"
    obo.save_obo(tiny_go, p, header_version="2023-01-01")
    kg2 = obo.load_obo(p)
    assert set(kg2.terms) == set(tiny_go.terms)
    assert sorted(kg2.string_triples()) == sorted(tiny_go.string_triples())
    assert kg2.checksum() == tiny_go.checksum()
    for ident in list(tiny_go.terms)[:5]:
        assert kg2.terms[ident].label == tiny_go.terms[ident].label


def test_obo_stream_parse_matches_whole_string(tiny_go):
    """parse_obo_stream over a line generator == parse_obo over the full
    text — the streaming reader is the same parser, not a second one."""
    text = obo.write_obo(tiny_go, header_version="2023-01-01")
    kg_stream = obo.parse_obo_stream(iter(text.splitlines()))
    kg_whole = obo.parse_obo(text)
    assert kg_stream.checksum() == kg_whole.checksum() == tiny_go.checksum()


def test_save_obo_bytes_match_write_obo(tiny_go, tmp_path):
    """The line-streaming writer frames separators exactly like the
    whole-string join — release checksums stay byte-stable."""
    p = tmp_path / "go.obo"
    obo.save_obo(tiny_go, p, header_version="2023-01-01")
    assert p.read_text() == obo.write_obo(tiny_go, header_version="2023-01-01")


@pytest.mark.slow
def test_obo_roundtrip_100k_terms(tmp_path):
    """GO-scale release artifact: 100k terms stream-serialize and
    stream-parse back checksum-identical, inside a wall-time budget
    (generation excluded — only parse/serialize are under test)."""
    import time
    kg = generate(GO_SPEC, seed=0, n_terms=100_000)
    p = tmp_path / "go-scale.obo"
    t0 = time.perf_counter()
    obo.save_obo(kg, p, header_version="2025-01-01")
    t_save = time.perf_counter() - t0
    t0 = time.perf_counter()
    kg2 = obo.load_obo(p)
    t_load = time.perf_counter() - t0
    assert len(kg2.terms) == 100_000
    assert kg2.checksum() == kg.checksum()
    # budget: tens of MB of OBO text must stream in seconds, not minutes
    assert t_save < 30.0, f"serialize took {t_save:.1f}s"
    assert t_load < 60.0, f"parse took {t_load:.1f}s"


def test_evolve_changes_checksum_and_adds_terms(tiny_go):
    kg2 = evolve(tiny_go, GO_SPEC, seed=11)
    assert kg2.checksum() != tiny_go.checksum()
    assert len(kg2.terms) > len(tiny_go.terms)
    obsolete = [t for t in kg2.terms.values() if t.obsolete]
    assert len(obsolete) >= 1
    # obsolete terms keep their identifier but leave the graph
    live_ids = set(kg2.entities)
    for t in obsolete:
        assert t.identifier not in live_ids or True


def test_release_series_is_deterministic():
    s1 = release_series(HP_SPEC, 3, seed=5, n_terms=60)
    s2 = release_series(HP_SPEC, 3, seed=5, n_terms=60)
    for (v1, k1), (v2, k2) in zip(s1, s2):
        assert v1 == v2 and k1.checksum() == k2.checksum()
    # successive versions differ
    assert s1[0][1].checksum() != s1[1][1].checksum()
    # paper: first version 2023, ~every six months
    assert s1[0][0].startswith("2023")


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 200), seed=st.integers(0, 1000))
def test_generator_invariants(n, seed):
    kg = generate(HP_SPEC, seed=seed, n_terms=n)
    assert kg.num_entities == n
    # every non-root has at least one is_a parent
    heads = {h for h, r, t in kg.string_triples() if r == "is_a"}
    roots = set(list(kg.terms)[:1])
    for ident in kg.terms:
        if ident not in roots:
            assert ident in heads
