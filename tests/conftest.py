import os
import sys
from pathlib import Path

# Tests see the single real CPU device (the dry-run sets its own flags in a
# separate process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# make `import benchmarks.roofline` work regardless of invocation dir
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

# `hypothesis` isn't installed in the container: register a deterministic
# fixed-seed stub so the property-test modules collect and run everywhere
# (see tests/_hypothesis_stub.py). A real install always wins.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", Path(__file__).with_name("_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_go():
    from repro.ontology.synthetic import GO_SPEC, generate
    return generate(GO_SPEC, seed=7, n_terms=120)


@pytest.fixture(scope="session")
def tiny_hp():
    from repro.ontology.synthetic import HP_SPEC, generate
    return generate(HP_SPEC, seed=7, n_terms=80)


@pytest.fixture()
def registry(tmp_path):
    from repro.core.registry import EmbeddingRegistry
    return EmbeddingRegistry(tmp_path / "registry")
