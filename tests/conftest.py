import os
import sys
from pathlib import Path

# Tests see the single real CPU device (the dry-run sets its own flags in a
# separate process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# make `import benchmarks.roofline` work regardless of invocation dir
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_go():
    from repro.ontology.synthetic import GO_SPEC, generate
    return generate(GO_SPEC, seed=7, n_terms=120)


@pytest.fixture(scope="session")
def tiny_hp():
    from repro.ontology.synthetic import HP_SPEC, generate
    return generate(HP_SPEC, seed=7, n_terms=80)


@pytest.fixture()
def registry(tmp_path):
    from repro.core.registry import EmbeddingRegistry
    return EmbeddingRegistry(tmp_path / "registry")
