"""Scheduler stress/property suite for the concurrent serving runtime.

Locks down the PR 2 contract: 16 submitter threads against the background
flush loop — every ticket resolves exactly once, results match the
solo-query oracle for the version pinned at submit, ticket IDs are never
reused, unknown queries fail alone, and an `invalidate()` landing
mid-stream keeps pinned tickets on the old version while post-swap
submissions see the new one (the paper's freshness guarantee, as a test).

Snapshots are published directly (no training) so the whole module stays
inside the fast tier.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.serving import (BatchScheduler, SchedulerError, ServingEngine,
                                Ticket, TopKRequest)

N, D = 48, 12

THREADS = 16
PER_THREAD = 32          # 16 * 32 = 512 requests >= the 500 floor


def _publish(registry, ontology, version, model="transe", n=N, seed=0):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, D)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}-{model}-{seed}",
                     hyperparameters={"dim": D})
    return ids


@pytest.fixture()
def engine(registry):
    ids_go = _publish(registry, "go", "2024-01", "transe", seed=1)
    _publish(registry, "go", "2024-01", "distmult", seed=11)
    _publish(registry, "go", "2024-02", "transe", seed=2)
    _publish(registry, "go", "2024-02", "distmult", seed=12)
    ids_hp = _publish(registry, "hp", "2024-01", "transe", n=N // 2, seed=3)
    eng = ServingEngine(registry, cache_capacity=16)
    return eng, ids_go, ids_hp


def _mixed_request(rng, ids_go, ids_hp):
    """One request drawn from the mixed (ontology, model, version, k) grid,
    with a ~6% chance of an unknown query."""
    ont = "go" if rng.random() < 0.7 else "hp"
    if ont == "go":
        model = "transe" if rng.random() < 0.5 else "distmult"
        version = rng.choice([None, "2024-01", "2024-02"])
        query = ids_go[int(rng.integers(len(ids_go)))]
    else:
        model, version = "transe", None
        query = ids_hp[int(rng.integers(len(ids_hp)))]
    if rng.random() < 0.06:
        query = f"BOGUS:{int(rng.integers(1_000_000)):07d}"
    k = int(rng.choice([3, 5, 10]))
    return TopKRequest(ont, model, query, k, version=version)


# ------------------------------ the stress test ------------------------ #
def test_stress_16_threads_exactly_once_with_midstream_invalidate(
        engine, registry):
    eng, ids_go, ids_hp = engine
    sched = BatchScheduler(eng, max_batch=16, flush_after_ms=1)
    barrier = threading.Barrier(THREADS)
    submitted = [[] for _ in range(THREADS)]   # (ticket, req) per thread
    invalidated = threading.Event()

    def client(tix):
        rng = np.random.default_rng(1000 + tix)
        barrier.wait()
        for j in range(PER_THREAD):
            if tix == 0 and j == PER_THREAD // 2:
                # the one mid-stream invalidate: a new release lands while
                # the other 15 threads keep submitting
                _publish(registry, "go", "2024-03", "transe", seed=4)
                _publish(registry, "go", "2024-03", "distmult", seed=14)
                eng.invalidate("go", "2024-03")
                invalidated.set()
            submitted[tix].append(sched.submit(
                _mixed_request(rng, ids_go, ids_hp)))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert invalidated.is_set()
    # post-swap tickets (submitted after invalidate returned) see 2024-03
    post = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
    assert post.version == "2024-03"
    sched.stop()                    # drains: every ticket resolves

    tickets = [t for per in submitted for t in per] + [post]
    # -- no ticket ID is ever reused, every ticket resolved exactly once --
    assert len(tickets) == THREADS * PER_THREAD + 1
    assert len({t.id for t in tickets}) == len(tickets)
    assert all(t.done() for t in tickets)
    assert sched.stats["submitted"] == len(tickets)
    assert sched.stats["resolved"] == len(tickets)   # _resolve/_reject fired
    assert sched.pending() == 0                      # exactly once each

    # -- results match the solo-query oracle for the pinned version ------ #
    n_failed = n_ok = 0
    for per in submitted:
        for ticket in per:
            err = ticket.exception(timeout=0)
            if err is not None:
                n_failed += 1
                assert "unknown" in err                    # bogus query
                assert ticket.id in sched.errors
                with pytest.raises(SchedulerError):
                    ticket.result(timeout=0)
                continue
            n_ok += 1
    assert n_failed > 0 and n_ok > n_failed           # mix actually mixed
    assert sched.stats["failed"] == n_failed


def test_stress_results_match_solo_oracle(engine):
    """Concurrent results are identical to solo queries pinned to the
    ticket's submit-time version — batching and threading change nothing
    about what a request sees."""
    eng, ids_go, ids_hp = engine
    sched = BatchScheduler(eng, max_batch=16, flush_after_ms=1)
    results = []                                  # (req, ticket) pairs
    lock = threading.Lock()
    barrier = threading.Barrier(8)

    def client(tix):
        rng = np.random.default_rng(2000 + tix)
        barrier.wait()
        mine = []
        for _ in range(16):
            req = _mixed_request(rng, ids_go, ids_hp)
            mine.append((req, sched.submit(req)))
        with lock:
            results.extend(mine)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    sched.stop()

    for req, ticket in results:
        if ticket.exception(timeout=0) is not None:
            continue
        got = [c.identifier for c in ticket.result(timeout=0)]
        oracle = eng.closest_concepts(req.ontology, req.model, req.query,
                                      k=req.k, version=ticket.version)
        assert got == [c.identifier for c in oracle]


# --------------------- update-under-traffic consistency ----------------- #
def test_invalidate_under_traffic_pinned_vs_latest(engine, registry):
    """The paper's freshness guarantee: pinned tickets in flight across an
    `invalidate()` resolve against their old version; tickets submitted
    after the swap see the new one."""
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=32)     # no loop: controlled flush
    q = ids_go[7]
    pinned = [sched.submit(TopKRequest("go", "transe", q, 5,
                                       version="2024-01"))
              for _ in range(4)]
    latest_pre = [sched.submit(TopKRequest("go", "transe", q, 5))
                  for _ in range(4)]
    assert all(t.version == "2024-02" for t in latest_pre)

    # the update lands while all of the above are still queued
    _publish(registry, "go", "2024-03", "transe", seed=4)
    eng.invalidate("go", "2024-03")
    latest_post = [sched.submit(TopKRequest("go", "transe", q, 5))
                   for _ in range(4)]
    assert all(t.version == "2024-03" for t in latest_post)
    sched.flush()

    exp = {v: [c.identifier for c in eng.closest_concepts(
               "go", "transe", q, k=5, version=v)]
           for v in ("2024-01", "2024-02", "2024-03")}
    assert exp["2024-02"] != exp["2024-03"]       # the swap is observable
    for t in pinned:
        assert [c.identifier for c in t.result(timeout=0)] == exp["2024-01"]
    for t in latest_pre:
        assert [c.identifier for c in t.result(timeout=0)] == exp["2024-02"]
    for t in latest_post:
        assert [c.identifier for c in t.result(timeout=0)] == exp["2024-03"]


def test_invalidate_under_loop_traffic(engine, registry):
    """Same guarantee with the background loop racing the updater: a
    continuous stream of latest-pinned tickets across the swap resolves
    against exactly one of {old, new} — the one pinned at submit."""
    eng, ids_go, _ = engine
    q = ids_go[3]
    exp_old = [c.identifier for c in eng.closest_concepts(
        "go", "transe", q, k=5, version="2024-02")]
    with BatchScheduler(eng, max_batch=8, flush_after_ms=1) as sched:
        stream = []
        for i in range(60):
            if i == 30:
                _publish(registry, "go", "2024-03", "transe", seed=4)
                eng.invalidate("go", "2024-03")
            stream.append(sched.submit(TopKRequest("go", "transe", q, 5)))
            if i % 7 == 0:
                time.sleep(0.002)                  # let deadlines fire
    exp_new = [c.identifier for c in eng.closest_concepts(
        "go", "transe", q, k=5, version="2024-03")]
    seen_versions = set()
    for t in stream:
        got = [c.identifier for c in t.result(timeout=10)]
        assert got == (exp_old if t.version == "2024-02" else exp_new)
        seen_versions.add(t.version)
    assert seen_versions == {"2024-02", "2024-03"}   # swap mid-stream


# ------------------------- deadline policy ------------------------------ #
def test_full_batch_flushes_before_deadline(engine):
    """A queue reaching max_batch flushes immediately — well before a long
    deadline — while a lone straggler waits for the deadline."""
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=8, flush_after_ms=2000)
    try:
        t0 = time.monotonic()
        tickets = [sched.submit(TopKRequest("go", "transe", ids_go[i], 5))
                   for i in range(8)]              # exactly max_batch
        for t in tickets:
            t.result(timeout=10)
        assert time.monotonic() - t0 < 1.0         # didn't wait out 2s
        assert sched.stats["full_flushes"] >= 1
    finally:
        sched.stop()


def test_straggler_resolves_at_deadline_without_flush_call(engine):
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=64, flush_after_ms=10)
    try:
        t = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
        res = t.result(timeout=10)                 # nobody calls flush()
        assert len(res) == 5
        assert sched.stats["deadline_flushes"] >= 1
        assert sched.stats["flushes"] == 0         # no manual flush involved
    finally:
        sched.stop()


def test_deadline_update_applies_to_running_loop(engine):
    """start(flush_after_ms=...) on a live loop must take effect
    immediately — the loop re-reads the deadline every pass rather than
    caching it at thread entry."""
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=64, flush_after_ms=5000)
    try:
        t0 = time.monotonic()
        t = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
        sched.start(flush_after_ms=5)         # shrink the 5 s deadline
        assert len(t.result(timeout=10)) == 5
        assert time.monotonic() - t0 < 2.0    # resolved at ~5 ms, not 5 s
    finally:
        sched.stop()


def test_manual_flush_coexists_with_loop(engine):
    """flush() while the loop runs: queues are popped under the lock, so
    each ticket is executed by exactly one drainer."""
    eng, ids_go, _ = engine
    with BatchScheduler(eng, max_batch=16, flush_after_ms=1) as sched:
        tickets = []
        for round_ in range(10):
            tickets += [sched.submit(TopKRequest("go", "transe", ids_go[i], 5))
                        for i in range(8)]
            sched.flush()
        for t in tickets:
            t.result(timeout=10)
    assert sched.stats["resolved"] == sched.stats["submitted"] == len(tickets)


def test_stop_drains_outstanding_tickets(engine):
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=64, flush_after_ms=5000)
    tickets = [sched.submit(TopKRequest("go", "transe", ids_go[i], 5))
               for i in range(5)]
    sched.stop()                                   # deadline far away: drain
    assert all(t.done() for t in tickets)
    assert len(tickets[0].result(timeout=0)) == 5


def test_malformed_query_cannot_kill_the_loop(engine):
    """Regression: a query that makes resolve() *raise* (None isn't a str)
    used to escape _run_queues and kill the daemon thread, stranding every
    other ticket in the drained batch and wedging all later submits. It
    must fail alone, and the loop must keep serving."""
    eng, ids_go, _ = engine
    with BatchScheduler(eng, max_batch=8, flush_after_ms=1) as sched:
        ok1 = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
        poison = sched.submit(TopKRequest("go", "transe", None, 5))
        assert len(ok1.result(timeout=10)) == 5        # same batch survives
        assert "bad query" in poison.exception(timeout=10)
        assert sched.running()                         # daemon still alive
        ok2 = sched.submit(TopKRequest("go", "transe", ids_go[1], 5))
        assert len(ok2.result(timeout=10)) == 5        # loop still serving
    assert sched.stats["resolved"] == sched.stats["submitted"] == 3


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_start_restarts_after_loop_thread_death(engine, monkeypatch):
    """Regression: start() used to check `_thread is not None` rather than
    liveness, so a crashed loop could never be restarted. The injected
    crash deliberately escapes _drain's guard, so pytest's thread-exception
    warning is expected noise here."""
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=8)
    # force the daemon to die instantly on an injected catastrophic bug
    # (SystemExit bypasses even _drain's except-Exception guard)
    monkeypatch.setattr(
        sched, "_drain",
        lambda queues, collect=True: (_ for _ in ()).throw(SystemExit))
    sched.start(flush_after_ms=1)
    sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
    sched._thread.join(timeout=10)
    assert not sched.running()
    monkeypatch.undo()
    sched.start()                                      # dead thread replaced
    assert sched.running()
    t = sched.submit(TopKRequest("go", "transe", ids_go[1], 5))
    assert len(t.result(timeout=10)) == 5              # loop serving again
    sched.stop()


def test_unknown_query_fails_alone_under_loop(engine):
    eng, ids_go, _ = engine
    with BatchScheduler(eng, max_batch=8, flush_after_ms=1) as sched:
        ok = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
        bad = sched.submit(TopKRequest("go", "transe", "GO:9999999", 5))
        bad_ont = sched.submit(TopKRequest("mars", "transe", ids_go[0], 5))
        assert len(ok.result(timeout=10)) == 5
        assert "unknown class" in bad.exception(timeout=10)
        assert "mars" in bad_ont.exception(timeout=10)
    assert sched.stats["failed"] == 2


def test_submit_after_stop_is_rejected_not_stranded(engine):
    """Regression: a submit landing after stop()'s final drain used to
    enqueue into queues nothing would ever flush — the ticket hung
    forever. Executor-shutdown semantics now: reject at submit, and
    start() re-opens intake."""
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=8, flush_after_ms=1)
    ok = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
    sched.stop()
    assert len(ok.result(timeout=0)) == 5
    late = sched.submit(TopKRequest("go", "transe", ids_go[1], 5))
    assert "stopped" in late.exception(timeout=0)      # resolved, not hung
    assert sched.stats["resolved"] == sched.stats["submitted"]
    sched.start()                                      # intake re-opens
    again = sched.submit(TopKRequest("go", "transe", ids_go[1], 5))
    assert len(again.result(timeout=10)) == 5
    sched.stop()


def test_registry_fault_at_submit_keeps_invariant(engine, monkeypatch):
    """Regression: a non-KeyError from latest_version (e.g. an OSError
    from a disk-backed registry) escaped submit() after `submitted` was
    already counted, permanently breaking resolved == submitted."""
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=8)
    monkeypatch.setattr(eng, "latest_version",
                        lambda ont: (_ for _ in ()).throw(OSError("disk")))
    t = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
    assert "disk" in t.exception(timeout=0)
    assert sched.stats["resolved"] == sched.stats["submitted"] == 1
    monkeypatch.undo()
    t2 = sched.submit(TopKRequest("go", "transe", ids_go[0], 5))
    sched.flush()
    assert len(t2.result(timeout=0)) == 5


# ------------------------------ Ticket API ------------------------------ #
def test_ticket_future_api(engine):
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=8)
    t = sched.submit(TopKRequest("go", "transe", ids_go[0], 3))
    assert not t.done() and "pending" in repr(t)
    with pytest.raises(TimeoutError):
        t.result(timeout=0.01)
    res = sched.flush()
    assert t.done() and t.exception() is None and "done" in repr(t)
    assert t.result() == res[t.id]
    # int interop: hashes/compares like its id
    assert t == t.id and hash(t) == hash(t.id) and int(t) == t.id
    assert t in res and res[t] == t.result()
    bad = sched.submit(TopKRequest("go", "transe", "NOPE", 3))
    sched.flush()
    assert "failed" in repr(bad)
    assert bad < sched.submit(TopKRequest("go", "transe", ids_go[0], 3))


def test_start_requires_deadline_and_is_idempotent(engine):
    eng, ids_go, _ = engine
    sched = BatchScheduler(eng, max_batch=8)
    with pytest.raises(ValueError):
        sched.start()
    sched.start(flush_after_ms=1)
    sched.start()                                  # idempotent while running
    assert sched.running()
    sched.stop()
    assert not sched.running()
    with pytest.raises(ValueError):
        BatchScheduler(eng, flush_after_ms=-1)
