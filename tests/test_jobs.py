"""Async batch-analytics job subsystem (PR 9 tentpole).

Covers the job lifecycle end to end: submit → poll → page/stream result
for all three workloads (bulk kNN join, cross-version drift, model
compare), result parity with the serial per-query oracle, the error
taxonomy (JOB_NOT_FOUND / JOB_CANCELLED / BAD_REQUEST / OVERLOADED)
counted exactly once through both ``Gateway.handle`` and HTTP, a
16-client poll storm against one running bulk job (exactly-once
materialization, monotone progress), cancellation mid-slab, and — slow
tier — a SIGKILL'd multi-process worker whose orphaned job reads FAILED
instead of hanging pollers.
"""
import http.client
import json
import os
import signal
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.api import ApiError, Gateway, serve_http
from repro.core.serving import ServingEngine

REPO = Path(__file__).resolve().parents[1]
N, D = 40, 12


def _publish(registry, ontology, version, model="transe", n=N, seed=0):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, D)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}-{seed}",
                     hyperparameters={"dim": D})
    return ids


@pytest.fixture()
def gw(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    _publish(registry, "go", "2024-02", seed=2)
    engine = ServingEngine(registry, cache_capacity=4)
    gateway = Gateway(engine)
    yield gateway, engine, ids
    gateway.close()


def _slow_gw(registry, ids=None, **kw):
    """A gateway whose jobs crawl: tiny slabs + a large inter-slab yield
    make RUNNING observable and give cancels/storms slabs to land in."""
    engine = ServingEngine(registry, cache_capacity=4)
    kw.setdefault("jobs_slab", 4)
    kw.setdefault("jobs_yield_s", 0.03)
    return Gateway(engine, **kw)


# ------------------------- workload correctness ------------------------ #
def test_knn_join_matches_serial_oracle(gw):
    gateway, engine, ids = gw
    sub = gateway.submit_job("knn-join", "go", model="transe",
                             classes=ids, k=5)
    assert sub.state in ("PENDING", "RUNNING")
    st = gateway.job_wait(sub.job_id, timeout=60)
    assert st.state == "DONE" and st.progress == 1.0
    assert st.total == len(ids) and st.wall_s is not None
    assert st.summary["n_queries"] == len(ids)
    page = gateway.job_result(sub.job_id, limit=len(ids))
    assert page.total == len(ids) and page.next_offset is None
    idx = engine._index("go", "transe")
    for ident, neighbors in page.rows:
        oracle = idx.top_k([ident], k=5)[0]
        assert [n[0] for n in neighbors] == [c.identifier for c in oracle]
        assert [n[1] for n in neighbors] == [c.score for c in oracle]


def test_drift_matches_manual_jaccard(gw):
    gateway, engine, ids = gw
    k = 5
    sub = gateway.submit_job("drift", "go", model="transe", k=k)
    st = gateway.job_wait(sub.job_id, timeout=60)
    assert st.state == "DONE"
    # default pair: previous release vs latest
    assert st.version == "2024-01" and st.version_b == "2024-02"
    page = gateway.job_result(sub.job_id, limit=N)
    idx_a = engine._index("go", "transe", "2024-01")
    idx_b = engine._index("go", "transe", "2024-02")
    got = dict(page.rows)
    for ident in ids:
        sa = {c.identifier for c in idx_a.top_k([ident], k=k)[0]}
        sb = {c.identifier for c in idx_b.top_k([ident], k=k)[0]}
        expect = len(sa & sb) / len(sa | sb)
        assert got[ident] == pytest.approx(expect)
    assert st.summary["n_common"] == N
    # the summary value is rounded for the wire — compare to its precision
    assert st.summary["mean_jaccard"] == pytest.approx(
        float(np.mean(list(got.values()))), abs=1e-6)


def test_compare_without_stored_graph_reports_skip(gw):
    gateway, _, _ = gw
    sub = gateway.submit_job("compare", "go")
    st = gateway.job_wait(sub.job_id, timeout=60)
    assert st.state == "DONE"
    page = gateway.job_result(sub.job_id)
    # no graph stored for the synthetic publish: every model row is
    # present but metric-less, and the summary says why
    assert [r[0] for r in page.rows] == ["transe"]
    assert page.rows[0][1] is None
    assert st.summary["skipped"] == 1 and "note" in st.summary


def test_submit_validation(gw):
    gateway, _, ids = gw
    with pytest.raises(ApiError) as e:
        gateway.submit_job("frobnicate", "go")
    assert e.value.code == "BAD_REQUEST"
    with pytest.raises(ApiError) as e:
        gateway.submit_job("knn-join", "go", model="transe", classes=[])
    assert e.value.code == "BAD_REQUEST"
    with pytest.raises(ApiError) as e:
        gateway.submit_job("knn-join", "go", model="nope", classes=ids[:2])
    assert e.value.code == "UNKNOWN_MODEL"
    # unknown classes fail the job (not the submit — resolution happens
    # on the executor), with the missing list in the error
    sub = gateway.submit_job("knn-join", "go", model="transe",
                             classes=["GO:9999999"])
    st = gateway.job_wait(sub.job_id, timeout=30)
    assert st.state == "FAILED" and "UNKNOWN_CLASS" in st.error


# --------------------------- error taxonomy ---------------------------- #
def test_taxonomy_through_handle_counted_once(gw):
    gateway, _, ids = gw
    wire = gateway.handle("jobs/j0-404")
    assert wire["type"] == "error" and wire["code"] == "JOB_NOT_FOUND"
    assert wire["status"] == 404
    sub = gateway.submit_job("knn-join", "go", model="transe",
                             classes=ids[:3], k=3)
    gateway.job_wait(sub.job_id, timeout=30)
    wire = gateway.handle(f"jobs/{sub.job_id}/cancel")
    assert wire["code"] == "BAD_REQUEST" and wire["status"] == 400
    by_code = gateway.stats().gateway["by_code"]
    assert by_code["JOB_NOT_FOUND"] == 1
    assert by_code["BAD_REQUEST"] == 1


def test_result_of_cancelled_job_is_job_cancelled(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    gateway = _slow_gw(registry)
    try:
        sub = gateway.submit_job("knn-join", "go", model="transe",
                                 classes=ids, k=3)
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            st = gateway.job_status(sub.job_id)
            if st.state == "RUNNING" and st.progress > 0:
                break
            time.sleep(0.002)
        else:
            pytest.fail("job never observed RUNNING")
        gateway.job_cancel(sub.job_id)
        st = gateway.job_wait(sub.job_id, timeout=30)
        # cancelled mid-slab: terminal, partial progress, no result
        assert st.state == "CANCELLED"
        assert 0 < st.progress < 1.0
        with pytest.raises(ApiError) as e:
            gateway.job_result(sub.job_id)
        assert e.value.code == "JOB_CANCELLED" and e.value.status == 409
    finally:
        gateway.close()


def test_queue_overflow_fast_rejects(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    gateway = _slow_gw(registry, max_jobs_queued=1)
    try:
        first = gateway.submit_job("knn-join", "go", model="transe",
                                   classes=ids, k=3)
        # wait for the executor to claim the first job, so the next
        # submit is the only PENDING one and the one after must reject
        deadline = time.monotonic() + 30
        while gateway.job_status(first.job_id).state == "PENDING":
            assert time.monotonic() < deadline
            time.sleep(0.002)
        gateway.submit_job("knn-join", "go", model="transe",
                           classes=ids[:4], k=3)
        with pytest.raises(ApiError) as e:
            gateway.submit_job("knn-join", "go", model="transe",
                               classes=ids[:4], k=3)
        assert e.value.code == "OVERLOADED" and e.value.status == 429
        assert e.value.details["retry_after_s"] > 0
        assert gateway.jobs.stats()["rejected_overloaded"] == 1
    finally:
        gateway.close()


# ------------------------ poll storm / cancellation -------------------- #
def test_poll_storm_exactly_once_and_monotone_progress(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    gateway = _slow_gw(registry)
    try:
        sub = gateway.submit_job("knn-join", "go", model="transe",
                                 classes=ids, k=5)
        results, errs = [], []
        lock = threading.Lock()

        def poller():
            try:
                seen = []
                while True:
                    st = gateway.job_status(sub.job_id)
                    seen.append(st.progress)
                    if st.state in ("DONE", "FAILED", "CANCELLED"):
                        break
                    time.sleep(0.001)
                # progress is monotone non-decreasing for every client
                assert seen == sorted(seen)
                assert st.state == "DONE"
                page = gateway.job_result(sub.job_id, limit=N)
                with lock:
                    results.append(json.dumps(page.rows, sort_keys=True))
            except Exception as e:                 # pragma: no cover
                with lock:
                    errs.append(e)

        threads = [threading.Thread(target=poller) for _ in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errs
        # exactly-once materialization: one completed run, every client
        # read the same bytes
        assert len(set(results)) == 1 and len(results) == 16
        assert gateway.jobs.stats()["completed"] == 1
        assert gateway.job_status(sub.job_id).summary["slabs"] == \
            (N + 3) // 4
    finally:
        gateway.close()


# ------------------------------ HTTP layer ----------------------------- #
def _http(port, method, path, body=None, headers=None):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    try:
        conn.request(method, path,
                     body=json.dumps(body) if body is not None else None,
                     headers=headers or {})
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


def test_http_job_roundtrip_etag_stream_and_taxonomy(gw):
    gateway, _, ids = gw
    server = serve_http(gateway, port=0)
    try:
        port = server.port
        st, _, body = _http(port, "POST", "/jobs/submit",
                            {"kind": "knn-join", "ontology": "go",
                             "model": "transe", "classes": ids[:8], "k": 3})
        assert st == 200
        jid = json.loads(body)["job_id"]
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            st, _, body = _http(port, "GET", f"/jobs/{jid}")
            if json.loads(body)["state"] == "DONE":
                break
            time.sleep(0.01)
        assert json.loads(body)["state"] == "DONE"
        # page + strong ETag; If-None-Match revalidates to a bodyless 304
        st, hdr, body = _http(port, "GET", f"/jobs/{jid}/result?limit=5")
        assert st == 200 and hdr.get("ETag")
        page = json.loads(body)
        assert page["type"] == "job_result_page" and page["total"] == 8
        assert len(page["rows"]) == 5 and page["next_offset"] == 5
        st2, hdr2, body2 = _http(port, "GET", f"/jobs/{jid}/result?limit=5",
                                 headers={"If-None-Match": hdr["ETag"]})
        assert st2 == 304 and body2 == b""
        assert hdr2.get("ETag") == hdr["ETag"]
        # chunked stream: the whole row set as one JSON array
        st3, hdr3, body3 = _http(port, "GET",
                                 f"/jobs/{jid}/result?stream=true")
        assert st3 == 200
        assert hdr3.get("X-Bio-KGvec2go-Kind") == "knn-join"
        rows = json.loads(body3)
        assert rows == page["rows"] + json.loads(
            _http(port, "GET", f"/jobs/{jid}/result?offset=5")[2])["rows"]
        # taxonomy over HTTP: real status lines, counted exactly once
        before = json.loads(_http(port, "GET", "/stats")[2])
        st4, _, body4 = _http(port, "GET", "/jobs/j0-404")
        assert st4 == 404
        assert json.loads(body4)["code"] == "JOB_NOT_FOUND"
        st5, _, body5 = _http(port, "POST", f"/jobs/{jid}/cancel", {})
        assert st5 == 400
        assert json.loads(body5)["code"] == "BAD_REQUEST"
        after = json.loads(_http(port, "GET", "/stats")[2])
        b0 = before["gateway"]["by_code"]
        b1 = after["gateway"]["by_code"]
        assert b1.get("JOB_NOT_FOUND", 0) == b0.get("JOB_NOT_FOUND", 0) + 1
        assert b1.get("BAD_REQUEST", 0) == b0.get("BAD_REQUEST", 0) + 1
        assert after["gateway"]["jobs"]["completed"] == 1
    finally:
        server.close()


def test_async_gateway_submit_wait_result(gw):
    import asyncio

    from repro.api.aio import AsyncGateway
    gateway, _, ids = gw

    async def main():
        ag = AsyncGateway(gateway)
        sub = await ag.submit_job("knn-join", "go", model="transe",
                                  classes=ids[:6], k=3)
        st = await ag.job_wait(sub.job_id, timeout=60)
        page = await ag.job_result(sub.job_id)
        listed = await ag.jobs_list()
        return st, page, listed

    st, page, listed = asyncio.run(main())
    assert st.state == "DONE"
    assert page.total == 6 and len(page.rows) == 6
    assert [j.job_id for j in listed.jobs] == [st.job_id]


# ----------------------- multi-process orphan rule --------------------- #
@pytest.mark.slow
def test_sigkilled_worker_reports_orphaned_job_failed(tmp_path):
    """SIGKILL the worker that owns a RUNNING job: a surviving sibling
    (or the supervisor's replacement) must answer polls with FAILED —
    never hang them, never resurrect the job."""
    from repro.core.registry import EmbeddingRegistry
    n = 256
    rng = np.random.default_rng(0)
    root = tmp_path / "reg"
    registry = EmbeddingRegistry(root)
    ids = [f"GO:{i:07d}" for i in range(n)]
    registry.publish("go", "2024-01", "transe", ids,
                     [f"t{i}" for i in range(n)],
                     rng.standard_normal((n, D)).astype(np.float32),
                     ontology_checksum="ck", hyperparameters={"dim": D})
    registry.seal("go", "2024-01")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.api.workers", "--registry", str(root),
         "--workers", "2", "--stats-interval-ms", "200"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
        cwd=str(REPO))
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("READY"), proc.stderr.read()
        port = int(line.split("port=")[1].split()[0])

        def poll(path, method="GET", body=None):
            # the killed worker's accept queue drops connections; retry
            # onto a live sibling
            for _ in range(50):
                try:
                    return _http(port, method, path, body)
                except OSError:
                    time.sleep(0.05)
            raise AssertionError("pool stopped answering")

        # a join big enough to still be RUNNING when the SIGKILL lands
        st, _, body = poll("/jobs/submit", "POST",
                           {"kind": "knn-join", "ontology": "go",
                            "model": "transe", "classes": ids * 250,
                            "k": 10})
        assert st == 200, body
        job = json.loads(body)
        jid, owner = job["job_id"], job["owner_pid"]
        assert owner in (int(p) for p in
                         line.split("pids=")[1].split()[0].split(","))
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            state = json.loads(poll(f"/jobs/{jid}")[2])["state"]
            if state == "RUNNING":
                break
            assert state == "PENDING", state
            time.sleep(0.01)
        os.kill(owner, signal.SIGKILL)
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            st, _, body = poll(f"/jobs/{jid}")
            job = json.loads(body)
            if job["state"] == "FAILED":
                break
            time.sleep(0.05)
        assert job["state"] == "FAILED"
        assert "died" in job["error"]
        # the failure is sticky: a later poll still reads FAILED, and
        # the result route answers the structured per-state error
        assert json.loads(poll(f"/jobs/{jid}")[2])["state"] == "FAILED"
        st, _, body = poll(f"/jobs/{jid}/result")
        assert st == 400
        assert json.loads(body)["details"]["state"] == "FAILED"
    finally:
        proc.send_signal(signal.SIGTERM)
        try:
            proc.wait(timeout=20)
        except subprocess.TimeoutExpired:
            proc.kill()
