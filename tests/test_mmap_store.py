"""Raw mmap snapshot layout (PR 6 tentpole, layer 1).

Every publish writes a serve-optimized sidecar next to the ``.npz``
interchange file: ``table.f32`` (rows padded to a 64-byte stride +
per-row float32 L2 norms) and ``table.json`` (geometry + ids/labels).
These tests pin the layout contract: bit-parity with the npz payload,
read-only enforcement on the views, truncation detection, seal markers,
raw-first/npz-fallback in ``get_serving``, and that dropping a version
actually releases the map so the files can be reclaimed.
"""
import gc
import json
import weakref

import numpy as np
import pytest

from repro.checkpoint.store import (RAW_ALIGN, RAW_FORMAT, RAW_HEADER,
                                    RAW_TABLE, SEAL_MARKER)
from repro.core.serving import EmbeddingIndex, ServingEngine

N, D = 40, 12


def _publish(registry, ontology, version, model="transe", n=N, d=D, seed=0):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, d)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}",
                     hyperparameters={"dim": d})
    return ids, labels, emb


# ------------------------- layout contract ---------------------------- #
def test_publish_writes_raw_layout(registry):
    _publish(registry, "go", "2024-01")
    store = registry.store
    assert store.has_raw("go", "2024-01", "transe")
    d = store._dir("go", "2024-01", "transe")
    header = json.loads((d / RAW_HEADER).read_text())
    assert header["format"] == RAW_FORMAT
    assert header["rows"] == N and header["dim"] == D
    assert header["align_bytes"] == RAW_ALIGN
    # stride: rows pad up to the next 64-byte multiple
    stride = header["stride_floats"]
    assert stride * 4 % RAW_ALIGN == 0 and stride >= D
    assert header["norms_offset_floats"] == N * stride
    # file holds exactly the padded table + the norms vector
    assert (d / RAW_TABLE).stat().st_size == (N * stride + N) * 4


def test_raw_npz_bit_parity(registry):
    ids, labels, emb = _publish(registry, "go", "2024-01", seed=3)
    table, norms, header = registry.store.open_table("go", "2024-01",
                                                     "transe")
    # the table view is the npz payload, bit for bit
    np.testing.assert_array_equal(np.asarray(table), emb)
    # norms match what the serve path used to compute at load time
    np.testing.assert_array_equal(
        np.asarray(norms), np.linalg.norm(emb, axis=1).astype("<f4"))
    assert header["ids"] == ids and header["labels"] == labels
    # both views are windows over ONE map (shared pages, one munmap)
    assert isinstance(table.base, np.ndarray) or isinstance(
        table.base, np.memmap)
    assert table.base.base is norms.base or table.base is norms.base


def test_open_table_is_read_only(registry):
    _publish(registry, "go", "2024-01")
    table, norms, _ = registry.store.open_table("go", "2024-01", "transe")
    with pytest.raises(ValueError):
        table[0, 0] = 1.0
    with pytest.raises(ValueError):
        norms[0] = 1.0


def test_truncated_table_detected(registry):
    _publish(registry, "go", "2024-01")
    d = registry.store._dir("go", "2024-01", "transe")
    raw = (d / RAW_TABLE).read_bytes()
    (d / RAW_TABLE).write_bytes(raw[: len(raw) // 2])
    with pytest.raises(ValueError, match="truncated"):
        registry.store.open_table("go", "2024-01", "transe")


def test_unknown_format_rejected(registry):
    _publish(registry, "go", "2024-01")
    d = registry.store._dir("go", "2024-01", "transe")
    header = json.loads((d / RAW_HEADER).read_text())
    header["format"] = "biokg-raw-v999"
    (d / RAW_HEADER).write_text(json.dumps(header))
    with pytest.raises(ValueError, match="unknown raw layout"):
        registry.store.open_table("go", "2024-01", "transe")


# ------------------------ serve-path loading -------------------------- #
def test_get_serving_prefers_raw(registry):
    ids, labels, emb = _publish(registry, "go", "2024-01", seed=5)
    gids, glabels, table, norms, meta = registry.get_serving("go", "transe")
    assert gids == ids and glabels == labels
    assert isinstance(table.base, np.ndarray)   # memmap view, not a copy
    np.testing.assert_array_equal(np.asarray(table), emb)
    assert meta["prov"]


def test_get_serving_npz_fallback_bit_identical(registry):
    """Pre-raw snapshots (older publishes) still serve — same numbers."""
    _publish(registry, "go", "2024-01", seed=6)
    raw = registry.get_serving("go", "transe")
    d = registry.store._dir("go", "2024-01", "transe")
    (d / RAW_TABLE).unlink()
    (d / RAW_HEADER).unlink()
    assert not registry.store.has_raw("go", "2024-01", "transe")
    fb = registry.get_serving("go", "transe")
    assert fb[0] == raw[0] and fb[1] == raw[1]
    np.testing.assert_array_equal(np.asarray(fb[2]), np.asarray(raw[2]))
    np.testing.assert_array_equal(np.asarray(fb[3]), np.asarray(raw[3]))


def test_embedding_index_zero_copy_over_mmap(registry):
    """The serving index keeps the memmap as its table — no private
    full-table copy — and unit rows match the eager normalize."""
    _, _, emb = _publish(registry, "go", "2024-01", seed=7)
    ids, labels, table, norms, _ = registry.get_serving("go", "transe")
    idx = EmbeddingIndex(ids, labels, table, norms=norms)
    # same pages, not a private copy
    assert np.shares_memory(idx.embeddings, table)
    assert np.shares_memory(idx.norms, norms)
    eager = emb / np.linalg.norm(emb, axis=1, keepdims=True)
    got = idx.unit_rows(list(range(N)))
    np.testing.assert_array_equal(
        got, (emb / np.maximum(np.linalg.norm(emb, axis=1,
                                              keepdims=True), 1e-12)))
    assert np.allclose(got, eager, atol=1e-7)


# --------------------- sorted-label sidecar (PR 8) --------------------- #
def test_header_carries_sorted_labels(registry):
    """Publish persists the sorted-normalized-label array so per-worker
    load skips the per-process re-sort at 100k-label scale."""
    from repro.checkpoint.store import norm_label
    _, labels, _ = _publish(registry, "go", "2024-01")
    d = registry.store._dir("go", "2024-01", "transe")
    header = json.loads((d / RAW_HEADER).read_text())
    assert header["sorted_labels"] == sorted({norm_label(x) for x in labels})
    # and get_serving forwards it through meta
    *_, meta = registry.get_serving("go", "transe")
    assert meta["sorted_labels"] == header["sorted_labels"]


def test_index_adopts_sidecar_sort_order(registry):
    """The engine-built index uses the persisted array verbatim; answers
    match an index that re-sorted from scratch."""
    ids, labels, _ = _publish(registry, "go", "2024-01", seed=9)
    engine = ServingEngine(registry)
    idx = engine._index("go", "transe")
    *_, meta = registry.get_serving("go", "transe")
    assert idx._sorted_labels == meta["sorted_labels"]
    _, _, table, norms, _ = registry.get_serving("go", "transe")
    fresh = EmbeddingIndex(ids, labels, table, norms=norms)
    assert idx._sorted_labels == fresh._sorted_labels
    assert idx.autocomplete("go term 1", limit=5) == \
        fresh.autocomplete("go term 1", limit=5)


def test_stale_sidecar_length_falls_back_to_resort(registry):
    """A sidecar whose length disagrees with the label set (e.g. written
    by a pre-dedup publisher) is ignored, not trusted."""
    ids, labels, _ = _publish(registry, "go", "2024-01")
    _, _, table, norms, _ = registry.get_serving("go", "transe")
    bogus = ["aaa"]                       # wrong length on purpose
    idx = EmbeddingIndex(ids, labels, table, norms=norms,
                         sorted_labels=bogus)
    fresh = EmbeddingIndex(ids, labels, table, norms=norms)
    assert idx._sorted_labels == fresh._sorted_labels


# ---------------------------- seal markers ---------------------------- #
def test_seal_and_sealed_versions(registry):
    _publish(registry, "go", "2024-01")
    _publish(registry, "go", "2024-02")
    assert registry.store.sealed_versions("go") == []
    registry.seal("go", "2024-01")
    assert registry.store.is_sealed("go", "2024-01")
    assert not registry.store.is_sealed("go", "2024-02")
    assert registry.store.sealed_versions("go") == ["2024-01"]
    registry.seal("go", "2024-02")
    assert registry.store.sealed_versions("go") == ["2024-01", "2024-02"]
    marker = json.loads(
        (registry.store.root / "go" / "2024-02" / SEAL_MARKER).read_text())
    assert marker["models"] == ["transe"]


# ------------------------ stale-mmap reclamation ----------------------- #
def test_drop_version_releases_mmap(registry):
    """After invalidate + drop_version, no live view pins the old map —
    the GC closes it and the snapshot files are reclaimable."""
    ids, _, _ = _publish(registry, "go", "2024-01", seed=1)
    engine = ServingEngine(registry, cache_capacity=4)
    engine.similarity("go", "transe", ids[0], ids[1])   # builds the index
    old = engine.cache.get(("go", "transe", "2024-01"))
    assert old is not None
    ref = weakref.ref(old.embeddings)
    del old

    _publish(registry, "go", "2024-02", seed=2)
    engine.invalidate("go", "2024-02")
    dropped = engine.drop_version("go", "2024-01")
    assert dropped == 1
    assert ("go", "transe", "2024-01") not in engine.cache
    gc.collect()
    assert ref() is None, "stale mmap still referenced after drop_version"
    # the files are now unlinkable and the version dir fully removable
    d = registry.store._dir("go", "2024-01", "transe")
    (d / RAW_TABLE).unlink()
    assert not (d / RAW_TABLE).exists()
    # serving continues on the new version
    assert engine.latest_version("go") == "2024-02"
    assert isinstance(engine.similarity("go", "transe", ids[0], ids[1]),
                      float)
