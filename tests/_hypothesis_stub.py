"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The container has no network, so the property-test dependency can't be
pip-installed. This stub keeps the property tests *running* instead of
failing at collection: each ``@given`` test becomes a deterministic
fixed-seed example sweep — strategies turn into samplers over one shared
numpy Generator and the test body runs ``max_examples`` times (clamped to
``REPRO_STUB_EXAMPLES``, default 8, since there's no shrinking/database to
amortize the cost).

Only the API surface these tests use is implemented: ``given`` (keyword
strategies), ``settings(max_examples=..., deadline=...)``, ``assume`` and
``strategies.{integers,floats,sampled_from,booleans}``.
"""
from __future__ import annotations

import functools
import inspect
import os
import types

import numpy as np

_DEFAULT_EXAMPLES = int(os.environ.get("REPRO_STUB_EXAMPLES", "8"))
_SEED = 0xB10B5


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _sampled_from(elements):
    elems = list(elements)
    return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans


class _Rejected(Exception):
    pass


def assume(condition):
    if not condition:
        raise _Rejected
    return True


def given(*args, **strats):
    if args:
        raise NotImplementedError("stub `given` supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            n = min(getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES),
                    _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(_SEED)
            for _ in range(n):
                drawn = {name: s.sample(rng) for name, s in strats.items()}
                try:
                    fn(*a, **drawn, **kw)
                except _Rejected:
                    continue
        # pytest plugins (e.g. anyio) probe `fn.hypothesis.inner_test`
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn, stub=True)
        # hide strategy params from pytest's fixture resolution; remaining
        # params (real fixtures) are still requested normally
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return deco


def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
