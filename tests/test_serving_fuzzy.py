"""Paper §6 future work, implemented: autocomplete + typo tolerance."""
import numpy as np
import pytest

from repro.core.serving import EmbeddingIndex, _edit_distance_capped


@pytest.fixture()
def index():
    rng = np.random.default_rng(0)
    ids = ["GO:0000001", "GO:0000002", "GO:0000003", "GO:0000004"]
    labels = ["positive regulation of pathway",
              "positive regulation of process",
              "negative binding of receptor",
              "membrane transport activity"]
    emb = rng.standard_normal((4, 8)).astype(np.float32)
    return EmbeddingIndex(ids, labels, emb)


def test_edit_distance():
    assert _edit_distance_capped("kinase", "kinase", 2) == 0
    assert _edit_distance_capped("kinase", "kinsae", 2) == 2
    assert _edit_distance_capped("kinase", "kinases", 2) == 1
    assert _edit_distance_capped("abc", "xyz", 2) == 3      # capped at cap+1
    assert _edit_distance_capped("short", "muchlongerstring", 2) == 3


def test_autocomplete(index):
    out = index.autocomplete("positive reg")
    assert out == ["positive regulation of pathway",
                   "positive regulation of process"]
    assert index.autocomplete("  POSITIVE ") == out        # normalized
    assert index.autocomplete("zzz") == []
    assert len(index.autocomplete("", limit=3)) == 3


def test_fuzzy_resolve_typos(index):
    # one substitution
    row = index.resolve("positive regulation of pathwey", fuzzy=True)
    assert index.labels[row] == "positive regulation of pathway"
    # transposition = 2 edits
    row = index.resolve("membrane transport activiyt", fuzzy=True)
    assert index.labels[row] == "membrane transport activity"
    # too far
    assert index.resolve("completely different thing", fuzzy=True) is None
    # exact ids and exact labels still work without fuzz
    assert index.resolve("GO:0000003") == 2
    assert index.resolve("positive regulation of pathwey") is None  # strict


def test_fuzzy_engine_endpoints(registry, tiny_go):
    from repro.core.serving import ServingEngine
    from repro.core.updater import Updater
    from repro.kge.train import TrainConfig
    upd = Updater(registry, models=("transe",), dim=8,
                  train_cfg=TrainConfig(batch_size=64, num_negs=4),
                  steps_override=5)

    class Ch:
        name = "go"
        def latest(self):
            return "v1", tiny_go
    upd.run_once(Ch())
    engine = ServingEngine(registry)

    label = tiny_go.terms[tiny_go.entities[5]].label
    typo = label[:-1] + ("x" if label[-1] != "x" else "y")
    s_exact = engine.similarity("go", "transe", label, tiny_go.entities[6])
    s_fuzzy = engine.similarity("go", "transe", typo, tiny_go.entities[6],
                                fuzzy=True)
    assert s_exact == s_fuzzy
    with pytest.raises(KeyError):
        engine.similarity("go", "transe", typo, tiny_go.entities[6])

    top = engine.closest_concepts("go", "transe", typo, k=3, fuzzy=True)
    assert len(top) == 3

    ac = engine.autocomplete("go", "transe", label.split()[0][:4], limit=5)
    assert any(a.startswith(label.split()[0][:4]) for a in ac)
