"""The six KGE models: shapes, scoring semantics, training, eval."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kge import available_models, make_model
from repro.kge.eval import rank_based_eval
from repro.kge.train import KGETrainer, TrainConfig

SIX = ("transe", "transr", "distmult", "hole", "boxe", "rdf2vec")


def test_all_six_paper_models_registered():
    assert set(SIX) <= set(available_models())


@pytest.mark.parametrize("name", SIX)
def test_init_and_score_shapes(name):
    m = make_model(name, n_entities=50, n_relations=4, dim=16)
    params = m.init(jax.random.key(0))
    for v in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(v, np.float32)).all()
    h = jnp.array([0, 1, 2])
    r = jnp.array([0, 1, 0])
    t = jnp.array([3, 4, 5])
    s = m.score(params, h, r, t)
    assert s.shape == (3,)
    assert np.isfinite(np.asarray(s)).all()
    # 1-vs-all fast path agrees with elementwise score
    all_t = m.score_all_tails(params, h, r)
    assert all_t.shape == (3, 50)
    np.testing.assert_allclose(
        np.asarray(all_t[jnp.arange(3), t]), np.asarray(s), rtol=1e-4,
        atol=1e-4)
    emb = m.entity_embeddings(params)
    assert emb.shape[0] == 50


@pytest.mark.slow
@pytest.mark.parametrize("name", SIX)
def test_training_reduces_loss(name, tiny_go):
    kg = tiny_go
    m = make_model(name, kg.num_entities, max(kg.num_relations, 1), dim=16)
    cfg = TrainConfig(batch_size=64, num_negs=8, lr=5e-2, epochs=1, seed=3)
    trainer = KGETrainer(m, cfg)
    params, opt_state = trainer.init()
    key = jax.random.key(0)
    first = last = None
    triples = jnp.asarray(kg.triples[:64])
    loss_of = trainer._loss_of
    first = float(loss_of(params, triples, key))
    params, _, stats = trainer.fit(kg.triples, params=params,
                                   opt_state=opt_state, steps=60)
    last = float(loss_of(params, triples, key))
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, (name, first, last)


@pytest.mark.slow
def test_transe_translational_geometry():
    """After training, linked pairs should score above random pairs."""
    rng = np.random.default_rng(0)
    n = 40
    triples = np.stack([np.arange(n - 1), np.zeros(n - 1, np.int64),
                        np.arange(1, n)], axis=1)
    m = make_model("transe", n, 1, dim=16)
    trainer = KGETrainer(m, TrainConfig(batch_size=39, num_negs=16, lr=5e-2))
    params, _, _ = trainer.fit(triples, steps=150)
    pos = m.score(params, triples[:, 0], triples[:, 1], triples[:, 2])
    neg_t = rng.integers(0, n, n - 1)
    neg = m.score(params, triples[:, 0], triples[:, 1], jnp.asarray(neg_t))
    assert float(jnp.mean(pos)) > float(jnp.mean(neg))


def test_transe_entity_constraint_unit_norm(tiny_go):
    m = make_model("transe", tiny_go.num_entities, tiny_go.num_relations,
                   dim=8)
    trainer = KGETrainer(m, TrainConfig(batch_size=32, num_negs=4))
    params, _, _ = trainer.fit(tiny_go.triples, steps=5)
    norms = np.linalg.norm(np.asarray(m.entity_embeddings(params)), axis=1)
    # the published constraint is ||e|| <= 1 (PyKEEN clamps rather than
    # renormalizing every entity to exactly 1)
    assert (norms <= 1.0 + 1e-4).all()
    assert norms.max() > 0.5      # and it isn't collapsing to zero


def test_rank_eval_perfect_model_gets_mrr_1(tiny_go):
    """An oracle scorer that puts the true tail on top must get MRR=1."""
    kg = tiny_go

    class Oracle:
        spec = type("S", (), {"n_entities": kg.num_entities})()

        def score_all_tails(self, params, h, r):
            out = np.zeros((len(h), kg.num_entities), np.float32)
            for i, (hh, rr) in enumerate(zip(np.asarray(h), np.asarray(r))):
                match = [t for (x, y, t) in map(tuple, kg.triples)
                         if x == hh and y == rr]
                out[i, match] = 10.0
            return jnp.asarray(out)

        def score_all_heads(self, params, r, t):
            out = np.zeros((len(r), kg.num_entities), np.float32)
            for i, (rr, tt) in enumerate(zip(np.asarray(r), np.asarray(t))):
                match = [h for (h, y, x) in map(tuple, kg.triples)
                         if x == tt and y == rr]
                out[i, match] = 10.0
            return jnp.asarray(out)

    res = rank_based_eval(Oracle(), None, kg.triples[:30], kg.triples,
                          batch_size=16)
    assert res["mrr"] > 0.99
    assert res["hits@1"] > 0.99


@pytest.mark.slow
def test_eval_metrics_trained_beats_random(tiny_go):
    kg = tiny_go
    m = make_model("distmult", kg.num_entities, kg.num_relations, dim=32)
    params0 = m.init(jax.random.key(0))
    res0 = rank_based_eval(m, params0, kg.triples[:40], kg.triples)
    trainer = KGETrainer(m, TrainConfig(batch_size=64, num_negs=16, lr=5e-2))
    params, _, _ = trainer.fit(kg.triples, steps=300)
    res1 = rank_based_eval(m, params, kg.triples[:40], kg.triples)
    assert res1["mrr"] > res0["mrr"]
