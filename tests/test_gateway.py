"""Gateway API v1: batch-first routing, parity with the index-level
oracle on all five paper endpoints, boundary validation, structured
errors, download pagination invariants, and the invalidate freshness
hook. Snapshots are published directly (no training) — fast tier."""
import json

import numpy as np
import pytest

from repro.api import ApiError, Gateway, from_wire
from repro.core.serving import ServingEngine

N, D = 40, 12


def _publish(registry, ontology, version, model="transe", n=N, seed=0,
             lineage=None):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, D)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}-{seed}",
                     hyperparameters={"dim": D}, lineage=lineage)
    return ids


@pytest.fixture()
def gw(registry):
    ids = _publish(registry, "go", "2024-01", seed=1,
                   lineage={"parent_version": None, "mode": "full",
                            "delta": None})
    _publish(registry, "go", "2024-02", seed=2,
             lineage={"parent_version": "2024-01", "mode": "incremental",
                      "delta": {"churn_fraction": 0.1}})
    engine = ServingEngine(registry, cache_capacity=4)
    return Gateway(engine), engine, ids


# ------------------------- batch-first routing ------------------------- #
def test_similarity_and_closest_route_through_scheduler(gw):
    gateway, engine, ids = gw
    before = dict(gateway.scheduler.stats)
    gateway.similarity("go", "transe", ids[0], ids[1])
    gateway.closest_concepts("go", "transe", ids[2], k=5)
    after = gateway.scheduler.stats
    # the acceptance criterion: gateway traffic increments the scheduler
    assert after["submitted"] == before["submitted"] + 2
    assert after["resolved"] == after["submitted"]
    assert after["sim_batches"] >= 1


def test_engine_delegates_also_route_through_scheduler(gw):
    gateway, engine, ids = gw
    # the deprecated ServingEngine methods share the engine's default
    # gateway — their traffic is batched scheduler traffic too
    engine.similarity("go", "transe", ids[0], ids[1])
    engine.closest_concepts("go", "transe", ids[0], k=3)
    st = engine.gateway().scheduler.stats
    assert st["submitted"] >= 2 and st["resolved"] == st["submitted"]


def test_topk_k_equal_sim_sentinel_cannot_poison_sim_queue(gw):
    """A direct-API TopKRequest with k == -1 must not land in the
    (ontology, model, version, _SIM_K) queue and fail its coalesced
    SimRequest peers: k is validated at intake."""
    from repro.core.serving import SimRequest, TopKRequest
    gateway, engine, ids = gw
    sched = gateway.scheduler
    good = sched.submit(SimRequest("go", "transe", ids[0], ids[1]))
    bad = sched.submit(TopKRequest("go", "transe", ids[2], -1))
    assert "k must be >= 1" in bad.exception(timeout=0)   # rejected at submit
    sched.flush()
    assert isinstance(good.result(timeout=0), float)      # peer unharmed
    assert sched.stats["resolved"] == sched.stats["submitted"]


def test_concurrent_sim_calls_coalesce_into_one_batch(gw):
    gateway, engine, ids = gw
    from repro.core.serving import SimRequest
    tickets = [gateway.scheduler.submit(
        SimRequest("go", "transe", ids[i], ids[i + 1], version="2024-02"))
        for i in range(8)]
    gateway.scheduler.flush()
    assert gateway.scheduler.stats["sim_batches"] == 1     # one kernel call
    for i, t in enumerate(tickets):
        oracle = float(np.dot(
            engine._index("go", "transe", "2024-02").unit[i],
            engine._index("go", "transe", "2024-02").unit[i + 1]))
        assert t.result(timeout=0) == pytest.approx(oracle, abs=1e-6)


# ------------------------- endpoint parity ----------------------------- #
def test_five_endpoints_parity_with_index_oracle(gw):
    gateway, engine, ids = gw
    idx = engine._index("go", "transe", "2024-02")

    vec = gateway.get_vector("go", "transe", ids[3])
    assert vec.version == "2024-02" and vec.identifier == ids[3]
    assert np.allclose(vec.vector, idx.embeddings[3])

    sim = gateway.similarity("go", "transe", ids[0], ids[1])
    assert sim.score == pytest.approx(
        float(np.dot(idx.unit[0], idx.unit[1])), abs=1e-6)

    top = gateway.closest_concepts("go", "transe", ids[3], k=5)
    oracle = idx.top_k([ids[3]], 5)[0]
    assert [h.identifier for h in top.results] == \
           [c.identifier for c in oracle]
    assert [h.score for h in top.results] == pytest.approx(
        [c.score for c in oracle])

    page = gateway.download("go", "transe", limit=N)
    assert json.dumps({i: v for i, v in page.rows}) == \
           engine.registry.to_json("go", "transe", "2024-02")

    ac = gateway.autocomplete("go", "transe", "go term 1", limit=4)
    assert ac.completions == idx.autocomplete("go term 1", 4)


def test_handle_wire_parity_with_typed_methods(gw):
    gateway, engine, ids = gw
    wire = gateway.handle("/sim/go/transe", {"a": ids[0], "b": ids[1]})
    typed = gateway.similarity("go", "transe", ids[0], ids[1])
    assert from_wire(wire) == typed
    wire = gateway.handle("closest-concepts/go/transe",   # no leading slash
                          {"query": ids[0], "k": 3})
    assert from_wire(wire) == gateway.closest_concepts(
        "go", "transe", ids[0], k=3)


# ---------------------- validation at the boundary --------------------- #
@pytest.mark.parametrize("route,payload", [
    ("/closest-concepts/go/transe", {"query": "GO:0000001", "k": 0}),
    ("/closest-concepts/go/transe", {"query": "GO:0000001", "k": -3}),
    ("/closest-concepts/go/transe", {"query": "GO:0000001", "k": True}),
    ("/closest-concepts/go/transe", {"query": "GO:0000001", "k": "5"}),
    ("/closest-concepts/go/transe", {"query": ""}),
    ("/closest-concepts/go/transe", {"query": "   "}),
    ("/closest-concepts/go/transe", {"query": None}),
    ("/sim/go/transe", {"a": "", "b": "GO:0000001"}),
    ("/download/go/transe", {"limit": 0}),
    ("/download/go/transe", {"offset": -1}),
    ("/autocomplete/go/transe", {"prefix": ""}),
    ("/autocomplete/go/transe", {"prefix": "x", "limit": -1}),
    ("/sim/go/transe", {"a": "x", "b": "y", "bogus_field": 1}),
    ("/sim/go/transe", {"a": "x"}),                      # missing b
])
def test_bad_requests_rejected_at_boundary(gw, route, payload):
    gateway, _, _ = gw
    before = dict(gateway.scheduler.stats)
    out = gateway.handle(route, payload)
    assert out["type"] == "error" and out["code"] == "BAD_REQUEST"
    # nothing reached the kernel path
    assert gateway.scheduler.stats["submitted"] == before["submitted"]


def test_unknown_route_is_distinct_not_found(gw):
    """Unknown routes get their own code (the error-taxonomy satellite):
    a transport can map status straight from the code, and by_code stats
    keep bad URLs apart from malformed payloads."""
    gateway, _, _ = gw
    for route in ("/no/such/route", "/sim/only-onto", "", "/sim"):
        out = gateway.handle(route)
        assert out["code"] == "NOT_FOUND" and out["status"] == 404
        assert out["details"]["route"] == route
    assert gateway.counters["by_code"]["NOT_FOUND"] == 4
    assert gateway.counters["by_code"].get("BAD_REQUEST", 0) == 0
    # a matched route with a malformed payload stays BAD_REQUEST
    out = gateway.handle("/sim/go/transe", {"a": "x"})
    assert out["code"] == "BAD_REQUEST" and out["status"] == 400


def test_unknown_coordinates_have_stable_codes(gw):
    gateway, _, ids = gw
    cases = [
        ("/sim/mars/transe", {"a": ids[0], "b": ids[1]}, "UNKNOWN_ONTOLOGY"),
        ("/sim/go/no-model", {"a": ids[0], "b": ids[1]}, "UNKNOWN_MODEL"),
        ("/sim/go/transe", {"a": ids[0], "b": ids[1], "version": "1999-01"},
         "UNKNOWN_VERSION"),
        ("/sim/go/transe", {"a": "NOPE", "b": ids[1]}, "UNKNOWN_CLASS"),
        ("/get-vector/go/transe", {"query": "NOPE"}, "UNKNOWN_CLASS"),
        ("/closest-concepts/go/transe", {"query": "NOPE"}, "UNKNOWN_CLASS"),
        ("/versions/venus", {}, "UNKNOWN_ONTOLOGY"),
        ("/lineage/go", {"version": "1999-01"}, "UNKNOWN_VERSION"),
    ]
    for route, payload, code in cases:
        out = gateway.handle(route, payload)
        assert (out["type"], out["code"]) == ("error", code), route
        assert out["status"] == 404


def test_similarity_reports_every_missing_class(gw):
    """The PR 4 satellite bugfix: BOTH unresolvable names are reported,
    fuzzy or not, and the gateway error carries the full list."""
    gateway, engine, ids = gw
    with pytest.raises(ApiError) as ei:
        gateway.similarity("go", "transe", "BOGUS-A", "BOGUS-B")
    assert ei.value.code == "UNKNOWN_CLASS"
    assert ei.value.details["missing"] == ["BOGUS-A", "BOGUS-B"]
    with pytest.raises(ApiError) as ei:
        gateway.similarity("go", "transe", "BOGUS-A", ids[0], fuzzy=True)
    assert ei.value.details["missing"] == ["BOGUS-A"]
    # the deprecated engine delegate keeps KeyError — with both names
    with pytest.raises(KeyError) as ke:
        engine.similarity("go", "transe", "BOGUS-A", "BOGUS-B", fuzzy=True)
    assert "BOGUS-A" in str(ke.value) and "BOGUS-B" in str(ke.value)


# ------------------------ download pagination -------------------------- #
def test_download_pages_are_a_disjoint_cover(gw):
    gateway, engine, ids = gw
    seen, offset, pages = [], 0, 0
    while offset is not None:
        page = gateway.download("go", "transe", offset=offset, limit=7)
        assert page.total == N and page.version == "2024-02"
        assert page.offset == offset
        seen.extend(r[0] for r in page.rows)
        offset = page.next_offset
        pages += 1
    assert pages == (N + 6) // 7
    assert seen == ids                      # full cover, order, no overlap
    # an offset past the end is an empty page, not an error
    tail = gateway.download("go", "transe", offset=N + 5, limit=7)
    assert tail.rows == [] and tail.next_offset is None


def test_download_cursor_stable_under_pinning_across_invalidate(
        gw, registry):
    gateway, engine, ids = gw
    first = gateway.download("go", "transe", limit=10)
    assert first.version == "2024-02"
    # a release lands mid-pagination
    _publish(registry, "go", "2024-03", seed=9)
    engine.invalidate("go", "2024-03")
    # echoing page.version back keeps the cursor on the pinned release
    second = gateway.download("go", "transe", version=first.version,
                              offset=first.next_offset, limit=10)
    assert second.version == "2024-02"
    repeat = gateway.download("go", "transe", version="2024-02",
                              offset=0, limit=10)
    assert repeat.rows == first.rows        # stable within the pin
    # an unpinned fresh download sees the new latest
    assert gateway.download("go", "transe", limit=5).version == "2024-03"


# ----------------------- ops endpoints + hook -------------------------- #
def test_versions_and_lineage_reflect_publish_after_invalidate(
        gw, registry):
    gateway, engine, ids = gw
    v = gateway.versions("go")
    assert v.versions == ["2024-01", "2024-02"] and v.latest == "2024-02"
    assert v.models == ["transe"]
    lin = gateway.lineage("go")
    assert lin.version == "2024-02"
    assert lin.lineage["transe"]["mode"] == "incremental"
    inv_before = gateway.counters["invalidations"]

    _publish(registry, "go", "2024-03", seed=9,
             lineage={"parent_version": "2024-02", "mode": "full",
                      "delta": None})
    engine.invalidate("go", "2024-03")      # the updater's publish hook
    assert gateway.counters["invalidations"] == inv_before + 1
    v = gateway.versions("go")
    assert v.latest == "2024-03" and "2024-03" in v.versions
    assert gateway.lineage("go").lineage["transe"]["mode"] == "full"


def test_health_and_stats_shapes(gw):
    gateway, engine, ids = gw
    h = gateway.health()
    assert h.status == "ok" and h.api_version == "v1"
    assert "go" in h.ontologies and h.scheduler_running is False
    gateway.similarity("go", "transe", ids[0], ids[1])
    s = gateway.stats()
    assert s.scheduler["submitted"] >= 1 and s.scheduler["pending"] == 0
    assert s.gateway["requests"] >= 3
    assert s.gateway["by_route"]["sim"] >= 1
    assert s.cache["size"] >= 1
    bad = gateway.handle("/sim/go/transe", {"a": "NOPE", "b": "NOPE2"})
    assert bad["code"] == "UNKNOWN_CLASS"
    s = gateway.stats()
    assert s.gateway["errors"] >= 1
    assert s.gateway["by_code"]["UNKNOWN_CLASS"] >= 1


def test_bogus_ontology_probes_do_not_grow_meta_cache(gw, registry):
    gateway, engine, ids = gw
    for i in range(50):
        out = gateway.handle(f"/versions/bogus-{i}")
        assert out["code"] == "UNKNOWN_ONTOLOGY"
    assert len(gateway._meta_cache) <= 4       # empty results never cached
    # an ontology published WITHOUT an invalidate (e.g. straight through
    # registry.publish) is therefore visible on the next probe
    assert gateway.handle("/versions/late")["code"] == "UNKNOWN_ONTOLOGY"
    _publish(registry, "late", "v1", seed=3)
    assert gateway.versions("late").latest == "v1"


def test_batch_accepts_one_shot_iterables(gw):
    from repro.api.schema import ClosestConceptsRequest
    gateway, _, ids = gw
    out = gateway.closest_concepts_batch(
        ClosestConceptsRequest("go", "transe", q, k=3) for q in ids[:5])
    assert len(out) == 5 and all(len(r.results) == 3 for r in out)


def test_handle_rejects_route_vs_payload_conflicts(gw):
    gateway, _, ids = gw
    out = gateway.handle("/sim/go/transe",
                         {"ontology": "hp", "a": ids[0], "b": ids[1]})
    assert out["code"] == "BAD_REQUEST"
    assert out["details"]["conflicting_fields"] == ["ontology"]
    # a redundant-but-agreeing field is fine
    out = gateway.handle("/sim/go/transe",
                         {"ontology": "go", "a": ids[0], "b": ids[1]})
    assert out["type"] == "similarity_response"


def test_batch_submit_failure_does_not_strand_staged_tickets(gw):
    """Sync-flush mode: a validation failure mid-burst must still flush
    the tickets staged before it — nothing else would drain them."""
    from repro.api.schema import ClosestConceptsRequest
    gateway, _, ids = gw
    with pytest.raises(ApiError):
        gateway.closest_concepts_batch(
            [ClosestConceptsRequest("go", "transe", ids[0], k=3),
             ClosestConceptsRequest("go", "transe", ids[1], k=0)])
    assert gateway.scheduler.pending() == 0
    st = gateway.scheduler.stats
    assert st["resolved"] == st["submitted"]


def test_close_unregisters_invalidate_listener(gw, registry):
    gateway, engine, ids = gw
    gateway.close()
    inv = gateway.counters["invalidations"]
    _publish(registry, "go", "2024-09", seed=5)
    engine.invalidate("go", "2024-09")
    assert gateway.counters["invalidations"] == inv    # dead gateway quiet
    assert engine._invalidate_listeners == []


def test_closed_gateway_fails_shutting_down(gw):
    gateway, engine, ids = gw
    gateway.close()
    out = gateway.handle("/sim/go/transe", {"a": ids[0], "b": ids[1]})
    assert out["code"] == "SHUTTING_DOWN" and out["status"] == 503
    assert gateway.health().status == "shutting_down"
    # scheduler-level shutdown rejections carry the same code
    from repro.core.serving import TopKRequest
    t = gateway.scheduler.submit(TopKRequest("go", "transe", ids[0], 3))
    assert t.exception(timeout=0) is not None
    with pytest.raises(ApiError) as ei:
        gateway._await_ticket(t)
    assert ei.value.code == "SHUTTING_DOWN"


def test_closest_concepts_batch_is_one_wave(gw):
    """The burst API: a page of requests submits as one wave (coalescing
    into few kernel calls) and failed items surface per-slot with
    return_exceptions."""
    from repro.api.schema import ClosestConceptsRequest
    gateway, engine, ids = gw
    reqs = [ClosestConceptsRequest("go", "transe", ids[i], k=3)
            for i in range(12)]
    before = gateway.scheduler.stats["batches"]
    out = gateway.closest_concepts_batch(reqs)
    assert gateway.scheduler.stats["batches"] == before + 1   # one wave
    for i, resp in enumerate(out):
        oracle = gateway.closest_concepts("go", "transe", ids[i], k=3)
        assert [h.identifier for h in resp.results] == \
               [h.identifier for h in oracle.results]
    mixed = gateway.closest_concepts_batch(
        [ClosestConceptsRequest("go", "transe", ids[0], k=3),
         ClosestConceptsRequest("go", "transe", "NOPE", k=3),
         ClosestConceptsRequest("go", "transe", ids[1], k=0)],
        return_exceptions=True)
    assert len(mixed[0].results) == 3
    assert isinstance(mixed[1], ApiError) and mixed[1].code == "UNKNOWN_CLASS"
    assert isinstance(mixed[2], ApiError) and mixed[2].code == "BAD_REQUEST"
    with pytest.raises(ApiError):
        gateway.closest_concepts_batch(
            [ClosestConceptsRequest("go", "transe", "NOPE", k=3)])


# ----------------------- wire fidelity (PR 5) -------------------------- #
def test_download_and_get_vector_serve_identical_bytes(gw):
    """The wire-fidelity bugfix: the same class must serialize to the
    same JSON on every endpoint that carries vectors — download pages no
    longer apply a private 6-decimal rounding that get-vector didn't."""
    gateway, engine, ids = gw
    page = gateway.download("go", "transe", limit=N)
    by_id = {ident: vec for ident, vec in page.rows}
    for probe in (ids[0], ids[7], ids[N - 1]):
        vec = gateway.get_vector("go", "transe", probe)
        assert json.dumps(by_id[probe]) == json.dumps(vec.vector)
    # full float32 precision survives: a synthetic standard-normal table
    # is (with overwhelming probability) not representable in 6 decimals
    idx = engine._index("go", "transe", "2024-02")
    assert any(v != round(v, 6) for vec in by_id.values() for v in vec)
    assert by_id[ids[0]] == [float(x) for x in idx.embeddings[0]]
    # and registry.to_json (the legacy full-download payload) agrees
    assert json.dumps(dict(page.rows)) == \
           engine.registry.to_json("go", "transe", "2024-02")


# --------------------- pagination contract (PR 5) ---------------------- #
def test_download_echoes_requested_and_effective_limit(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    gateway = Gateway(ServingEngine(registry), page_limit_max=8)
    page = gateway.download("go", "transe", limit=20_000)
    assert page.requested_limit == 20_000       # what the client asked
    assert page.limit == 8                      # what the server enforces
    assert len(page.rows) == 8 and page.next_offset == 8
    # an unclamped request echoes equal limits
    page = gateway.download("go", "transe", limit=5)
    assert page.requested_limit == 5 and page.limit == 5
    gateway.close()


def test_download_offset_at_or_past_total_is_empty_page_not_error(gw):
    gateway, engine, ids = gw
    for offset in (N, N + 1, N + 1000):
        page = gateway.download("go", "transe", offset=offset, limit=7)
        assert page.rows == [] and page.next_offset is None
        assert page.total == N and page.offset == offset
        assert page.etag                        # still a cacheable page


def test_download_etag_keyed_on_full_coordinates(gw):
    from repro.api.gateway import download_etag
    gateway, engine, ids = gw
    page = gateway.download("go", "transe", version="2024-02", limit=10)
    assert page.etag == download_etag("go", "transe", "2024-02", 0, 10)
    # identical re-fetch -> identical validator (that's what makes the
    # HTTP 304 path sound); any coordinate change -> different validator
    assert gateway.download("go", "transe", version="2024-02",
                            limit=10).etag == page.etag
    others = [gateway.download("go", "transe", version="2024-01",
                               limit=10).etag,
              gateway.download("go", "transe", version="2024-02",
                               limit=9).etag,
              gateway.download("go", "transe", version="2024-02", offset=10,
                               limit=10).etag]
    assert len({page.etag, *others}) == 4
    # strong validators identify BYTES: two clamped requests serve the
    # same rows but echo different requested_limit values, so they must
    # NOT share an ETag with each other or with an unclamped request
    clamped = Gateway(engine, page_limit_max=10)
    a = clamped.download("go", "transe", version="2024-02", limit=5000)
    b = clamped.download("go", "transe", version="2024-02", limit=6000)
    assert a.rows == b.rows == page.rows            # same representation…
    assert len({a.etag, b.etag, page.etag}) == 3    # …different bytes
    assert a.etag == download_etag("go", "transe", "2024-02", 0, 10, 5000)
    clamped.close()


# ------------------- counter integrity (PR 5 satellite) ---------------- #
def test_counter_integrity_under_16_thread_mixed_traffic(gw):
    """requests == sum(by_route), errors == sum(by_code), exactly, after
    16 threads hammer handle() with a mix of ok and every error class —
    counter updates and error dedup must be race-free."""
    import threading
    gateway, engine, ids = gw
    n_threads, per = 16, 24

    def worker(tid):
        for j in range(per):
            kind = (tid + j) % 4
            if kind == 0:                                   # ok
                gateway.handle("/sim/go/transe",
                               {"a": ids[j % N], "b": ids[(j + 1) % N]})
            elif kind == 1:                                 # UNKNOWN_CLASS
                gateway.handle("/closest-concepts/go/transe",
                               {"query": f"NOPE-{tid}-{j}"})
            elif kind == 2:                                 # NOT_FOUND
                gateway.handle(f"/no/such/route/{tid}")
            else:                                           # BAD_REQUEST
                gateway.handle("/download/go/transe", {"limit": 0})

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    c = gateway.counters
    total = n_threads * per
    # NOT_FOUND never reaches _run, so it counts as an error but not a
    # routed request — the two identities below pin that bookkeeping
    assert c["requests"] == sum(c["by_route"].values()) == total * 3 // 4
    assert c["errors"] == sum(c["by_code"].values()) == total * 3 // 4
    assert c["by_code"]["UNKNOWN_CLASS"] == total // 4
    assert c["by_code"]["NOT_FOUND"] == total // 4
    assert c["by_code"]["BAD_REQUEST"] == total // 4
    st = gateway.scheduler.stats
    assert st["resolved"] == st["submitted"]


def test_apierror_through_both_handle_layers_counted_once(gw):
    """An ApiError raised inside _run and re-caught by handle() (or by a
    deprecated engine delegate above it) must count exactly once."""
    gateway, engine, ids = gw
    base = gateway.counters["errors"]
    out = gateway.handle("/sim/go/transe", {"a": "NOPE", "b": "NOPE2"})
    assert out["code"] == "UNKNOWN_CLASS"
    assert gateway.counters["errors"] == base + 1
    # the engine delegate path stacks engine._legacy over gateway._run
    with pytest.raises(KeyError):
        engine.similarity("go", "transe", "NOPE", "NOPE2")
    assert engine.gateway().counters["errors"] == \
           engine.gateway().counters["by_code"]["UNKNOWN_CLASS"]
    assert gateway.counters["errors"] == base + 1      # distinct gateway


# --------------------- latency histograms (PR 5) ----------------------- #
def test_stats_expose_per_route_latency_histograms(gw):
    gateway, engine, ids = gw
    for i in range(4):
        gateway.similarity("go", "transe", ids[i], ids[i + 1])
    gateway.download("go", "transe", limit=5)
    gateway.handle("/sim/go/transe", {"a": "NOPE", "b": "NOPE2"})
    s = gateway.stats()
    assert s.latency["sim"]["count"] == 5              # errors timed too
    assert s.latency["download"]["count"] == 1
    sim = s.latency["sim"]
    assert sum(sim["bucket_counts"]) == sim["count"]
    assert len(sim["bucket_counts"]) == len(sim["bucket_le_ms"])
    assert sim["p50_ms"] is not None and sim["p99_ms"] >= sim["p50_ms"]
    # scheduler-side submit->resolve histogram covers every ticket
    st = gateway.scheduler.stats
    assert s.scheduler["latency_ms"]["count"] == st["resolved"]
    # /stats itself is timed (on the next snapshot, not its own)
    s2 = gateway.stats()
    assert s2.latency["stats"]["count"] >= 1


def test_fuzzy_routes_through_scheduler(gw):
    gateway, engine, ids = gw
    idx = engine._index("go", "transe", "2024-02")
    typo = idx.labels[5][:-1] + "x"     # synthetic labels are 1 edit apart,
    row = idx.resolve(typo, fuzzy=True)  # so pin the ambiguity-free oracle
    assert row is not None
    before = gateway.scheduler.stats["submitted"]
    fuzzy = gateway.similarity("go", "transe", typo, ids[6], fuzzy=True)
    exact = gateway.similarity("go", "transe", idx.entity_ids[row], ids[6])
    assert exact.score == fuzzy.score
    top = gateway.closest_concepts("go", "transe", typo, k=3, fuzzy=True)
    assert len(top.results) == 3
    assert gateway.scheduler.stats["submitted"] == before + 3
