"""int8 KV cache (serving feature): quantization round-trip + decode
consistency within quantization tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import blocks, build, get_config

# LM-zoo/trainer tests: tier-2 only (run with plain `pytest`)
pytestmark = pytest.mark.slow


def test_quantize_roundtrip():
    x = jax.random.normal(jax.random.key(0), (3, 4, 7, 32), jnp.float32) * 5
    q, s = blocks.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (3, 4, 7)
    x2 = blocks.dequantize_kv(q, s, jnp.float32)
    # symmetric int8: relative error <= 1/254 of the row max
    err = np.abs(np.asarray(x2 - x))
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1))[..., None] / 127.0
    assert (err <= bound + 1e-6).all()


def test_quantize_handles_zero_rows():
    x = jnp.zeros((2, 5, 8))
    q, s = blocks.quantize_kv(x)
    assert np.asarray(blocks.dequantize_kv(q, s, jnp.float32)).sum() == 0


@pytest.mark.parametrize("arch", ["qwen2_72b", "h2o_danube_1_8b"])
def test_int8_decode_close_to_fp(arch):
    """prefill+decode with int8 cache tracks the fp cache within
    quantization noise (and exactly matches shapes/structure)."""
    cfg = get_config(arch, reduced=True).with_(dtype="float32")
    model_fp = build(cfg)
    model_q8 = build(cfg.with_(kv_cache_dtype="int8"))
    params = model_fp.init(jax.random.key(0))
    B, S = 2, 48
    key = jax.random.key(1)
    batch = {
        "tokens": jax.random.randint(key, (B, S - 1), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(key, (B, S - 1), 0, cfg.vocab, jnp.int32),
    }
    tok = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab, jnp.int32)
    pos = jnp.asarray(S - 1, jnp.int32)

    _, cache_fp = jax.jit(lambda p, b: model_fp.prefill(p, b, cache_len=S))(
        params, batch)
    logits_fp, _ = jax.jit(model_fp.decode_step)(params, cache_fp, tok, pos)

    _, cache_q8 = jax.jit(lambda p, b: model_q8.prefill(p, b, cache_len=S))(
        params, batch)
    assert cache_q8["k"].dtype == jnp.int8
    assert "k_scale" in cache_q8
    logits_q8, cache_q8b = jax.jit(model_q8.decode_step)(params, cache_q8,
                                                         tok, pos)
    assert cache_q8b["k"].dtype == jnp.int8

    lf = np.asarray(logits_fp, np.float32)
    lq = np.asarray(logits_q8, np.float32)
    # quantization-level agreement, and identical top-1 predictions
    np.testing.assert_allclose(lq, lf, rtol=0.1, atol=0.15)
    np.testing.assert_array_equal(lq.argmax(-1), lf.argmax(-1))


def test_int8_cache_spec_half_the_bytes():
    cfg = get_config("qwen2_72b").with_(kv_groups=16)
    fp = build(cfg).cache_spec(128, 32768)
    q8 = build(cfg.with_(kv_cache_dtype="int8")).cache_spec(128, 32768)
    size = lambda t: sum(np.prod(l.shape) * l.dtype.itemsize
                         for l in jax.tree.leaves(t))
    assert size(q8) < 0.6 * size(fp)
