"""Pallas kernels (interpret=True on CPU) vs pure-jnp oracles in ref.py.

Per the deliverable: shape/dtype sweeps + hypothesis property tests per
kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.kge_score import kge_score_pallas
from repro.kernels.swa_attention import swa_attention_pallas
from repro.kernels.topk_similarity import topk_cosine_pallas


def _unit(key, n, d, dtype=jnp.float32):
    x = jax.random.normal(key, (n, d), jnp.float32)
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x.astype(dtype)


# ===================================================================== #
# top-k cosine
# ===================================================================== #
@pytest.mark.slow
@pytest.mark.parametrize("Q,N,d,k,block_n", [
    (1, 100, 16, 10, 32),
    (4, 1000, 200, 10, 256),      # the paper's dim/k
    (8, 257, 64, 5, 64),          # ragged N
    (2, 64, 128, 3, 64),          # single block
])
def test_topk_matches_ref(Q, N, d, k, block_n):
    kq, ke = jax.random.split(jax.random.key(0))
    q, e = _unit(kq, Q, d), _unit(ke, N, d)
    s, i, v = topk_cosine_pallas(q, e, k, block_n=block_n, interpret=True)
    s_ref, i_ref, v_ref = ref.topk_cosine_ref(q, e, k)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    assert np.asarray(v).tolist() == [min(k, N)] * Q


def test_topk_exclude_rows_matches_ref():
    kq, ke = jax.random.split(jax.random.key(7))
    q, e = _unit(kq, 4, 32), _unit(ke, 200, 32)
    excl = jnp.array([0, 57, 199, -1], jnp.int32)
    s, i, v = topk_cosine_pallas(q, e, 10, exclude_rows=excl, block_n=64,
                                 interpret=True)
    s_ref, i_ref, v_ref = ref.topk_cosine_ref(q, e, 10, exclude_rows=excl)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    i = np.asarray(i)
    for r, x in enumerate([0, 57, 199]):
        assert x not in i[r]


@pytest.mark.slow
def test_topk_k_exceeds_table_regression():
    """Regression: k (or k+1 with self-exclusion) > N used to return
    sentinel rows (score -1e30, index 0) that serving surfaced as fake
    entity-0 results. Now k clamps to N and `valid` marks real entries."""
    kq, ke = jax.random.split(jax.random.key(9))
    q, e = _unit(kq, 2, 8), _unit(ke, 3, 8)
    excl = jnp.array([1, -1], jnp.int32)
    for impl in ("pallas", "ref"):
        if impl == "pallas":
            s, i, v = topk_cosine_pallas(q, e, 10, exclude_rows=excl,
                                         block_n=32, interpret=True)
        else:
            s, i, v = ref.topk_cosine_ref(q, e, 10, exclude_rows=excl)
        s, i, v = np.asarray(s), np.asarray(i), np.asarray(v)
        assert s.shape == (2, 3)                      # clamped to N
        assert v.tolist() == [2, 3]                   # row 0 excludes itself
        for r in range(2):
            assert (s[r, :v[r]] > -1e29).all()        # no sentinel in valid
            assert len(set(i[r, :v[r]].tolist())) == v[r]
        assert 1 not in i[0, :v[0]]


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_dtypes(dtype):
    kq, ke = jax.random.split(jax.random.key(1))
    q, e = _unit(kq, 3, 64, dtype), _unit(ke, 300, 64, dtype)
    s, i, _ = topk_cosine_pallas(q, e, 10, block_n=128, interpret=True)
    s_ref, i_ref, _ = ref.topk_cosine_ref(q, e, 10)
    # bf16 inputs: scores match to bf16 resolution; indices may swap among
    # near-ties, so compare score values (sorted) rather than exact indices.
    np.testing.assert_allclose(np.asarray(s, np.float32),
                               np.asarray(s_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(n=st.integers(5, 400), d=st.sampled_from([8, 32, 200]),
       k=st.integers(1, 5), seed=st.integers(0, 2**16))
def test_topk_property(n, d, k, seed):
    kq, ke = jax.random.split(jax.random.key(seed))
    q, e = _unit(kq, 2, d), _unit(ke, n, d)
    k = min(k, n)
    s, i, _ = topk_cosine_pallas(q, e, k, block_n=64, interpret=True)
    s, i = np.asarray(s), np.asarray(i)
    full = np.asarray(q @ e.T)
    # invariants: scores descending; indices in range & unique per row;
    # scores equal full[i]; top-1 is the global max.
    assert (np.diff(s, axis=1) <= 1e-6).all()
    for r in range(2):
        assert len(set(i[r].tolist())) == k
        np.testing.assert_allclose(s[r], full[r, i[r]], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(s[r, 0], full[r].max(), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N,d,k,batch", [
    (7, 8, 10, 1),        # k > N: clamps, valid marks the real entries
    (16, 8, 16, 3),       # k == N
    (3, 4, 9, 2),         # tiny table, k far beyond N
    (100, 16, 10, 4),
    (257, 32, 5, 2),      # ragged N (not a block multiple)
    (64, 200, 10, 8),     # the paper's dim
])
def test_topk_parity_grid(N, d, k, batch):
    """Pallas vs ref over the (N, d, k, batch) grid with exclude_rows
    hitting the last valid row: identical (scores, indices, valid) on the
    valid region, sentinel (-1e30) beyond it in both."""
    kq, ke = jax.random.split(jax.random.key(N * 1000 + k))
    q, e = _unit(kq, batch, d), _unit(ke, N, d)
    # alternate: exclude the LAST valid table row / no exclusion
    excl = jnp.array([N - 1 if i % 2 == 0 else -1 for i in range(batch)],
                     jnp.int32)
    s, i, v = topk_cosine_pallas(q, e, k, exclude_rows=excl, block_n=64,
                                 interpret=True)
    s_ref, i_ref, v_ref = ref.topk_cosine_ref(q, e, k, exclude_rows=excl)
    s, i, v = np.asarray(s), np.asarray(i), np.asarray(v)
    s_ref, i_ref, v_ref = np.asarray(s_ref), np.asarray(i_ref), np.asarray(v_ref)
    np.testing.assert_array_equal(v, v_ref)
    assert s.shape == s_ref.shape == (batch, min(k, N))
    for r in range(batch):
        np.testing.assert_allclose(s[r, :v[r]], s_ref[r, :v[r]],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(i[r, :v[r]], i_ref[r, :v[r]])
        assert (s[r, v[r]:] < -1e29).all() and (s_ref[r, v[r]:] < -1e29).all()
        if r % 2 == 0:
            assert N - 1 not in i[r, :v[r]]         # exclusion held


def test_topk_sharded_single_device_fallback():
    """mesh=None (and a 1-device axis) must route through the unchanged
    single-device dispatcher, bit-identical results."""
    kq, ke = jax.random.split(jax.random.key(11))
    q, e = _unit(kq, 3, 16), _unit(ke, 90, 16)
    excl = jnp.array([89, -1, 5], jnp.int32)
    s0, i0, v0 = ops.topk_cosine(q, e, 7, exclude_rows=excl, use_pallas=False)
    s1, i1, v1 = ops.topk_cosine_sharded(q, e, 7, exclude_rows=excl,
                                         mesh=None, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    # padded table + n_valid slices back to the real rows on the fallback
    e_pad = jnp.concatenate([e, jnp.zeros((6, 16))], axis=0)
    s2, i2, v2 = ops.topk_cosine_sharded(q, e_pad, 7, exclude_rows=excl,
                                         mesh=None, n_valid=90,
                                         use_pallas=False)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v2))


# ===================================================================== #
# KGE scoring
# ===================================================================== #
@pytest.mark.slow
@pytest.mark.parametrize("model", ["transe_l1", "transe_l2", "distmult"])
@pytest.mark.parametrize("B,K,d", [(32, 8, 64), (100, 5, 200), (7, 3, 32)])
def test_kge_score_matches_ref(model, B, K, d):
    ks = jax.random.split(jax.random.key(2), 5)
    h = jax.random.normal(ks[0], (B, d))
    r = jax.random.normal(ks[1], (B, d))
    t = jax.random.normal(ks[2], (B, d))
    neg = jax.random.normal(ks[3], (B, K, d))
    ch = jax.random.bernoulli(ks[4], 0.5, (B, K))
    pos, negs = kge_score_pallas(h, r, t, neg, ch, model=model, interpret=True)
    pos_ref, negs_ref = ref.kge_score_ref(h, r, t, neg, ch, model=model)
    np.testing.assert_allclose(np.asarray(pos), np.asarray(pos_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(negs), np.asarray(negs_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 64), k=st.integers(1, 8),
       d=st.sampled_from([16, 200]), seed=st.integers(0, 2**16))
def test_kge_score_property(b, k, d, seed):
    """Translational identity: score(h, r, h+r) == 0 for L1/L2."""
    ks = jax.random.split(jax.random.key(seed), 3)
    h = jax.random.normal(ks[0], (b, d))
    r = jax.random.normal(ks[1], (b, d))
    t = h + r
    neg = jax.random.normal(ks[2], (b, k, d))
    pos, _ = kge_score_pallas(h, r, t, neg, jnp.zeros((b, k), bool),
                              model="transe_l2", interpret=True)
    np.testing.assert_allclose(np.asarray(pos), 0.0, atol=1e-4)


# ===================================================================== #
# sliding-window attention kernel
# ===================================================================== #
@pytest.mark.slow
@pytest.mark.parametrize("B,H,S,hd,W", [
    (1, 2, 128, 32, 32),
    (2, 4, 256, 64, 64),
    (1, 1, 64, 16, 128),          # window >= seq: full causal
])
def test_swa_kernel_matches_ref(B, H, S, hd, W):
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H // 2 or 1, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H // 2 or 1, S, hd), jnp.float32)
    hkv = k.shape[1]
    out = swa_attention_pallas(q.reshape(B * H, S, hd),
                               k.reshape(B * hkv, S, hd),
                               v.reshape(B * hkv, S, hd),
                               window=W, interpret=True).reshape(B, H, S, hd)
    out_ref = ref.swa_attention_ref(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)


# ===================================================================== #
# ops dispatcher
# ===================================================================== #
def test_ops_topk_dispatches_both_paths():
    kq, ke = jax.random.split(jax.random.key(4))
    q, e = _unit(kq, 2, 32), _unit(ke, 128, 32)
    excl = jnp.array([3, -1], jnp.int32)
    s1, i1, v1 = ops.topk_cosine(q, e, 5, exclude_rows=excl, use_pallas=True)
    s2, i2, v2 = ops.topk_cosine(q, e, 5, exclude_rows=excl, use_pallas=False)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
