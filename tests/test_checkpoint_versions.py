"""version_sort_key / SnapshotStore.versions edge cases: mixed alphanumeric
tags, and agreement between the store's ordering and FileReleaseChannel's."""
import numpy as np
import pytest

from repro.checkpoint import SnapshotStore, version_sort_key
from repro.core.updater import FileReleaseChannel
from repro.ontology import obo


def test_sort_key_numeric_runs():
    assert version_sort_key("2024-10") > version_sort_key("2024-9")
    assert version_sort_key("v10") > version_sort_key("v2")
    assert version_sort_key("2024-01-02") > version_sort_key("2024-01-01")


def test_sort_key_mixed_alphanumeric():
    # an rc suffix sorts after the plain release of the same month
    assert version_sort_key("2024-10-rc1") > version_sort_key("2024-10")
    assert version_sort_key("2024-10-rc2") > version_sort_key("2024-10-rc1")
    assert version_sort_key("2024-10-rc10") > version_sort_key("2024-10-rc2")
    # but before the next month
    assert version_sort_key("2024-11") > version_sort_key("2024-10-rc1")


def test_sort_key_never_compares_int_to_str():
    """re.split alternates str/int positions, so tuple comparison is always
    str-vs-str or int-vs-int — no TypeError on any tag mix."""
    tags = ["2024-10", "2024-9", "v2", "v10", "release", "1", "a1b", "a-b",
            "2024-10-rc1", "", "10a", "a10"]
    assert sorted(tags, key=version_sort_key)   # must not raise


def test_store_versions_mixed_tags(tmp_path):
    store = SnapshotStore(tmp_path / "s")
    tags = ["2024-10-rc1", "2024-10", "2024-9", "v10", "v2"]
    for v in tags:
        store.save("go", v, "transe",
                   {"embeddings": np.zeros((1, 2), np.float32)}, {})
    assert store.versions("go") == ["2024-9", "2024-10", "2024-10-rc1",
                                    "v2", "v10"]
    assert store.latest_version("go") == "v10"


def test_store_and_channel_agree_on_latest(tmp_path, tiny_go):
    """FileReleaseChannel and SnapshotStore use the same key, so the release
    the channel calls 'latest' is the version the store calls 'latest' —
    the updater's checksum compare relies on this agreement."""
    d = tmp_path / "releases"
    d.mkdir()
    store = SnapshotStore(tmp_path / "snap")
    tags = ["2024-9", "2024-10", "2024-10-rc1", "2023-12"]
    for v in tags:
        obo.save_obo(tiny_go, d / f"{v}.obo", header_version=v)
        store.save("go", v, "transe",
                   {"embeddings": np.zeros((1, 2), np.float32)}, {})
    ch = FileReleaseChannel("go", d)
    latest_tag, _ = ch.latest()
    assert latest_tag == store.latest_version("go") == "2024-10-rc1"


def test_store_versions_empty_and_single(tmp_path):
    store = SnapshotStore(tmp_path / "s")
    assert store.versions("go") == []
    assert store.latest_version("go") is None
    store.save("go", "2024-10", "transe",
               {"embeddings": np.zeros((1, 2), np.float32)}, {})
    assert store.versions("go") == ["2024-10"]
    assert store.latest_version("go") == "2024-10"
