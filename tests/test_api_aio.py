"""Async front end over Ticket (the PR 2 open item): loop-safe
ticket->future bridge, gather fan-out across a mid-stream invalidate
with exactly-once resolution + version pinning, and structured error
propagation into coroutines."""
import asyncio

import numpy as np
import pytest

from repro.api import ApiError, AsyncGateway, Gateway, ticket_future
from repro.api.schema import ClosestConceptsRequest
from repro.core.serving import ServingEngine

N, D = 40, 12


def _publish(registry, version, seed):
    rng = np.random.default_rng(seed)
    ids = [f"GO:{i:07d}" for i in range(N)]
    labels = [f"go term {i}" for i in range(N)]
    emb = rng.standard_normal((N, D)).astype(np.float32)
    registry.publish("go", version, "transe", ids, labels, emb,
                     ontology_checksum=f"ck-{version}", hyperparameters={})
    return ids


@pytest.fixture()
def served(registry):
    ids = _publish(registry, "2024-01", seed=1)
    engine = ServingEngine(registry, cache_capacity=4)
    gateway = Gateway(engine)
    return engine, gateway, ids


def test_gather_64_across_midstream_invalidate(served, registry):
    """64 concurrent closest_concepts awaits; a release lands after the
    first 32 submits. Every call resolves exactly once, pinned to the
    version that was latest when it was submitted."""
    engine, gateway, ids = served
    ag = AsyncGateway(gateway, flush_after_ms=1.0)

    async def run():
        first = [asyncio.ensure_future(
            ag.closest_concepts("go", "transe", ids[i % N], k=5))
            for i in range(32)]
        # wait until every phase-1 coroutine has actually submitted
        while gateway.scheduler.stats["submitted"] < 32:
            await asyncio.sleep(0.001)
        _publish(registry, "2024-02", seed=2)
        engine.invalidate("go", "2024-02")
        second = [asyncio.ensure_future(
            ag.closest_concepts("go", "transe", ids[i % N], k=5))
            for i in range(32)]
        return await asyncio.gather(*(first + second))

    res = asyncio.run(run())
    gateway.close()                               # drains the flush loop
    assert len(res) == 64
    assert {r.version for r in res[:32]} == {"2024-01"}   # pinned pre-swap
    assert {r.version for r in res[32:]} == {"2024-02"}   # post-swap
    assert all(len(r.results) == 5 for r in res)
    st = gateway.scheduler.stats
    assert st["resolved"] == st["submitted"]              # exactly once
    assert st["failed"] == 0 and gateway.scheduler.pending() == 0
    # concurrent awaits actually coalesced (far fewer kernel calls than
    # requests — 64 sequential solo calls would be 64 batches)
    assert st["batches"] < 64


def test_async_results_match_sync_oracle(served):
    engine, gateway, ids = served
    ag = AsyncGateway(gateway, flush_after_ms=1.0)

    async def run():
        return await ag.closest_concepts_many(
            [ClosestConceptsRequest("go", "transe", ids[i], k=4)
             for i in range(8)])

    res = asyncio.run(run())
    for i, r in enumerate(res):
        oracle = gateway.closest_concepts("go", "transe", ids[i], k=4)
        assert [h.identifier for h in r.results] == \
               [h.identifier for h in oracle.results]
    gateway.close()


def test_async_error_propagation(served):
    engine, gateway, ids = served
    ag = AsyncGateway(gateway, flush_after_ms=1.0)

    async def run():
        with pytest.raises(ApiError) as ei:
            await ag.similarity("go", "transe", "BOGUS-A", "BOGUS-B")
        assert ei.value.code == "UNKNOWN_CLASS"
        assert ei.value.details["missing"] == ["BOGUS-A", "BOGUS-B"]
        with pytest.raises(ApiError) as ei:
            await ag.closest_concepts("go", "transe", ids[0], k=0)
        assert ei.value.code == "BAD_REQUEST"
        # gathered errors surface per-call with return_exceptions
        out = await ag.closest_concepts_many(
            [ClosestConceptsRequest("go", "transe", ids[0], k=3),
             ClosestConceptsRequest("go", "transe", "NOPE", k=3)],
            return_exceptions=True)
        assert len(out[0].results) == 3
        assert isinstance(out[1], ApiError)
        assert out[1].code == "UNKNOWN_CLASS"

    asyncio.run(run())
    gateway.close()
    st = gateway.scheduler.stats
    assert st["resolved"] == st["submitted"]
    # async resolution-time failures are counted in the gateway stats too
    assert gateway.counters["by_code"]["UNKNOWN_CLASS"] >= 2
    assert gateway.counters["by_code"]["BAD_REQUEST"] >= 1


def test_async_direct_reads_and_wire(served):
    engine, gateway, ids = served
    ag = AsyncGateway(gateway, flush_after_ms=1.0)

    async def run():
        page, vers, health, vec = await asyncio.gather(
            ag.download("go", "transe", limit=10),
            ag.versions("go"),
            ag.health(),
            ag.get_vector("go", "transe", ids[0]))
        assert page.total == N and len(page.rows) == 10
        assert vers.latest == "2024-01"
        assert health.scheduler_running is True        # aio started the loop
        assert vec.identifier == ids[0]
        wire = await ag.handle("/sim/go/transe", {"a": ids[0], "b": ids[1]})
        assert wire["type"] == "similarity_response"
        err = await ag.handle("/sim/go/transe", {"a": "NOPE", "b": "NOPE2"})
        assert err["code"] == "UNKNOWN_CLASS"
        assert err["details"]["missing"] == ["NOPE", "NOPE2"]
        assert (await ag.handle("/no/such/route"))["status"] == 404
        # same parsing contract as the sync handle: malformed payloads
        # and route/payload conflicts come back as wire errors, never
        # raised exceptions
        bad = await ag.handle("/sim/go/transe", "notadict")
        assert bad["code"] == "BAD_REQUEST"
        clash = await ag.handle("/sim/go/transe",
                                {"ontology": "hp", "a": ids[0], "b": ids[1]})
        assert clash["code"] == "BAD_REQUEST"
        assert clash["details"]["conflicting_fields"] == ["ontology"]

    asyncio.run(run())
    gateway.close()


def test_ticket_future_on_already_resolved_ticket(served):
    """The bridge must settle immediately for a ticket that resolved
    before the callback was attached (no lost-wakeup race)."""
    engine, gateway, ids = served
    from repro.core.serving import TopKRequest
    ticket = gateway.scheduler.submit(TopKRequest("go", "transe", ids[0], 3))
    gateway.scheduler.flush()
    assert ticket.done()

    async def run():
        res = await asyncio.wait_for(ticket_future(ticket), timeout=5)
        assert len(res) == 3

    asyncio.run(run())
    gateway.close()
