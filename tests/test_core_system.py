"""The paper's system: update pipeline, registry, the three API endpoints,
request batching, PROV metadata."""
import json

import numpy as np
import pytest

from repro.core.provenance import prov_record, validate_prov
from repro.core.registry import EmbeddingRegistry
from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest
from repro.core.updater import (FileReleaseChannel, Updater, poll_loop)
from repro.kge.train import TrainConfig
from repro.ontology import obo
from repro.ontology.synthetic import GO_SPEC, evolve, generate

FAST = TrainConfig(batch_size=64, num_negs=4, lr=5e-2)
TWO = ("transe", "distmult")


class MemChannel:
    def __init__(self, name, version, kg):
        self.name, self._v, self._kg = name, version, kg

    def latest(self):
        return self._v, self._kg

    def bump(self, version, kg):
        self._v, self._kg = version, kg


def _publish_one_release(registry, tiny_go):
    """Train-and-publish one version into ``registry``; the shared body of
    both `served` fixtures."""
    upd = Updater(registry, models=TWO, dim=16, train_cfg=FAST,
                  steps_override=40)
    ch = MemChannel("go", "2023-01-01", tiny_go)
    rep = upd.run_once(ch)
    assert rep.changed and rep.trained_models == list(TWO)
    return registry, ServingEngine(registry), ch, upd


@pytest.fixture()
def served(registry, tiny_go):
    """Registry with one published version + engine (fresh per test — for
    tests that publish new releases or mutate updater state)."""
    return _publish_one_release(registry, tiny_go)


@pytest.fixture(scope="module")
def served_ro(tmp_path_factory, tiny_go):
    """Same published state, trained once per module — for read-only
    endpoint tests (training two models per test dominated suite time)."""
    registry = EmbeddingRegistry(tmp_path_factory.mktemp("served") / "reg")
    return _publish_one_release(registry, tiny_go)


# ------------------------- updater semantics ------------------------- #
def test_unchanged_release_is_not_retrained(served):
    registry, engine, ch, upd = served
    rep2 = upd.run_once(ch)
    assert not rep2.changed and rep2.trained_models == []


def test_new_release_triggers_retrain_and_invalidation(served, tiny_go):
    registry, engine, ch, upd = served
    # warm the engine cache, then release a new version
    engine.similarity("go", "transe", tiny_go.entities[0], tiny_go.entities[1])
    assert len(engine.cache) == 1
    upd.engine = engine
    kg2 = evolve(tiny_go, GO_SPEC, seed=3)
    ch.bump("2023-07-01", kg2)
    rep = upd.run_once(ch)
    assert rep.changed
    # atomic latest-pointer swap: new queries see the new version, while
    # the old version's index stays cached for in-flight pinned queries
    assert engine.latest_version("go") == "2023-07-01"
    assert ("go", "transe", "2023-01-01") in engine.cache
    assert registry.versions("go") == ["2023-01-01", "2023-07-01"]
    # endpoints now serve the NEW version's entity set
    new_ent = [e for e in kg2.entities if e not in set(tiny_go.entities)][0]
    s = engine.similarity("go", "transe", new_ent, kg2.entities[0])
    assert -1.001 <= s <= 1.001


def test_file_release_channel(tmp_path, tiny_go):
    d = tmp_path / "releases"
    d.mkdir()
    obo.save_obo(tiny_go, d / "2023-01-01.obo", header_version="2023-01-01")
    kg2 = evolve(tiny_go, GO_SPEC, seed=1)
    obo.save_obo(kg2, d / "2023-07-01.obo", header_version="2023-07-01")
    ch = FileReleaseChannel("go", d)
    v, kg = ch.latest()
    assert v == "2023-07-01"
    assert kg.checksum() == kg2.checksum()


def test_file_release_channel_natural_version_order(tmp_path, tiny_go):
    """'2024-9' must sort BEFORE '2024-10' (lexicographic sort served the
    stale September release as latest)."""
    d = tmp_path / "releases"
    d.mkdir()
    kg2 = evolve(tiny_go, GO_SPEC, seed=1)
    obo.save_obo(tiny_go, d / "2024-9.obo", header_version="2024-9")
    obo.save_obo(kg2, d / "2024-10.obo", header_version="2024-10")
    ch = FileReleaseChannel("go", d)
    v, kg = ch.latest()
    assert v == "2024-10"
    assert kg.checksum() == kg2.checksum()


def test_store_latest_version_natural_order(tmp_path):
    from repro.checkpoint import SnapshotStore, version_sort_key
    store = SnapshotStore(tmp_path / "s")
    for v in ("2024-10", "2024-9", "2023-12", "2024-11"):
        store.save("go", v, "transe",
                   {"embeddings": np.zeros((1, 2), np.float32)}, {})
    assert store.versions("go") == ["2023-12", "2024-9", "2024-10", "2024-11"]
    assert store.latest_version("go") == "2024-11"
    assert version_sort_key("v10") > version_sort_key("v2")


@pytest.mark.slow
def test_poll_loop_runs_all_channels(registry, tiny_go, tiny_hp):
    upd = Updater(registry, models=("transe",), dim=8, train_cfg=FAST,
                  steps_override=10)
    chans = [MemChannel("go", "v1", tiny_go), MemChannel("hp", "v1", tiny_hp)]
    reports = poll_loop(upd, chans, iterations=2)
    assert len(reports) == 4
    assert reports[0].changed and reports[1].changed
    assert not reports[2].changed and not reports[3].changed


# ------------------------- the three endpoints ------------------------- #
def test_download_endpoint_payload(served_ro):
    registry, engine, ch, _ = served_ro
    payload = json.loads(engine.download("go", "transe"))
    assert len(payload) == 120
    vecs = list(payload.values())
    assert all(len(v) == 16 for v in vecs)
    # versioned download: explicit version works too
    payload_v = json.loads(engine.download("go", "transe", "2023-01-01"))
    assert payload == payload_v


def test_similarity_endpoint(served_ro, tiny_go):
    registry, engine, ch, _ = served_ro
    a, b = tiny_go.entities[0], tiny_go.entities[1]
    s_ab = engine.similarity("go", "transe", a, b)
    s_ba = engine.similarity("go", "transe", b, a)
    assert abs(s_ab - s_ba) < 1e-6                    # symmetric
    assert abs(engine.similarity("go", "transe", a, a) - 1.0) < 1e-5
    assert -1.001 <= s_ab <= 1.001


def test_similarity_accepts_labels_with_normalization(served_ro, tiny_go):
    registry, engine, ch, _ = served_ro
    ident = tiny_go.entities[5]
    label = tiny_go.terms[ident].label
    messy = "  " + label.upper().replace(" ", "   ") + " "
    s1 = engine.similarity("go", "transe", ident, tiny_go.entities[6])
    s2 = engine.similarity("go", "transe", messy, tiny_go.entities[6])
    assert s1 == s2


def test_unknown_class_raises(served_ro):
    _, engine, _, _ = served_ro
    with pytest.raises(KeyError):
        engine.similarity("go", "transe", "GO:9999999", "GO:0000001")


def test_closest_concepts_endpoint(served_ro, tiny_go):
    registry, engine, ch, _ = served_ro
    q = tiny_go.entities[3]
    res = engine.closest_concepts("go", "transe", q, k=10)
    assert len(res) == 10
    scores = [c.score for c in res]
    assert scores == sorted(scores, reverse=True)     # ranked
    assert all(c.identifier != q for c in res)        # self excluded
    assert all(c.url.endswith(c.identifier) for c in res)
    assert all(isinstance(c.label, str) and c.label for c in res)


def test_scheduler_matches_individual_queries(served_ro, tiny_go):
    registry, engine, ch, _ = served_ro
    sched = BatchScheduler(engine, max_batch=8)
    queries = tiny_go.entities[:20]
    tickets = [sched.submit(TopKRequest("go", "transe", q, 5))
               for q in queries]
    batched = sched.flush()
    for t, q in zip(tickets, queries):
        solo = engine.closest_concepts("go", "transe", q, k=5)
        got = batched[t]
        assert [c.identifier for c in got] == [c.identifier for c in solo]


# ------------------------- registry / PROV ------------------------- #
def test_prov_roundtrip_and_validation(served_ro):
    registry, _, _, _ = served_ro
    ids, labels, emb, meta = registry.get("go", "transe")
    assert validate_prov(meta["prov"])
    blob = json.dumps(meta["prov"])
    # PROV must record the input ontology, the model and the hypers
    assert "transe" in blob and "go" in blob
    assert meta["ontology_checksum"] in blob
    assert meta["dim"] == 16 and meta["num_entities"] == len(ids)


def test_prov_validation_rejects_garbage():
    assert not validate_prov({})
    assert not validate_prov({"wasGeneratedBy": {}})


def test_registry_latest_version_ordering(registry, tiny_go):
    upd = Updater(registry, models=("transe",), dim=8, train_cfg=FAST,
                  steps_override=5)
    ch = MemChannel("go", "2023-01-01", tiny_go)
    upd.run_once(ch)
    ch.bump("2024-01-01", evolve(tiny_go, GO_SPEC, seed=2))
    upd.run_once(ch)
    assert registry.store.latest_version("go") == "2024-01-01"
    # engine serves the most up-to-date version by default (paper semantics)
    engine = ServingEngine(registry)
    idx = engine._index("go", "transe")
    assert len(idx.entity_ids) > 120
