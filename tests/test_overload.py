"""Admission control and the burst-path bugfix sweep (PR 7): bounded
scheduler intake fast-rejecting with OVERLOADED, HTTP 429 + Retry-After
instead of a hang, deadline budgets rejecting expired tickets before
kernel work, flush-time skip of already-resolved tickets, the aio
ticket bridge surviving a closed event loop, and 304s landing in
transport stats with latency. Fast tier — snapshots are published
directly."""
import asyncio
import json
import time

import numpy as np
import pytest

from repro.api import Gateway, serve_http, ticket_future
from repro.core.serving import (BatchScheduler, SchedulerError, ServingEngine,
                                TopKRequest)

N, D = 40, 12


def _publish(registry, ontology, version, model="transe", n=N, seed=0):
    rng = np.random.default_rng(seed)
    ids = [f"{ontology.upper()}:{i:07d}" for i in range(n)]
    labels = [f"{ontology} term {i}" for i in range(n)]
    emb = rng.standard_normal((n, D)).astype(np.float32)
    registry.publish(ontology, version, model, ids, labels, emb,
                     ontology_checksum=f"ck-{version}-{seed}",
                     hyperparameters={"dim": D})
    return ids


@pytest.fixture()
def engine(registry):
    ids = _publish(registry, "go", "2024-01", seed=1)
    return ServingEngine(registry, cache_capacity=4), ids


# ----------------------- scheduler admission -------------------------- #
def test_max_pending_fast_rejects_with_overloaded(engine):
    eng, ids = engine
    sched = BatchScheduler(eng, max_pending=2)      # no flush loop
    t1 = sched.submit(TopKRequest("go", "transe", ids[0], k=3))
    t2 = sched.submit(TopKRequest("go", "transe", ids[1], k=3))
    t3 = sched.submit(TopKRequest("go", "transe", ids[2], k=3))
    assert t3.done() and not t1.done() and not t2.done()
    with pytest.raises(SchedulerError) as ei:
        t3.result(timeout=0)
    assert ei.value.code == "OVERLOADED"
    assert ei.value.details["max_pending"] == 2
    assert ei.value.details["retry_after_s"] > 0
    assert sched.stats["rejected_overloaded"] == 1
    # capacity frees after a flush; intake accepts again
    sched.flush()
    assert t1.result(timeout=1) and t2.result(timeout=1)
    t4 = sched.submit(TopKRequest("go", "transe", ids[3], k=3))
    sched.flush()
    assert t4.result(timeout=1)
    # every accepted ticket resolved; the fast-reject never enters queues
    assert sched.stats["resolved"] == sched.stats["submitted"]


def test_max_pending_validated():
    with pytest.raises(ValueError):
        BatchScheduler(object(), max_pending=0)


def test_deadline_budget_rejects_expired_before_kernel_work(engine):
    """Satellite 1: a ticket queued past submit+budget is rejected at
    flush time *before* the index build — zero batches run when every
    queued ticket has expired."""
    eng, ids = engine
    sched = BatchScheduler(eng, max_batch=8)
    t = sched.submit(TopKRequest("go", "transe", ids[0], k=3,
                                 budget_s=0.01))
    assert t.deadline is not None
    time.sleep(0.05)
    sched.flush()
    with pytest.raises(SchedulerError) as ei:
        t.result(timeout=0)
    assert ei.value.code == "TIMEOUT"
    assert ei.value.details["queued_s"] >= 0.01
    assert sched.stats["expired"] == 1
    assert sched.stats["batches"] == 0          # no kernel work happened
    assert sched.stats["resolved"] == sched.stats["submitted"]


def test_default_budget_applies_when_request_has_none(engine):
    eng, ids = engine
    sched = BatchScheduler(eng, default_budget_s=0.01)
    t = sched.submit(TopKRequest("go", "transe", ids[0], k=3))
    assert t.deadline == pytest.approx(t.created + 0.01)
    time.sleep(0.05)
    sched.flush()
    with pytest.raises(SchedulerError):
        t.result(timeout=0)
    assert sched.stats["expired"] == 1


def test_flush_skips_already_resolved_tickets(engine):
    """Satellite 1: a ticket resolved externally (e.g. a client-side
    cancel) between submit and flush is silently dropped from the batch
    instead of being double-resolved or batched for nothing."""
    eng, ids = engine
    sched = BatchScheduler(eng)
    t = sched.submit(TopKRequest("go", "transe", ids[0], k=3))
    t._resolve("cancelled-by-client")
    sched.flush()
    assert sched.stats["skipped_resolved"] == 1
    assert sched.stats["batches"] == 0
    assert t.result(timeout=0) == "cancelled-by-client"   # untouched


# --------------------------- wire-level 429 ---------------------------- #
def test_saturated_scheduler_returns_429_with_retry_after(engine):
    """Satellite 4: a saturated scheduler must answer over HTTP with 429
    + Retry-After — quickly — not hang the connection until timeout."""
    import urllib.error
    import urllib.request
    eng, ids = engine
    # flush loop running but glacial: the pre-filled ticket below holds
    # the single max_pending slot for the whole test
    gateway = Gateway(eng, max_pending=1, flush_after_ms=60_000,
                      result_cache_entries=0)
    server = serve_http(gateway, port=0)
    try:
        gateway.scheduler.submit(
            TopKRequest("go", "transe", ids[0], k=3))   # occupies the slot
        t0 = time.perf_counter()
        req = urllib.request.Request(
            server.url + f"/closest-concepts/go/transe?query={ids[1]}&k=3")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req, timeout=30)
        elapsed = time.perf_counter() - t0
        err = ei.value
        assert err.code == 429
        assert int(err.headers["Retry-After"]) >= 1
        body = json.loads(err.read())
        assert body["code"] == "OVERLOADED" and body["status"] == 429
        assert body["details"]["retry_after_s"] > 0
        assert elapsed < 5.0                    # fast-reject, not a hang
        # rejected requests count exactly once in errors_by_code
        assert gateway.counters["by_code"]["OVERLOADED"] == 1
        assert gateway.counters["errors"] == 1
        assert gateway.scheduler.stats["rejected_overloaded"] == 1
        wire = gateway.handle("/stats", {})   # /stats itself never submits
        assert wire["gateway"]["by_code"]["OVERLOADED"] == 1
    finally:
        server.close()
        gateway.close()


# ------------------------ aio shutdown race ---------------------------- #
def test_ticket_future_survives_loop_closed_before_resolution(engine):
    """Satellite 2: the flush thread resolving a ticket whose awaiting
    event loop has already closed must not blow up the flush loop."""
    eng, ids = engine
    sched = BatchScheduler(eng)
    t = sched.submit(TopKRequest("go", "transe", ids[0], k=3))
    loop = asyncio.new_event_loop()
    fut = ticket_future(t, loop)
    loop.close()                     # client went away mid-flight
    sched.flush()                    # fires on_done against the dead loop
    assert t.done() and t.result(timeout=0)
    assert not fut.done()            # never settled — but nothing raised
    assert sched.stats["resolved"] == sched.stats["submitted"]


def test_ticket_future_still_settles_on_live_loop(engine):
    eng, ids = engine
    sched = BatchScheduler(eng)

    async def run():
        t = sched.submit(TopKRequest("go", "transe", ids[0], k=3))
        fut = ticket_future(t)
        await asyncio.get_running_loop().run_in_executor(None, sched.flush)
        return await fut

    hits = asyncio.run(run())
    assert len(hits) == 3


# ------------------------- 304 observability --------------------------- #
def test_not_modified_counts_and_latency_in_http_stats(registry):
    """Satellite 3: conditional-GET 304s are answered before dispatch;
    they must still show up in transport-level /stats with latency."""
    import urllib.request
    ids = _publish(registry, "go", "2024-01", seed=1)
    eng = ServingEngine(registry, cache_capacity=4)
    gateway = Gateway(eng)
    server = serve_http(gateway, port=0)
    try:
        path = "/download/go/transe?limit=3"
        with urllib.request.urlopen(server.url + path, timeout=30) as r:
            etag = r.headers["ETag"]
        import http.client
        host = server.server_address[0]
        conn = http.client.HTTPConnection(host, server.port, timeout=30)
        conn.request("GET", path, headers={"If-None-Match": etag})
        resp = conn.getresponse()
        resp.read()
        assert resp.status == 304
        conn.close()
        with urllib.request.urlopen(server.url + "/stats", timeout=30) as r:
            body = json.loads(r.read())
        http_stats = body["http"]
        assert http_stats["not_modified"] == 1
        lat = http_stats["latency_ms"]["not_modified"]
        assert lat["count"] == 1 and lat["p50_ms"] >= 0
    finally:
        server.close()
        gateway.close()
