"""Block-tiled top-k (streaming + blocked-ref) vs the unblocked oracle.

PR 8 makes the top-k hot path scale-oblivious: the table is walked in
fixed-size row blocks with a running top-k merge, and per-row norms are
folded into the in-kernel score so no host-normalized private copy is
ever materialized. The contract is bit-parity with the one-shot oracle
(`ref.topk_cosine_ref`) across the full edge grid — indices and valid
exactly equal, scores allclose, entries past ``valid`` never compared.

Edge classes required by the issue, each × both backends:
  * k larger than the block size (running merge must carry > block state)
  * N not a multiple of the block (final partial block, masked tail)
  * exclusion landing in the final partial block
  * k == N (every row surfaces, sentinel tail empty)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _unit(n, d):
    x = RNG.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _assert_parity(got, want, n, note):
    s, i, v = (np.asarray(x) for x in got)
    sr, ir, vr = (np.asarray(x) for x in want)
    assert (v == vr).all(), (note, v, vr)
    assert s.shape == sr.shape, (note, s.shape, sr.shape)
    for r in range(s.shape[0]):
        np.testing.assert_array_equal(i[r, :v[r]], ir[r, :v[r]], err_msg=note)
        np.testing.assert_allclose(s[r, :v[r]], sr[r, :v[r]],
                                   rtol=1e-5, atol=1e-5, err_msg=note)
        assert (s[r, v[r]:] < -1e29).all(), note      # sentinel tail
        assert (i[r, :v[r]] < n).all(), note          # no pad row leaks


# (Q, N, d, k, block): the issue's edge grid.  block=8 with k=12 makes
# k > block; N=21, block=8 leaves a 5-row final partial block; N=16,
# block=8, k=16 is k == N across exactly two full blocks.
GRID = [
    (2, 21, 16, 12, 8),      # k > block AND partial final block
    (3, 21, 16, 5, 8),       # partial final block, small k
    (2, 16, 8, 16, 8),       # k == N, block-multiple N
    (1, 7, 8, 10, 8),        # k > N (clamped), single partial block
    (2, 64, 32, 64, 16),     # k == N across many blocks
]


@pytest.mark.parametrize("Q,N,d,k,block", GRID)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_streaming_matches_oracle(Q, N, d, k, block, use_pallas):
    """Host-streaming path (np table in, block_rows forced tiny)."""
    q, e = _unit(Q, d), _unit(N, d)
    # exclusion lands in the FINAL (possibly partial) block on even
    # queries — a block-local index translation bug surfaces here
    excl = np.array([N - 1 if i % 2 == 0 else -1 for i in range(Q)],
                    np.int32)
    got = ops.topk_cosine(q, e, k, exclude_rows=excl,
                          use_pallas=use_pallas, block_rows=block)
    want = ref.topk_cosine_ref(jnp.asarray(q), jnp.asarray(e), k,
                               exclude_rows=jnp.asarray(excl))
    note = f"stream pallas={use_pallas} Q={Q} N={N} k={k} block={block}"
    _assert_parity(got, want, N, note)
    i, v = np.asarray(got[1]), np.asarray(got[2])
    for r in range(Q):
        if r % 2 == 0:
            assert N - 1 not in i[r, :v[r]], note     # exclusion held


@pytest.mark.parametrize("Q,N,d,k,block", GRID)
def test_blocked_ref_matches_oracle(Q, N, d, k, block):
    """Device-side blocked ref (fori_loop + dynamic_slice) on jnp arrays."""
    q, e = _unit(Q, d), _unit(N, d)
    excl = jnp.array([N - 1 if i % 2 == 0 else -1 for i in range(Q)],
                     jnp.int32)
    got = ref.topk_cosine_blocked_ref(jnp.asarray(q), jnp.asarray(e), k,
                                      exclude_rows=excl, block_n=block)
    want = ref.topk_cosine_ref(jnp.asarray(q), jnp.asarray(e), k,
                               exclude_rows=excl)
    _assert_parity(got, want, N, f"blocked_ref N={N} k={k} block={block}")


@pytest.mark.parametrize("use_pallas", [False, True])
def test_norm_folding_matches_host_normalized(use_pallas):
    """Raw table + per-row norms scores bit-identically (indices/valid)
    to the oracle over the host-normalized copy — the kernel performs
    the exact same float32 division the host would."""
    Q, N, d, k, block = 3, 21, 16, 12, 8
    q = _unit(Q, d)
    raw = (RNG.standard_normal((N, d)) * 3.0).astype(np.float32)
    nrm = np.linalg.norm(raw, axis=1).astype(np.float32)
    excl = np.array([N - 1, -1, 4], np.int32)
    got = ops.topk_cosine(q, raw, k, exclude_rows=excl,
                          use_pallas=use_pallas, norms=nrm,
                          block_rows=block)
    unit_t = raw / np.maximum(nrm[:, None], 1e-12)
    want = ref.topk_cosine_ref(jnp.asarray(q), jnp.asarray(unit_t), k,
                               exclude_rows=jnp.asarray(excl))
    _assert_parity(got, want, N, f"norms pallas={use_pallas}")


def test_blocked_ref_norm_folding():
    """Same norms-folding parity on the jnp blocked-ref path (the
    sharded per-device local top-k uses this route)."""
    Q, N, d, k, block = 2, 21, 16, 5, 8
    q = _unit(Q, d)
    raw = (RNG.standard_normal((N, d)) * 2.0).astype(np.float32)
    nrm = np.linalg.norm(raw, axis=1).astype(np.float32)
    got = ref.topk_cosine_blocked_ref(jnp.asarray(q), jnp.asarray(raw), k,
                                      norms=jnp.asarray(nrm), block_n=block)
    unit_t = raw / np.maximum(nrm[:, None], 1e-12)
    want = ref.topk_cosine_ref(jnp.asarray(q), jnp.asarray(unit_t), k)
    _assert_parity(got, want, N, "blocked_ref norms")


def test_stream_stats_track_residency():
    """The streaming driver records its peak single-block transfer —
    strictly smaller than the table once N exceeds one block."""
    Q, N, d, k, block = 2, 100, 16, 5, 16
    q, e = _unit(Q, d), _unit(N, d)
    ops.reset_stream_stats()
    ops.topk_cosine(q, e, k, use_pallas=False, block_rows=block)
    stats = ops.stream_stats
    assert stats["calls"] == 1
    assert stats["blocks"] == -(-N // block)
    assert 0 < stats["peak_block_bytes"] < e.nbytes
    # bound: block rows + their norms, float32
    assert stats["peak_block_bytes"] <= block * (d + 1) * 4
