"""Invariant-analyzer self-tests (PR 10 tentpole).

Three layers:

1. Seeded-violation fixtures: for every rule, the known-bad snippet in
   ``tests/fixtures/analysis/`` fires exactly that rule and the known-
   good twin stays silent — the analyzer's own positive/negative gate.
2. Machinery: suppression comments (line + file), the baseline
   round-trip (grandfather → clean → stale detection), the CLI's
   ``--strict`` exit codes, and the repo itself scanning clean.
3. Regressions for the true positives the analyzer surfaced and this PR
   fixed: jobs publishing DONE-state fields under the lock, the
   snapshot store's fully-atomic writes, and the HTTP transport
   counters' locked snapshot accessor.
"""
import json
import shutil
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (all_checkers, run_analysis, write_baseline)
from repro.analysis.__main__ import main as analysis_main

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "analysis"
SRC = REPO / "src"

BIO_RULES = ("BIO001", "BIO002", "BIO003", "BIO004", "BIO005")
GEN_RULES = ("GEN001", "GEN002")
ALL_FIXTURE_RULES = BIO_RULES + GEN_RULES


def _scan(path: Path, **kw):
    return run_analysis([path], root=REPO, **kw)


# ---------------------------- rule catalogue --------------------------- #
def test_registry_has_all_contract_rules():
    codes = set(all_checkers())
    assert set(ALL_FIXTURE_RULES) <= codes
    for checker in all_checkers().values():
        assert checker.contract, f"{checker.code} has no contract docstring"


# ------------------------ seeded-violation gate ------------------------ #
@pytest.mark.parametrize("rule", ALL_FIXTURE_RULES)
def test_bad_fixture_fires_exactly_its_rule(rule):
    report = _scan(FIXTURES / f"{rule.lower()}_bad.py")
    fired = {f.rule for f in report.findings}
    assert rule in fired, f"{rule} did not fire on its seeded violation"
    assert fired == {rule}, f"cross-fire on {rule} fixture: {fired}"


@pytest.mark.parametrize("rule", ALL_FIXTURE_RULES)
def test_good_fixture_stays_silent(rule):
    report = _scan(FIXTURES / f"{rule.lower()}_good.py")
    assert report.findings == [], [
        f"{f.rule} {f.message}" for f in report.findings]


# ----------------------------- suppression ----------------------------- #
def _bad_copy(tmp_path: Path, rule: str) -> Path:
    dst = tmp_path / f"{rule.lower()}_bad.py"
    shutil.copy(FIXTURES / f"{rule.lower()}_bad.py", dst)
    return dst


def test_line_suppression_silences_only_that_line(tmp_path):
    target = _bad_copy(tmp_path, "BIO001")
    report = _scan(target)
    (line,) = {f.line for f in report.findings}
    lines = target.read_text().splitlines()
    lines[line - 1] += "  # bioan: ignore[BIO001] reset is test-only"
    target.write_text("\n".join(lines) + "\n")
    after = _scan(target)
    assert after.findings == []
    assert [f.rule for f in after.suppressed] == ["BIO001"]


def test_line_suppression_is_rule_specific(tmp_path):
    target = _bad_copy(tmp_path, "BIO001")
    report = _scan(target)
    (line,) = {f.line for f in report.findings}
    lines = target.read_text().splitlines()
    lines[line - 1] += "  # bioan: ignore[BIO005]"      # wrong rule
    target.write_text("\n".join(lines) + "\n")
    after = _scan(target)
    assert [f.rule for f in after.findings] == ["BIO001"]


def test_file_suppression(tmp_path):
    target = _bad_copy(tmp_path, "GEN001")
    text = target.read_text()
    target.write_text("# bioan: ignore-file[GEN001]\n" + text)
    after = _scan(target)
    assert after.findings == [] and len(after.suppressed) == 1


def test_bare_ignore_suppresses_every_rule(tmp_path):
    target = _bad_copy(tmp_path, "GEN002")
    line = next(i for i, l in enumerate(target.read_text().splitlines())
                if "f\"" in l)
    lines = target.read_text().splitlines()
    lines[line] += "  # bioan: ignore"
    target.write_text("\n".join(lines) + "\n")
    assert _scan(target).findings == []


# ------------------------------ baseline ------------------------------- #
def test_baseline_round_trip_and_staleness(tmp_path):
    target = _bad_copy(tmp_path, "BIO002")
    baseline = tmp_path / "baseline.json"

    before = _scan(target)
    assert before.findings, "seeded violation must fire to baseline it"
    write_baseline(baseline, before.findings)

    grandfathered = _scan(target, baseline=baseline)
    assert grandfathered.findings == []
    assert len(grandfathered.baselined) == len(before.findings)
    assert grandfathered.stale_baseline == []

    # fix the violation: every baseline entry is now stale and reported
    shutil.copy(FIXTURES / "bio002_good.py", target)
    fixed = _scan(target, baseline=baseline)
    assert fixed.findings == []
    assert fixed.baselined == []
    assert len(fixed.stale_baseline) == len(before.findings)


def test_baseline_survives_line_drift(tmp_path):
    """Fingerprints exclude line numbers: prepending code must not
    un-grandfather a baselined finding."""
    target = _bad_copy(tmp_path, "BIO005")
    baseline = tmp_path / "baseline.json"
    write_baseline(baseline, _scan(target).findings)
    target.write_text("import os  # bioan: ignore[GEN001]\n\n"
                      + target.read_text())
    drifted = _scan(target, baseline=baseline)
    assert drifted.findings == []
    assert len(drifted.baselined) == 1


# -------------------------------- CLI ---------------------------------- #
def _cli(*args: str, cwd: Path = REPO) -> subprocess.CompletedProcess:
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin:/usr/local/bin"}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=120)


def test_cli_strict_exits_nonzero_on_seeded_violation(tmp_path):
    proc = _cli("--strict", str(FIXTURES / "bio003_bad.py"))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "BIO003" in proc.stdout


def test_cli_strict_exits_zero_on_repo():
    proc = _cli("--strict", "src")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_json_report_and_select(tmp_path):
    out = tmp_path / "report.json"
    proc = _cli("--select", "GEN", "--json", str(out),
                str(FIXTURES / "gen001_bad.py"))
    assert proc.returncode == 0          # non-strict always exits 0
    data = json.loads(out.read_text())
    assert data["ok"] is False
    assert data["counts"] == {"GEN001": 1}
    assert data["findings"][0]["fingerprint"]


def test_cli_list_rules_in_process(capsys):
    assert analysis_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ALL_FIXTURE_RULES:
        assert rule in out


def test_cli_write_baseline_round_trip(tmp_path):
    target = _bad_copy(tmp_path, "GEN002")
    baseline = tmp_path / "bl.json"
    assert analysis_main(["--baseline", str(baseline), "--write-baseline",
                          str(target)]) == 0
    assert analysis_main(["--strict", "--baseline", str(baseline),
                          str(target)]) == 0


# -------------------------- repo stays clean --------------------------- #
def test_repo_scans_clean_in_process():
    """The acceptance gate, in-process: zero unsuppressed findings over
    src/ — and fast enough for the smoke's < 10 s budget."""
    report = _scan(SRC)
    assert report.ok, "\n".join(
        f"{f.path}:{f.line} {f.rule} {f.message}" for f in report.findings)
    assert report.elapsed_s < 10.0
    assert report.files > 50


# ================= regressions for analyzer-found fixes ================ #
def test_job_done_state_is_published_atomically(registry):
    """BIO001 true positive (jobs.py _run_loop): result fields were
    written after the lock was dropped, so a poller could observe
    state == DONE with progress < 1 or rows unset.  Every DONE/RUNNING
    observation must now be internally consistent."""
    from repro.api import Gateway
    from repro.core.serving import ServingEngine

    rng = np.random.default_rng(3)
    n, d = 48, 8
    ids = [f"GO:{i:07d}" for i in range(n)]
    registry.publish("go", "2024-01", "transe", ids,
                     [f"t {i}" for i in range(n)],
                     rng.standard_normal((n, d)).astype(np.float32),
                     ontology_checksum="ck", hyperparameters={"dim": d})
    engine = ServingEngine(registry, cache_capacity=4)
    gateway = Gateway(engine, jobs_slab=4, jobs_yield_s=0.02)
    try:
        sub = gateway.submit_job("knn-join", "go", model="transe",
                                 classes=ids, k=3)
        torn = []
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            st = gateway.job_status(sub.job_id)
            if st.state == "DONE":
                if st.progress != 1.0 or st.total != n:
                    torn.append(("DONE", st.progress, st.total))
                break
            time.sleep(0.001)
        else:
            pytest.fail("job did not finish")
        assert torn == []
        page = gateway.job_result(sub.job_id, limit=n)
        assert page.total == n
    finally:
        gateway.close()


def test_store_writes_are_all_atomic(registry, tmp_path):
    """BIO002 true positives (store.py): embeddings/params/graph
    archives and the params/graph sidecars were written in place.  All
    publish-side writes must go tmp-first and leave no droppings."""
    import repro.checkpoint.store as store_mod

    replaced = []
    orig = store_mod.os.replace

    def spy(src, dst):
        replaced.append(Path(dst).name)
        return orig(src, dst)

    store_mod.os.replace = spy
    try:
        rng = np.random.default_rng(0)
        n, d = 12, 6
        ids = [f"GO:{i:07d}" for i in range(n)]
        registry.publish(
            "go", "2024-01", "transe", ids, [f"t {i}" for i in range(n)],
            rng.standard_normal((n, d)).astype(np.float32),
            ontology_checksum="ck", hyperparameters={"dim": d},
            params={"entity": rng.standard_normal((n, d))},
            params_vocab={"entity": ids})

        class _KG:
            entities = ids
            relations = ["is_a"]
            triples = np.zeros((1, 3), dtype=np.int64)
            terms = {}

        registry.store.save_graph("go", "2024-01", _KG())
    finally:
        store_mod.os.replace = orig

    for name in ("embeddings.npz", "params.npz", "params_vocab.json",
                 "graph.npz", "graph_terms.json", "metadata.json"):
        assert name in replaced, f"{name} was not published atomically"
    leftovers = [p for p in (registry.store.root).rglob("*.tmp*")]
    assert leftovers == []
    # and the archives still round-trip
    params, vocab = registry.get_params("go", "transe")
    assert vocab["entity"] == ids
    _, _, emb, _ = registry.get("go", "transe")
    assert emb.shape == (n, d)


def test_http_counts_accessor_is_locked_and_consistent():
    """BIO001-adjacent true positive (workers.py): the worker state dump
    and pool-merged /stats copied ``server.http_stats`` without the
    stats lock.  The locked accessor must return a stable copy while
    writers hammer the counters."""
    from repro.api.http import GatewayHTTPServer

    class _Shim:
        _count = GatewayHTTPServer._count
        http_counts = GatewayHTTPServer.http_counts

        def __init__(self):
            self._stats_lock = threading.Lock()
            self.http_stats = {"requests": 0, "not_modified": 0}

    srv = _Shim()
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            srv._count("requests")
            srv._count("not_modified")

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(300):
            snap = srv.http_counts()
            assert set(snap) == {"requests", "not_modified"}
        snap = srv.http_counts()
        snap["requests"] = -1                 # a copy, not the live dict
        assert srv.http_stats["requests"] >= 0
    finally:
        stop.set()
        for t in threads:
            t.join()
