"""Delta-aware incremental update pipeline: plan → policy → warm-start →
publish → invalidate, plus the lineage/persistence that makes warm starts
survive a process restart."""
import numpy as np
import pytest

from repro.core.registry import EmbeddingRegistry
from repro.core.serving import ServingEngine
from repro.core.updater import SyntheticReleaseChannel, Updater, poll_loop
from repro.kge.train import TrainConfig
from repro.ontology.synthetic import GO_SPEC, evolve, generate

FAST = TrainConfig(batch_size=64, num_negs=4, lr=5e-2)
CALM = dict(add_frac=0.02, obsolete_frac=0.005, rewire_frac=0.005)
WILD = dict(add_frac=0.5, obsolete_frac=0.05, rewire_frac=0.3)


MemChannel = SyntheticReleaseChannel


def _updater(registry, engine=None, models=("transe",), **kw):
    kw.setdefault("steps_override", 20)
    return Updater(registry, engine=engine, models=models, dim=16,
                   train_cfg=FAST, **kw)


# ----------------------------- plan ------------------------------- #
def test_plan_stages(registry, tiny_go):
    upd = _updater(registry)
    ch = MemChannel("go", "2023-01-01", tiny_go)
    plan, kg = upd.plan(ch)
    assert plan.changed and plan.mode == "full"
    assert plan.parent_version is None and plan.delta is None
    upd.run_once(ch)

    plan2, _ = upd.plan(ch)
    assert not plan2.changed and plan2.mode == "noop"

    ch.bump("2023-07-01", evolve(tiny_go, GO_SPEC, seed=3, **CALM))
    plan3, _ = upd.plan(ch)
    assert plan3.changed and plan3.mode == "incremental"
    assert plan3.parent_version == "2023-01-01"
    assert 0.0 < plan3.delta.churn_fraction < upd.churn_threshold


def test_high_churn_forces_full(registry, tiny_go):
    upd = _updater(registry)
    ch = MemChannel("go", "v1", tiny_go)
    upd.run_once(ch)
    ch.bump("v2", evolve(tiny_go, GO_SPEC, seed=9, **WILD))
    plan, _ = upd.plan(ch)
    assert plan.mode == "full"
    assert plan.delta.churn_fraction >= upd.churn_threshold


def test_zero_threshold_disables_warm_start(registry, tiny_go):
    upd = _updater(registry, churn_threshold=0.0)
    ch = MemChannel("go", "v1", tiny_go)
    upd.run_once(ch)
    ch.bump("v2", evolve(tiny_go, GO_SPEC, seed=3, **CALM))
    rep = upd.run_once(ch)
    assert rep.mode == "full"
    assert rep.details["transe"]["mode"] == "full"
    assert rep.details["transe"]["budget_frac"] == 1.0


# ------------------------- run_once: incremental --------------------- #
@pytest.mark.slow
def test_incremental_update_lands_in_serving_engine(registry, tiny_go):
    """Acceptance: a mid-series run_once publishes via the warm path and
    still lands in ServingEngine through the existing atomic invalidate."""
    engine = ServingEngine(registry)
    upd = _updater(registry, engine=engine, models=("transe", "rdf2vec"))
    ch = MemChannel("go", "2023-01-01", tiny_go)
    rep1 = upd.run_once(ch)
    assert rep1.mode == "full" and rep1.changed
    engine.similarity("go", "transe", tiny_go.entities[0], tiny_go.entities[1])

    kg2 = evolve(tiny_go, GO_SPEC, seed=3, **CALM)
    ch.bump("2023-07-01", kg2)
    rep2 = upd.run_once(ch)
    assert rep2.mode == "incremental"
    assert rep2.parent_version == "2023-01-01"
    assert rep2.delta["churn_fraction"] < upd.churn_threshold
    for m in ("transe", "rdf2vec"):
        det = rep2.details[m]
        assert det["mode"] == "incremental"
        assert det["budget_frac"] == upd.warm_frac
        assert det["carried_rows"] > 0
        assert det["step_budget_ratio"] > 1.0
    # atomic latest-pointer swap: new queries see the new version, old
    # version's index stays cached for in-flight pinned queries
    assert engine.latest_version("go") == "2023-07-01"
    assert ("go", "transe", "2023-01-01") in engine.cache
    new_ent = [e for e in kg2.entities if e not in set(tiny_go.entities)][0]
    s = engine.similarity("go", "transe", new_ent, kg2.entities[0])
    assert -1.001 <= s <= 1.001
    top = engine.closest_concepts("go", "rdf2vec", kg2.entities[0], k=3)
    assert len(top) == 3


def test_warm_start_survives_process_restart(registry, tiny_go):
    """Params + graph + lineage are persisted, so a *fresh* Updater over the
    same registry warm-starts (the paper's cron job restarts every cycle)."""
    upd = _updater(registry)
    ch = MemChannel("go", "v1", tiny_go)
    upd.run_once(ch)
    del upd

    upd2 = _updater(registry)                 # no in-memory state
    kg2 = evolve(tiny_go, GO_SPEC, seed=3, **CALM)
    ch.bump("v2", kg2)
    rep = upd2.run_once(ch)
    assert rep.mode == "incremental"
    assert rep.details["transe"]["mode"] == "incremental"
    assert rep.details["transe"]["carried_rows"] > 100


def test_parent_without_params_falls_back_to_cold(registry, tiny_go):
    """Snapshots published by older code (no params.npz) must not break the
    pipeline: the plan can still be incremental, but training goes full."""
    # publish v1 through the registry directly, without params
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((tiny_go.num_entities, 16)).astype(np.float32)
    labels = [tiny_go.label_of(e) for e in tiny_go.entities]
    registry.publish("go", "v1", "transe", tiny_go.entities, labels, emb,
                     ontology_checksum=tiny_go.checksum(),
                     hyperparameters={"dim": 16})
    registry.store.save_graph("go", "v1", tiny_go)

    upd = _updater(registry)
    kg2 = evolve(tiny_go, GO_SPEC, seed=3, **CALM)
    ch = MemChannel("go", "v2", kg2)
    rep = upd.run_once(ch)
    assert rep.changed and rep.mode == "incremental"
    assert rep.details["transe"]["mode"] == "full"        # per-model fallback
    assert rep.details["transe"]["budget_frac"] == 1.0


def test_parent_without_graph_plans_full(registry, tiny_go):
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((tiny_go.num_entities, 16)).astype(np.float32)
    labels = [tiny_go.label_of(e) for e in tiny_go.entities]
    registry.publish("go", "v1", "transe", tiny_go.entities, labels, emb,
                     ontology_checksum=tiny_go.checksum(),
                     hyperparameters={"dim": 16})
    upd = _updater(registry)
    ch = MemChannel("go", "v2", evolve(tiny_go, GO_SPEC, seed=3, **CALM))
    plan, _ = upd.plan(ch)
    assert plan.mode == "full" and "not persisted" in plan.reason


# ----------------------- lineage + persistence ----------------------- #
def test_lineage_metadata_roundtrip(registry, tiny_go):
    upd = _updater(registry)
    ch = MemChannel("go", "v1", tiny_go)
    upd.run_once(ch)
    _, _, _, meta1 = registry.get("go", "transe", "v1")
    assert meta1["lineage"]["mode"] == "full"
    assert meta1["lineage"]["parent_version"] is None

    kg2 = evolve(tiny_go, GO_SPEC, seed=3, **CALM)
    ch.bump("v2", kg2)
    rep = upd.run_once(ch)
    _, _, _, meta2 = registry.get("go", "transe", "v2")
    lin = meta2["lineage"]
    assert lin["mode"] == "incremental"
    assert lin["parent_version"] == "v1"
    assert lin["delta"] == rep.delta
    assert lin["delta"]["churn_fraction"] > 0

    # full params + vocab are loadable for the *next* warm start
    params, vocab = registry.get_params("go", "transe", "v2")
    assert set(params) == {"entity", "relation"}
    assert params["entity"].shape == (kg2.num_entities, 16)
    assert vocab["entity"] == kg2.entities
    assert vocab["relation"] == kg2.relations
    # and the parsed graph roundtrips exactly
    kg_back = registry.store.load_graph("go", "v2")
    assert kg_back.checksum() == kg2.checksum()


# --------------------------- satellites ------------------------------ #
def test_unchanged_poll_reports_real_wall_time(registry, tiny_go):
    upd = _updater(registry)
    ch = MemChannel("go", "v1", tiny_go)
    upd.run_once(ch)
    rep = upd.run_once(ch)
    assert not rep.changed and rep.mode == "noop"
    # checksum + parse cost is real work; 0.0 hid it from monitoring
    assert rep.wall_s > 0.0


def test_poll_loop_threads_distinct_seeds(registry, tiny_go):
    seeds = []

    class Spy(Updater):
        def run_once(self, channel, seed=0):
            seeds.append(seed)
            return super().run_once(channel, seed=seed)

    upd = Spy(registry, models=("transe",), dim=8, train_cfg=FAST,
              steps_override=5)
    chans = [MemChannel("go", "v1", tiny_go)]
    poll_loop(upd, chans, iterations=3)
    assert len(seeds) == 3
    assert len(set(seeds)) == 3, "every polling round must get its own seed"
    reports = poll_loop(upd, chans, iterations=2, base_seed=100)
    assert seeds[-2:] == [100, 101]
    assert all(not r.changed for r in reports)
