"""Multi-device equivalence of the shard_map expert-parallel MoE.

The shard_map path (explicit local dispatch + psum combine) must compute
the same loss AND gradients as the pure-GSPMD path with matching
block-local capacity. A 16x error in the router gradient (double-psum) or
a dropped expert contribution would pass single-device tests — so this
runs in a subprocess with 8 forced host devices on a (2, 4) mesh.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

# LM-zoo/trainer tests: tier-2 only (run with plain `pytest`)
pytestmark = pytest.mark.slow

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models import build, get_config, runtime
    from repro.models.sharding import param_shardings

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    def run(impl):
        cfg = get_config("olmoe_1b_7b", reduced=True).with_(
            dtype="float32", moe_impl=impl, moe_dp_blocks=2, kv_groups=4)
        model = build(cfg)
        params = model.init(jax.random.key(0))
        B, S = 4, 16
        key = jax.random.key(1)
        batch = {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
        }

        def loss_fn(p, b):
            loss, m = model.loss(p, b)
            return loss

        with mesh, runtime.use_mesh(mesh if impl == "shard_map" else None):
            p_sh = param_shardings(cfg, mesh, params)
            b_sh = jax.tree.map(
                lambda l: NamedSharding(mesh, P("data", None)), batch)
            g = jax.jit(jax.value_and_grad(loss_fn),
                        in_shardings=(p_sh, b_sh))(params, batch)
        loss, grads = g
        flat = jax.tree.leaves(grads)
        return float(loss), [float(jnp.linalg.norm(x.astype(jnp.float32)))
                             for x in flat]

    l1, g1 = run("gspmd")
    l2, g2 = run("shard_map")
    print("RESULT " + json.dumps({"l1": l1, "l2": l2, "g1": g1, "g2": g2}))
""")


def test_shard_map_moe_matches_gspmd_on_8_devices():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    r = json.loads(line[len("RESULT "):])
    assert abs(r["l1"] - r["l2"]) < 1e-4 * max(1.0, abs(r["l1"])), r
    g1, g2 = np.asarray(r["g1"]), np.asarray(r["g2"])
    np.testing.assert_allclose(g1, g2, rtol=2e-3, atol=1e-5)
