"""Head padding + KV replication must not change the function computed.

Production runs carry kv_groups=16 (one KV slot per model-axis shard),
which pads q-heads per KV group and repeats KV heads. With the pad-head
weights zeroed (attn_init does this), the forward output must equal the
unpadded reference exactly — this is what makes the production sharding a
pure layout choice rather than a model change.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_config
from repro.models import blocks
from repro.models.config import ArchConfig
import pytest

# LM-zoo/trainer tests: tier-2 only (run with plain `pytest`)
pytestmark = pytest.mark.slow


def _mini_cfg(**kw):
    base = dict(arch_id="t", family="dense", n_layers=1, d_model=64,
                n_heads=6, n_kv_heads=2, d_ff=128, vocab=128,
                dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_padded_heads_math():
    # llava: 56 q heads, 8 kv, groups 16 -> pad groups of 7 to 8 => 64
    cfg = get_config("llava_next_34b").with_(kv_groups=16)
    assert cfg.padded_heads() == 64
    assert cfg.heads_per_group == 4
    # whisper: 8 q heads, 8 kv, groups 16 -> each group 1 -> 2 => 16
    cfg = get_config("whisper_base").with_(kv_groups=16)
    assert cfg.padded_heads() == 16
    # recurrentgemma: 10 q heads, 1 kv -> pad to 16
    cfg = get_config("recurrentgemma_2b").with_(kv_groups=16)
    assert cfg.padded_heads() == 16
    # qwen2: 64 q heads, 8 kv divide cleanly -> no padding
    cfg = get_config("qwen2_72b").with_(kv_groups=16)
    assert cfg.padded_heads() == 64
    # no-replication CPU mode: identity
    for arch in ("qwen2_72b", "llava_next_34b", "whisper_base"):
        cfg = get_config(arch)
        assert cfg.padded_heads() == cfg.n_heads


def _forward(cfg, x, params):
    return blocks.attn_apply(params, x, cfg, causal=True)


def test_padded_forward_equals_unpadded():
    """kv_groups=8 on a (6 q-heads, 2 kv) model pads each group 3->4; copy
    the real-head weights into the padded layout and compare outputs."""
    ref_cfg = _mini_cfg()                      # groups = kv = 2, no padding
    pad_cfg = _mini_cfg(kv_groups=8)           # pad 6 -> 8 q heads, kv rep 4x
    assert pad_cfg.padded_heads() == 8

    key = jax.random.key(0)
    p_ref = blocks.attn_init(key, ref_cfg)
    hd = ref_cfg.hd

    # build padded params from the reference weights
    g, gp, kv = 3, 4, 2
    wq = p_ref["wq"]["w"].reshape(ref_cfg.d_model, kv, g, hd)
    wq_pad = jnp.zeros((ref_cfg.d_model, kv, gp, hd))
    wq_pad = wq_pad.at[:, :, :g].set(wq)
    wo = p_ref["wo"]["w"].reshape(kv, g, hd, ref_cfg.d_model)
    wo_pad = jnp.zeros((kv, gp, hd, ref_cfg.d_model))
    wo_pad = wo_pad.at[:, :g].set(wo)
    p_pad = {
        "wq": {"w": wq_pad.reshape(ref_cfg.d_model, kv * gp * hd)},
        "wk": p_ref["wk"],
        "wv": p_ref["wv"],
        "wo": {"w": wo_pad.reshape(kv * gp * hd, ref_cfg.d_model)},
    }

    x = jax.random.normal(jax.random.key(1), (2, 24, ref_cfg.d_model),
                          jnp.float32)
    out_ref = _forward(ref_cfg, x, p_ref)
    out_pad = _forward(pad_cfg, x, p_pad)
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


def test_attn_init_zeroes_pad_heads():
    cfg = _mini_cfg(kv_groups=8)
    p = blocks.attn_init(jax.random.key(0), cfg)
    hd = cfg.hd
    wq = np.asarray(p["wq"]["w"]).reshape(cfg.d_model, 2, 4, hd)
    wo = np.asarray(p["wo"]["w"]).reshape(2, 4, hd, cfg.d_model)
    assert (wq[:, :, 3] == 0).all()            # pad slot per group
    assert (wo[:, 3] == 0).all()
    assert (wq[:, :, :3] != 0).any()
