# Compute hot-spots: serving top-k scan, KGE scoring, sliding-window attn.
from . import ops, ref
from .kge_score import kge_score_pallas
from .swa_attention import swa_attention_pallas
from .topk_similarity import topk_cosine_pallas

__all__ = ["ops", "ref", "kge_score_pallas", "swa_attention_pallas",
           "topk_cosine_pallas"]
