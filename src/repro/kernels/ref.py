"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; kernels must match them (tests assert_allclose,
sweeping shapes and dtypes).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------- topk_similarity --------------------------- #
def topk_cosine_blocked_ref(
    q_unit: jnp.ndarray,
    e_table: jnp.ndarray,
    k: int,
    exclude_rows: Optional[jnp.ndarray] = None,
    norms: Optional[jnp.ndarray] = None,
    block_n: int = 1024,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Blocked pure-jnp top-k: same contract as :func:`topk_cosine_ref`,
    computed over fixed (block_n, d) row tiles with a running top-k merge —
    scratch is O(block_n + k) regardless of N, so one jitted shape serves a
    100k-row table as well as a 1k-row one (it is also what each shard runs
    inside ``topk_cosine_sharded``: blocks-within-shards).

    ``norms`` (optional, per-row L2) folds normalization into the score:
    ``e_table`` may then be the raw mmap rows and each block is normalized
    with the exact float32 ops ``EmbeddingIndex.unit_rows`` uses, so scores
    are bit-identical to pre-normalizing the full table on the host.

    Merge tie-order matches one-shot ``lax.top_k`` on the full score
    matrix: running entries are concatenated *before* the current block's
    candidates and blocks are visited in ascending row order, so among
    equal scores the lower global index always wins — same as the global
    argmax. (Entries past ``valid`` are sentinel padding and may differ
    from the one-shot oracle there; the contract forbids surfacing them.)
    """
    n, d = e_table.shape
    qn = q_unit.shape[0]
    k_c = min(k, n)
    if exclude_rows is None:
        excl = jnp.full((qn,), -1, jnp.int32)
    else:
        excl = jnp.asarray(exclude_rows, jnp.int32)
    q = jnp.asarray(q_unit, jnp.float32)
    e = jnp.asarray(e_table, jnp.float32)
    nrm = None if norms is None else jnp.asarray(norms, jnp.float32)
    n_pad = -n % block_n
    if n_pad:
        e = jnp.concatenate([e, jnp.zeros((n_pad, d), e.dtype)], axis=0)
        if nrm is not None:
            # pad norms with 1.0: pad rows are zero vectors, and the
            # col >= n mask below sends them to -inf anyway
            nrm = jnp.concatenate([nrm, jnp.ones((n_pad,), nrm.dtype)])
    n_blocks = (n + n_pad) // block_n
    iota = jax.lax.broadcasted_iota(jnp.int32, (qn, block_n), 1)

    def body(b, carry):
        run_s, run_i = carry
        blk = jax.lax.dynamic_slice(e, (b * block_n, 0), (block_n, d))
        if nrm is not None:
            nb = jax.lax.dynamic_slice(nrm, (b * block_n,), (block_n,))
            blk = blk / jnp.maximum(nb[:, None], 1e-12)
        s = q @ blk.T                                      # (Q, block_n)
        col = b * block_n + iota
        s = jnp.where(col < n, s, NEG_INF)                 # pad rows
        s = jnp.where(col == excl[:, None], NEG_INF, s)    # self-exclusion
        cand_s = jnp.concatenate([run_s, s], axis=1)
        cand_i = jnp.concatenate([run_i, col], axis=1)
        s2, pos = jax.lax.top_k(cand_s, k_c)
        return s2, jnp.take_along_axis(cand_i, pos, axis=1)

    run = (jnp.full((qn, k_c), NEG_INF, jnp.float32),
           jnp.zeros((qn, k_c), jnp.int32))
    s, i = jax.lax.fori_loop(0, n_blocks, body, run)
    excluded = ((excl >= 0) & (excl < n)).astype(jnp.int32)
    valid = jnp.minimum(k_c, n - excluded)
    return s, i, valid


def topk_cosine_ref(
    q_unit: jnp.ndarray,
    e_unit: jnp.ndarray,
    k: int,
    exclude_rows: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q_unit (Q, d), e_unit (N, d), both row-normalized.

    Returns (scores (Q, k'), indices (Q, k'), valid (Q,)) sorted descending,
    with k' = min(k, N). ``exclude_rows`` masks one table row per query
    (-1 = none); entries past ``valid[q]`` are sentinel padding.
    """
    n = e_unit.shape[0]
    k = min(k, n)
    scores = q_unit @ e_unit.T
    if exclude_rows is None:
        excl = jnp.full((q_unit.shape[0],), -1, jnp.int32)
    else:
        excl = jnp.asarray(exclude_rows, jnp.int32)
    col = jnp.arange(n, dtype=jnp.int32)[None, :]
    scores = jnp.where(col == excl[:, None], NEG_INF, scores)
    s, i = jax.lax.top_k(scores, k)
    excluded = ((excl >= 0) & (excl < n)).astype(jnp.int32)
    valid = jnp.minimum(k, n - excluded)
    return s, i, valid


# ------------------------------ kge_score ------------------------------ #
def kge_score_ref(
    h: jnp.ndarray,            # (B, d) head embeddings
    r: jnp.ndarray,            # (B, d) relation embeddings
    t: jnp.ndarray,            # (B, d) tail embeddings
    neg: jnp.ndarray,          # (B, K, d) corrupting entity embeddings
    corrupt_head: jnp.ndarray, # (B, K) bool — True: neg replaces head
    model: str = "transe_l1",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused positive + negative scoring. Returns (pos (B,), neg (B, K))."""
    if model == "transe_l1":
        pos = -jnp.sum(jnp.abs(h + r - t), axis=-1)
        diff_h = neg + r[:, None, :] - t[:, None, :]    # neg as head
        diff_t = h[:, None, :] + r[:, None, :] - neg    # neg as tail
        diff = jnp.where(corrupt_head[..., None], diff_h, diff_t)
        negs = -jnp.sum(jnp.abs(diff), axis=-1)
    elif model == "transe_l2":
        pos = -jnp.sqrt(jnp.sum((h + r - t) ** 2, axis=-1) + 1e-12)
        diff_h = neg + r[:, None, :] - t[:, None, :]
        diff_t = h[:, None, :] + r[:, None, :] - neg
        diff = jnp.where(corrupt_head[..., None], diff_h, diff_t)
        negs = -jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    elif model == "distmult":
        pos = jnp.sum(h * r * t, axis=-1)
        s_h = jnp.sum(neg * (r * t)[:, None, :], axis=-1)
        s_t = jnp.sum((h * r)[:, None, :] * neg, axis=-1)
        negs = jnp.where(corrupt_head, s_h, s_t)
    else:
        raise ValueError(model)
    return pos, negs


# ---------------------------- swa_attention ---------------------------- #
def swa_attention_ref(
    q: jnp.ndarray,      # (B, Hq, Sq, d)
    k: jnp.ndarray,      # (B, Hkv, Skv, d)
    v: jnp.ndarray,      # (B, Hkv, Skv, d)
    window: int,         # attend to positions in (pos - window, pos]
    q_offset: int = 0,   # absolute position of q[..., 0, :] (decode: Skv-Sq)
) -> jnp.ndarray:
    """Causal sliding-window GQA attention, fp32 softmax. (B, Hq, Sq, d)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    causal = k_pos <= q_pos
    in_window = k_pos > q_pos - window
    mask = causal & in_window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)
