"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; kernels must match them (tests assert_allclose,
sweeping shapes and dtypes).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------- topk_similarity --------------------------- #
def topk_cosine_ref(
    q_unit: jnp.ndarray,
    e_unit: jnp.ndarray,
    k: int,
    exclude_rows: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q_unit (Q, d), e_unit (N, d), both row-normalized.

    Returns (scores (Q, k'), indices (Q, k'), valid (Q,)) sorted descending,
    with k' = min(k, N). ``exclude_rows`` masks one table row per query
    (-1 = none); entries past ``valid[q]`` are sentinel padding.
    """
    n = e_unit.shape[0]
    k = min(k, n)
    scores = q_unit @ e_unit.T
    if exclude_rows is None:
        excl = jnp.full((q_unit.shape[0],), -1, jnp.int32)
    else:
        excl = jnp.asarray(exclude_rows, jnp.int32)
    col = jnp.arange(n, dtype=jnp.int32)[None, :]
    scores = jnp.where(col == excl[:, None], NEG_INF, scores)
    s, i = jax.lax.top_k(scores, k)
    excluded = ((excl >= 0) & (excl < n)).astype(jnp.int32)
    valid = jnp.minimum(k, n - excluded)
    return s, i, valid


# ------------------------------ kge_score ------------------------------ #
def kge_score_ref(
    h: jnp.ndarray,            # (B, d) head embeddings
    r: jnp.ndarray,            # (B, d) relation embeddings
    t: jnp.ndarray,            # (B, d) tail embeddings
    neg: jnp.ndarray,          # (B, K, d) corrupting entity embeddings
    corrupt_head: jnp.ndarray, # (B, K) bool — True: neg replaces head
    model: str = "transe_l1",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused positive + negative scoring. Returns (pos (B,), neg (B, K))."""
    if model == "transe_l1":
        pos = -jnp.sum(jnp.abs(h + r - t), axis=-1)
        diff_h = neg + r[:, None, :] - t[:, None, :]    # neg as head
        diff_t = h[:, None, :] + r[:, None, :] - neg    # neg as tail
        diff = jnp.where(corrupt_head[..., None], diff_h, diff_t)
        negs = -jnp.sum(jnp.abs(diff), axis=-1)
    elif model == "transe_l2":
        pos = -jnp.sqrt(jnp.sum((h + r - t) ** 2, axis=-1) + 1e-12)
        diff_h = neg + r[:, None, :] - t[:, None, :]
        diff_t = h[:, None, :] + r[:, None, :] - neg
        diff = jnp.where(corrupt_head[..., None], diff_h, diff_t)
        negs = -jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    elif model == "distmult":
        pos = jnp.sum(h * r * t, axis=-1)
        s_h = jnp.sum(neg * (r * t)[:, None, :], axis=-1)
        s_t = jnp.sum((h * r)[:, None, :] * neg, axis=-1)
        negs = jnp.where(corrupt_head, s_h, s_t)
    else:
        raise ValueError(model)
    return pos, negs


# ---------------------------- swa_attention ---------------------------- #
def swa_attention_ref(
    q: jnp.ndarray,      # (B, Hq, Sq, d)
    k: jnp.ndarray,      # (B, Hkv, Skv, d)
    v: jnp.ndarray,      # (B, Hkv, Skv, d)
    window: int,         # attend to positions in (pos - window, pos]
    q_offset: int = 0,   # absolute position of q[..., 0, :] (decode: Skv-Sq)
) -> jnp.ndarray:
    """Causal sliding-window GQA attention, fp32 softmax. (B, Hq, Sq, d)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    causal = k_pos <= q_pos
    in_window = k_pos > q_pos - window
    mask = causal & in_window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)
