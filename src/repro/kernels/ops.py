"""jit'd public wrappers for the Pallas kernels.

``use_pallas`` dispatch: on this CPU container the kernels execute in
interpret mode (numerically identical, slow); the pure-jnp reference path is
the default for jitted production lowering on CPU and the shape source of
truth. On a real TPU, flip REPRO_USE_PALLAS=1 (or pass use_pallas=True) and
the same call sites run the compiled kernels with interpret=False.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from . import ref
from .kge_score import kge_score_pallas
from .swa_attention import swa_attention_pallas
from .topk_similarity import topk_cosine_pallas

_ENV_FLAG = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
# on CPU, pallas runs in interpret mode; on TPU, compiled
_INTERPRET = jax.default_backend() != "tpu"


def _use_pallas(flag: Optional[bool]) -> bool:
    return _ENV_FLAG if flag is None else flag


def topk_cosine(q_unit: jnp.ndarray, e_unit: jnp.ndarray, k: int,
                exclude_rows: Optional[jnp.ndarray] = None,
                use_pallas: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(Q, d) x (N, d) -> (scores, indices, valid), descending per row.

    k is clamped to N; ``exclude_rows`` (−1 = none) masks one table row per
    query inside the kernel; entries past ``valid[q]`` are sentinel padding
    that callers must not surface.
    """
    if _use_pallas(flag=use_pallas):
        block_n = min(1024, max(128, e_unit.shape[0]))
        return topk_cosine_pallas(q_unit, e_unit, k,
                                  exclude_rows=exclude_rows,
                                  block_n=block_n, interpret=_INTERPRET)
    return ref.topk_cosine_ref(q_unit, e_unit, k, exclude_rows=exclude_rows)


def mesh_data_shards(mesh, axis: str = "data") -> int:
    """Number of table shards a mesh provides (1 = no sharding)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(axis, 1))


def shard_table(e_unit: jnp.ndarray, mesh, axis: str = "data"
                ) -> Tuple[jnp.ndarray, int]:
    """Lay an (N, d) table out ``P(axis, None)`` across the mesh devices.

    N is zero-padded to a multiple of the axis size (shard_map needs even
    row blocks); returns ``(sharded table, n_valid)`` where ``n_valid`` is
    the real row count — pass both to :func:`topk_cosine_sharded`.
    """
    shards = mesh_data_shards(mesh, axis)
    e = jnp.asarray(e_unit, jnp.float32)
    pad = -e.shape[0] % shards
    if pad:
        e = jnp.concatenate([e, jnp.zeros((pad, e.shape[1]), e.dtype)], axis=0)
    return jax.device_put(e, NamedSharding(mesh, P(axis, None))), int(e_unit.shape[0])


@functools.lru_cache(maxsize=128)
def _sharded_topk_fn(mesh, axis: str, n_real: int, n_total: int, k: int,
                     use_pallas: bool, interpret: bool):
    """Build (and cache) the jitted sharded top-k for one table layout.

    Each shard runs the existing single-device kernel contract on its
    (local_n, d) row block — global ``exclude_rows`` are translated to
    shard-local coordinates (−1 when the excluded row lives elsewhere) —
    then a global merge top-k's the gathered shard candidates.

    Shard-merge invariants:
      * local fetch depth is ``min(k + n_pad, local_n)``: the zero rows
        padding N up to a shard multiple can occupy at most ``n_pad``
        local top-k slots (all in the last shard), so fetching that many
        extras guarantees every global top-k row survives its shard;
      * pad candidates (global index >= n_real) are masked to −inf after
        the local top-k, never surfaced;
      * ``valid`` is computed globally — min(k', N − excluded) with
        k' = min(k, N) — identical to the single-device contract.
    """
    shards = mesh_data_shards(mesh, axis)
    local_n = n_total // shards
    n_pad = n_total - n_real
    k_c = min(k, n_real)
    k_fetch = min(k + n_pad, local_n)

    def local_topk(q, e_loc, excl):
        off = jax.lax.axis_index(axis).astype(jnp.int32) * local_n
        loc = jnp.where((excl >= off) & (excl < off + local_n),
                        excl - off, -1).astype(jnp.int32)
        if use_pallas:
            block_n = min(1024, max(128, local_n))
            s, i, _ = topk_cosine_pallas(q, e_loc, k_fetch, exclude_rows=loc,
                                         block_n=block_n, interpret=interpret)
        else:
            s, i, _ = ref.topk_cosine_ref(q, e_loc, k_fetch, exclude_rows=loc)
        gi = i + off
        s = jnp.where(gi < n_real, s, ref.NEG_INF)
        return s, gi

    # check_rep=False: pallas_call has no replication rule yet, and the
    # outputs are explicitly sharded over ``axis`` anyway
    mapped = shard_map(local_topk, mesh=mesh,
                       in_specs=(P(None, None), P(axis, None), P(None)),
                       out_specs=(P(None, axis), P(None, axis)),
                       check_rep=False)

    @jax.jit
    def run(q, e, excl):
        cand_s, cand_i = mapped(q, e, excl)      # (Q, shards * k_fetch)
        s, pos = jax.lax.top_k(cand_s, k_c)
        i = jnp.take_along_axis(cand_i, pos, axis=1)
        excluded = ((excl >= 0) & (excl < n_real)).astype(jnp.int32)
        valid = jnp.minimum(k_c, n_real - excluded)
        return s, i, valid

    return run


def topk_cosine_sharded(q_unit: jnp.ndarray, e_unit: jnp.ndarray, k: int,
                        exclude_rows: Optional[jnp.ndarray] = None,
                        mesh=None, axis: str = "data",
                        n_valid: Optional[int] = None,
                        use_pallas: Optional[bool] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Device-sharded :func:`topk_cosine`: the (N, d) table is split in row
    blocks across the mesh's ``axis`` devices, each shard computes a local
    top-k via the single-device kernel contract, and a final merge reduces
    shard candidates to the global top-k.

    ``e_unit`` may carry zero-row padding (``n_valid`` = real rows; use
    :func:`shard_table` to lay the table out). Falls back to the
    single-device path — bit-identical contract — when the mesh has one
    device (or none) on ``axis``.
    """
    n_total = e_unit.shape[0]
    n_real = n_total if n_valid is None else int(n_valid)
    shards = mesh_data_shards(mesh, axis)
    if shards <= 1:
        return topk_cosine(q_unit, e_unit[:n_real], k,
                           exclude_rows=exclude_rows, use_pallas=use_pallas)
    if n_total % shards:
        raise ValueError(
            f"table rows ({n_total}) must divide the {axis!r} axis "
            f"({shards}); lay the table out with shard_table()")
    qn = q_unit.shape[0]
    if exclude_rows is None:
        exclude_rows = jnp.full((qn,), -1, jnp.int32)
    run = _sharded_topk_fn(mesh, axis, n_real, n_total, int(k),
                           _use_pallas(flag=use_pallas), _INTERPRET)
    return run(q_unit.astype(jnp.float32), e_unit,
               jnp.asarray(exclude_rows, jnp.int32))


def kge_score(h, r, t, neg, corrupt_head, model: str = "transe_l1",
              use_pallas: Optional[bool] = None):
    """Fused positive+negative KGE scoring. Returns (pos (B,), neg (B, K))."""
    if _use_pallas(flag=use_pallas):
        return kge_score_pallas(h, r, t, neg, corrupt_head, model=model,
                                interpret=_INTERPRET)
    return ref.kge_score_ref(h, r, t, neg, corrupt_head, model=model)


def swa_attention(q, k, v, window: int, q_offset: int = 0,
                  use_pallas: Optional[bool] = None):
    """Sliding-window GQA attention.

    Accepts (B, H, S, d) tensors (ref layout); the pallas path folds heads.
    """
    if _use_pallas(flag=use_pallas):
        b, hq, sq, d = q.shape
        _, hkv, skv, _ = k.shape
        qf = q.reshape(b * hq, sq, d)
        kf = k.reshape(b * hkv, skv, d)
        vf = v.reshape(b * hkv, skv, d)
        out = swa_attention_pallas(qf, kf, vf, window=window, q_offset=q_offset,
                                   interpret=_INTERPRET)
        return out.reshape(b, hq, sq, d)
    return ref.swa_attention_ref(q, k, v, window=window, q_offset=q_offset)
