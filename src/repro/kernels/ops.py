"""jit'd public wrappers for the Pallas kernels.

``use_pallas`` dispatch: on this CPU container the kernels execute in
interpret mode (numerically identical, slow); the pure-jnp reference path is
the default for jitted production lowering on CPU and the shape source of
truth. On a real TPU, flip REPRO_USE_PALLAS=1 (or pass use_pallas=True) and
the same call sites run the compiled kernels with interpret=False.

Streaming table residency (PR 8): when ``topk_cosine`` receives a host
table (``np.ndarray`` / ``np.memmap``), it never puts the whole (N, d)
array on device.  The host loop walks the table in fixed ``block_rows``
slabs — each slab is transferred, scored (with the sidecar ``norms``
folded into the kernel, so no unit copy exists on *either* side), and
merged into a running (Q, k) top-k.  Peak device allocation is
O(block_rows·d + Q·k) regardless of N; ``stream_stats`` records it so the
scale bench can assert the bound.  A jnp-array table keeps the original
device-resident single-launch path (the mesh-sharded path also stays
device-resident — residency there is the sharding itself).
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from . import ref
from .kge_score import kge_score_pallas
from .swa_attention import swa_attention_pallas
from .topk_similarity import topk_cosine_pallas

_ENV_FLAG = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
# on CPU, pallas runs in interpret mode; on TPU, compiled
_INTERPRET = jax.default_backend() != "tpu"

#: host-block size for the streaming top-k driver: 8192 rows × 200 dims ×
#: 4 B ≈ 6.6 MB per transfer — large enough to amortize dispatch, small
#: enough that a dozen concurrent streams fit VMEM-scale budgets
STREAM_BLOCK_ROWS = 8192

#: in-shard block size for the blocked ref path inside the sharded merge
SHARD_BLOCK_N = 1024

#: cumulative streaming-driver counters (reset with reset_stream_stats):
#: ``peak_block_bytes`` is the largest single device transfer (table block
#: + norms block) any streamed call made — the scale bench asserts it stays
#: O(block_rows·d), i.e. no full-table private device copy ever happened
stream_stats = {"calls": 0, "blocks": 0, "peak_block_bytes": 0}


def reset_stream_stats() -> None:
    stream_stats.update({"calls": 0, "blocks": 0, "peak_block_bytes": 0})


def _use_pallas(flag: Optional[bool]) -> bool:
    return _ENV_FLAG if flag is None else flag


@functools.partial(jax.jit, static_argnames=("k", "has_norms"))
def _stream_step_ref(q, blk, nrm, offset, limit, excl, run_s, run_i, *,
                     k: int, has_norms: bool):
    """Score one (block_rows, d) slab and merge it into the running top-k.

    ``offset``/``limit`` are traced scalars (block start, real table rows),
    so every block of every same-shaped table reuses one compiled step.
    Tie-order matches the one-shot oracle: running entries concatenate
    first and always carry lower global indices than the current block
    (blocks ascend), so equal scores resolve to the lower global index —
    exactly ``lax.top_k`` over the full score matrix.
    """
    if has_norms:
        blk = blk / jnp.maximum(nrm[:, None], 1e-12)
    s = q @ blk.T                                          # (Q, bs)
    col = offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < limit, s, ref.NEG_INF)             # tail-pad rows
    s = jnp.where(col == excl[:, None], ref.NEG_INF, s)    # self-exclusion
    cand_s = jnp.concatenate([run_s, s], axis=1)
    cand_i = jnp.concatenate([run_i, col], axis=1)
    s2, pos = jax.lax.top_k(cand_s, k)
    return s2, jnp.take_along_axis(cand_i, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("k",))
def _stream_merge(run_s, run_i, blk_s, blk_i, offset, *, k: int):
    """Fold one block's local top-k (pallas backend) into the running
    top-k; local indices shift by ``offset`` to global.  Same concat order
    (running first) as ``_stream_step_ref`` — same tie semantics."""
    cand_s = jnp.concatenate([run_s, blk_s], axis=1)
    cand_i = jnp.concatenate([run_i, blk_i + offset], axis=1)
    s2, pos = jax.lax.top_k(cand_s, k)
    return s2, jnp.take_along_axis(cand_i, pos, axis=1)


def _topk_stream(q_unit, e_table: np.ndarray, k: int, exclude_rows,
                 norms, use_pallas: bool, block_rows: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Host-block streaming driver over an ``np.ndarray``/``np.memmap``
    table: per block, copy a (bs, d) slab host→device, score it (norms
    folded in-kernel), merge into the running (Q, k') top-k.  The table is
    never resident on device and never normalized as a whole anywhere."""
    n, d = e_table.shape
    qn = q_unit.shape[0]
    k_c = min(int(k), n)
    bs = min(int(block_rows), n)
    if exclude_rows is None:
        excl_np = np.full((qn,), -1, np.int32)
    else:
        excl_np = np.asarray(exclude_rows, np.int32)
    excl = jnp.asarray(excl_np)
    q = jnp.asarray(q_unit, jnp.float32)
    has_norms = norms is not None
    norms_np = None if norms is None else np.asarray(norms)

    run_s = jnp.full((qn, k_c), ref.NEG_INF, jnp.float32)
    run_i = jnp.zeros((qn, k_c), jnp.int32)
    limit = jnp.int32(n)
    peak = 0
    n_blocks = 0
    for start in range(0, n, bs):
        rows = min(bs, n - start)
        if use_pallas:
            # the pallas kernel tiles internally and masks past its own
            # n_real, so hand it exactly the real rows of this slab
            blk = jnp.asarray(np.ascontiguousarray(
                e_table[start:start + rows], dtype=np.float32))
            nrm = (jnp.asarray(np.ascontiguousarray(
                norms_np[start:start + rows], dtype=np.float32))
                if has_norms else None)
            loc = np.where((excl_np >= start) & (excl_np < start + rows),
                           excl_np - start, -1).astype(np.int32)
            kb = min(k_c, rows)
            bn = min(1024, max(128, rows))
            blk_s, blk_i, _ = topk_cosine_pallas(
                q, blk, kb, exclude_rows=jnp.asarray(loc), norms=nrm,
                block_n=bn, interpret=_INTERPRET)
            run_s, run_i = _stream_merge(run_s, run_i, blk_s, blk_i,
                                         jnp.int32(start), k=k_c)
            peak = max(peak, rows * d * 4 + (rows * 4 if has_norms else 0))
        else:
            # fixed-size slab (tail zero-padded) → one jitted step shape.
            # The staging arrays MUST be freshly allocated per block:
            # jnp.asarray can adopt an aligned numpy buffer zero-copy on
            # CPU, so a reused scratch array would be rewritten under the
            # previous (async-dispatched) step and merge the wrong rows.
            blk_host = np.zeros((bs, d), np.float32)
            blk_host[:rows] = e_table[start:start + rows]
            nrm_host = np.ones((bs,), np.float32)
            if has_norms:
                nrm_host[:rows] = norms_np[start:start + rows]
            run_s, run_i = _stream_step_ref(
                q, jnp.asarray(blk_host), jnp.asarray(nrm_host),
                jnp.int32(start), limit, excl, run_s, run_i,
                k=k_c, has_norms=has_norms)
            peak = max(peak, bs * d * 4 + bs * 4)
        n_blocks += 1
    stream_stats["calls"] += 1
    stream_stats["blocks"] += n_blocks
    stream_stats["peak_block_bytes"] = max(
        stream_stats["peak_block_bytes"], peak)
    excluded = ((excl_np >= 0) & (excl_np < n)).astype(np.int32)
    valid = jnp.asarray(np.minimum(k_c, n - excluded).astype(np.int32))
    return run_s, run_i, valid


def topk_cosine(q_unit, e_table, k: int,
                exclude_rows=None,
                use_pallas: Optional[bool] = None,
                norms=None,
                block_rows: Optional[int] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(Q, d) x (N, d) -> (scores, indices, valid), descending per row.

    k is clamped to N; ``exclude_rows`` (−1 = none) masks one table row per
    query inside the kernel; entries past ``valid[q]`` are sentinel padding
    that callers must not surface.

    ``e_table`` may be a host ``np.ndarray``/``np.memmap`` — then the
    streaming driver above runs (norms folded in-kernel, O(block) device
    scratch).  A jnp array takes the single-launch device path, unchanged
    from the pre-streaming contract.  ``norms`` (per-row L2) lets both
    paths score a raw, un-normalized table.
    """
    if isinstance(e_table, np.ndarray) and not isinstance(e_table, jnp.ndarray):
        return _topk_stream(q_unit, e_table, k, exclude_rows=exclude_rows,
                            norms=norms, use_pallas=_use_pallas(use_pallas),
                            block_rows=block_rows or STREAM_BLOCK_ROWS)
    if _use_pallas(flag=use_pallas):
        block_n = min(1024, max(128, e_table.shape[0]))
        return topk_cosine_pallas(q_unit, e_table, k,
                                  exclude_rows=exclude_rows, norms=norms,
                                  block_n=block_n, interpret=_INTERPRET)
    if norms is not None:
        return ref.topk_cosine_blocked_ref(
            q_unit, e_table, k, exclude_rows=exclude_rows, norms=norms,
            block_n=min(SHARD_BLOCK_N, max(128, e_table.shape[0])))
    return ref.topk_cosine_ref(q_unit, e_table, k, exclude_rows=exclude_rows)


def topk_cosine_join(q_unit, e_table, k: int,
                     exclude_rows=None,
                     norms=None,
                     use_pallas: Optional[bool] = None,
                     query_block_rows: int = 256,
                     block_rows: Optional[int] = None):
    """Slab-iterated all-pairs kNN join: generator over query slabs.

    Walks the (Q, d) query block in fixed ``query_block_rows`` slabs and
    runs each through :func:`topk_cosine` (streaming table residency when
    ``e_table`` is a host array), yielding ``(start, scores, indices,
    valid)`` with the slab's results trimmed to its real rows.  Peak
    allocation is O(query_block · table_block + query_block · k) no matter
    how long the join list is, and the caller regains control between
    slabs — the job executor uses that boundary to publish progress,
    observe cancellation, and yield to interactive traffic.

    The final partial slab is zero-padded up to ``query_block_rows``
    (pad exclusions −1) so every slab reuses one compiled step shape;
    pad rows are dropped before yielding.  Row results are bit-identical
    to a serial per-query :func:`topk_cosine` call: each output row of
    the slab matmul accumulates independently of its neighbors.
    """
    q = np.asarray(q_unit, np.float32)
    qn = q.shape[0]
    s = max(1, int(query_block_rows))
    if exclude_rows is None:
        excl_np = np.full((qn,), -1, np.int32)
    else:
        excl_np = np.asarray(exclude_rows, np.int32)
    for start in range(0, qn, s):
        rows = min(s, qn - start)
        q_slab = q[start:start + rows]
        e_slab = excl_np[start:start + rows]
        if rows < s:
            q_slab = np.concatenate(
                [q_slab, np.zeros((s - rows, q.shape[1]), np.float32)])
            e_slab = np.concatenate(
                [e_slab, np.full((s - rows,), -1, np.int32)])
        sc, ix, va = topk_cosine(q_slab, e_table, k, exclude_rows=e_slab,
                                 use_pallas=use_pallas, norms=norms,
                                 block_rows=block_rows)
        yield (start, np.asarray(sc)[:rows], np.asarray(ix)[:rows],
               np.asarray(va)[:rows])


def mesh_data_shards(mesh, axis: str = "data") -> int:
    """Number of table shards a mesh provides (1 = no sharding)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(axis, 1))


def shard_table(e_unit: jnp.ndarray, mesh, axis: str = "data"
                ) -> Tuple[jnp.ndarray, int]:
    """Lay an (N, d) table out ``P(axis, None)`` across the mesh devices.

    N is zero-padded to a multiple of the axis size (shard_map needs even
    row blocks); returns ``(sharded table, n_valid)`` where ``n_valid`` is
    the real row count — pass both to :func:`topk_cosine_sharded`.
    """
    shards = mesh_data_shards(mesh, axis)
    e = jnp.asarray(e_unit, jnp.float32)
    pad = -e.shape[0] % shards
    if pad:
        e = jnp.concatenate([e, jnp.zeros((pad, e.shape[1]), e.dtype)], axis=0)
    return jax.device_put(e, NamedSharding(mesh, P(axis, None))), int(e_unit.shape[0])


def shard_table_raw(e_table, norms, mesh, axis: str = "data"
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """:func:`shard_table` for a *raw* (un-normalized) table plus its
    per-row L2 norms: rows are zero-padded, norms are one-padded (so pad
    rows stay zero after the in-kernel division), and both land sharded
    ``P(axis, …)`` on the mesh.  Returns ``(table, norms, n_valid)`` —
    pass all three (norms via ``norms=``) to :func:`topk_cosine_sharded`,
    which then normalizes each in-shard block in-kernel: no full unit copy
    exists on any device.
    """
    shards = mesh_data_shards(mesh, axis)
    e = jnp.asarray(e_table, jnp.float32)
    nrm = jnp.asarray(norms, jnp.float32)
    pad = -e.shape[0] % shards
    if pad:
        e = jnp.concatenate([e, jnp.zeros((pad, e.shape[1]), e.dtype)], axis=0)
        nrm = jnp.concatenate([nrm, jnp.ones((pad,), nrm.dtype)])
    return (jax.device_put(e, NamedSharding(mesh, P(axis, None))),
            jax.device_put(nrm, NamedSharding(mesh, P(axis))),
            int(e_table.shape[0]))


@functools.lru_cache(maxsize=128)
def _sharded_topk_fn(mesh, axis: str, n_real: int, n_total: int, k: int,
                     use_pallas: bool, interpret: bool, has_norms: bool):
    """Build (and cache) the jitted sharded top-k for one table layout.

    Each shard runs the existing single-device kernel contract on its
    (local_n, d) row block — global ``exclude_rows`` are translated to
    shard-local coordinates (−1 when the excluded row lives elsewhere) —
    then a global merge top-k's the gathered shard candidates.

    Shard-merge invariants:
      * local fetch depth is ``min(k + n_pad, local_n)``: the zero rows
        padding N up to a shard multiple can occupy at most ``n_pad``
        local top-k slots (all in the last shard), so fetching that many
        extras guarantees every global top-k row survives its shard;
      * pad candidates (global index >= n_real) are masked to −inf after
        the local top-k, never surfaced;
      * ``valid`` is computed globally — min(k', N − excluded) with
        k' = min(k, N) — identical to the single-device contract.
    """
    shards = mesh_data_shards(mesh, axis)
    local_n = n_total // shards
    n_pad = n_total - n_real
    k_c = min(k, n_real)
    k_fetch = min(k + n_pad, local_n)

    def local_topk(q, e_loc, nrm_loc, excl):
        off = jax.lax.axis_index(axis).astype(jnp.int32) * local_n
        loc = jnp.where((excl >= off) & (excl < off + local_n),
                        excl - off, -1).astype(jnp.int32)
        block_n = min(SHARD_BLOCK_N, max(128, local_n))
        if use_pallas:
            s, i, _ = topk_cosine_pallas(
                q, e_loc, k_fetch, exclude_rows=loc,
                norms=nrm_loc if has_norms else None,
                block_n=block_n, interpret=interpret)
        elif has_norms:
            # blocks-within-shards: the blocked ref walks this shard's
            # rows in O(block_n) tiles, normalizing each tile in-kernel
            s, i, _ = ref.topk_cosine_blocked_ref(
                q, e_loc, k_fetch, exclude_rows=loc, norms=nrm_loc,
                block_n=block_n)
        else:
            s, i, _ = ref.topk_cosine_ref(q, e_loc, k_fetch, exclude_rows=loc)
        gi = i + off
        s = jnp.where(gi < n_real, s, ref.NEG_INF)
        return s, gi

    # check_rep=False: pallas_call has no replication rule yet, and the
    # outputs are explicitly sharded over ``axis`` anyway
    mapped = shard_map(local_topk, mesh=mesh,
                       in_specs=(P(None, None), P(axis, None), P(axis), P(None)),
                       out_specs=(P(None, axis), P(None, axis)),
                       check_rep=False)

    @jax.jit
    def run(q, e, nrm, excl):
        cand_s, cand_i = mapped(q, e, nrm, excl)  # (Q, shards * k_fetch)
        s, pos = jax.lax.top_k(cand_s, k_c)
        i = jnp.take_along_axis(cand_i, pos, axis=1)
        excluded = ((excl >= 0) & (excl < n_real)).astype(jnp.int32)
        valid = jnp.minimum(k_c, n_real - excluded)
        return s, i, valid

    return run


def topk_cosine_sharded(q_unit: jnp.ndarray, e_unit: jnp.ndarray, k: int,
                        exclude_rows: Optional[jnp.ndarray] = None,
                        mesh=None, axis: str = "data",
                        n_valid: Optional[int] = None,
                        use_pallas: Optional[bool] = None,
                        norms: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Device-sharded :func:`topk_cosine`: the (N, d) table is split in row
    blocks across the mesh's ``axis`` devices, each shard computes a local
    top-k via the single-device kernel contract, and a final merge reduces
    shard candidates to the global top-k.

    ``e_unit`` may carry zero-row padding (``n_valid`` = real rows; use
    :func:`shard_table` — or :func:`shard_table_raw` with ``norms`` for a
    raw table normalized in-kernel per block). Falls back to the
    single-device path — bit-identical contract — when the mesh has one
    device (or none) on ``axis``.
    """
    n_total = e_unit.shape[0]
    n_real = n_total if n_valid is None else int(n_valid)
    shards = mesh_data_shards(mesh, axis)
    if shards <= 1:
        return topk_cosine(q_unit, e_unit[:n_real], k,
                           exclude_rows=exclude_rows, use_pallas=use_pallas,
                           norms=None if norms is None else norms[:n_real])
    if n_total % shards:
        raise ValueError(
            f"table rows ({n_total}) must divide the {axis!r} axis "
            f"({shards}); lay the table out with shard_table()")
    qn = q_unit.shape[0]
    if exclude_rows is None:
        exclude_rows = jnp.full((qn,), -1, jnp.int32)
    has_norms = norms is not None
    if has_norms:
        nrm = jnp.asarray(norms, jnp.float32)
    else:
        # uniform operand shape keeps one cached shard_map program; the
        # has_norms static flag skips the division entirely
        nrm = jnp.ones((n_total,), jnp.float32)
        nrm = jax.device_put(nrm, NamedSharding(mesh, P(axis)))
    run = _sharded_topk_fn(mesh, axis, n_real, n_total, int(k),
                           _use_pallas(flag=use_pallas), _INTERPRET,
                           has_norms)
    return run(q_unit.astype(jnp.float32), e_unit, nrm,
               jnp.asarray(exclude_rows, jnp.int32))


def kge_score(h, r, t, neg, corrupt_head, model: str = "transe_l1",
              use_pallas: Optional[bool] = None):
    """Fused positive+negative KGE scoring. Returns (pos (B,), neg (B, K))."""
    if _use_pallas(flag=use_pallas):
        return kge_score_pallas(h, r, t, neg, corrupt_head, model=model,
                                interpret=_INTERPRET)
    return ref.kge_score_ref(h, r, t, neg, corrupt_head, model=model)


def swa_attention(q, k, v, window: int, q_offset: int = 0,
                  use_pallas: Optional[bool] = None):
    """Sliding-window GQA attention.

    Accepts (B, H, S, d) tensors (ref layout); the pallas path folds heads.
    """
    if _use_pallas(flag=use_pallas):
        b, hq, sq, d = q.shape
        _, hkv, skv, _ = k.shape
        qf = q.reshape(b * hq, sq, d)
        kf = k.reshape(b * hkv, skv, d)
        vf = v.reshape(b * hkv, skv, d)
        out = swa_attention_pallas(qf, kf, vf, window=window, q_offset=q_offset,
                                   interpret=_INTERPRET)
        return out.reshape(b, hq, sq, d)
    return ref.swa_attention_ref(q, k, v, window=window, q_offset=q_offset)
