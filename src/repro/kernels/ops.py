"""jit'd public wrappers for the Pallas kernels.

``use_pallas`` dispatch: on this CPU container the kernels execute in
interpret mode (numerically identical, slow); the pure-jnp reference path is
the default for jitted production lowering on CPU and the shape source of
truth. On a real TPU, flip REPRO_USE_PALLAS=1 (or pass use_pallas=True) and
the same call sites run the compiled kernels with interpret=False.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .kge_score import kge_score_pallas
from .swa_attention import swa_attention_pallas
from .topk_similarity import topk_cosine_pallas

_ENV_FLAG = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
# on CPU, pallas runs in interpret mode; on TPU, compiled
_INTERPRET = jax.default_backend() != "tpu"


def _use_pallas(flag: Optional[bool]) -> bool:
    return _ENV_FLAG if flag is None else flag


def topk_cosine(q_unit: jnp.ndarray, e_unit: jnp.ndarray, k: int,
                exclude_rows: Optional[jnp.ndarray] = None,
                use_pallas: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(Q, d) x (N, d) -> (scores, indices, valid), descending per row.

    k is clamped to N; ``exclude_rows`` (−1 = none) masks one table row per
    query inside the kernel; entries past ``valid[q]`` are sentinel padding
    that callers must not surface.
    """
    if _use_pallas(flag=use_pallas):
        block_n = min(1024, max(128, e_unit.shape[0]))
        return topk_cosine_pallas(q_unit, e_unit, k,
                                  exclude_rows=exclude_rows,
                                  block_n=block_n, interpret=_INTERPRET)
    return ref.topk_cosine_ref(q_unit, e_unit, k, exclude_rows=exclude_rows)


def kge_score(h, r, t, neg, corrupt_head, model: str = "transe_l1",
              use_pallas: Optional[bool] = None):
    """Fused positive+negative KGE scoring. Returns (pos (B,), neg (B, K))."""
    if _use_pallas(flag=use_pallas):
        return kge_score_pallas(h, r, t, neg, corrupt_head, model=model,
                                interpret=_INTERPRET)
    return ref.kge_score_ref(h, r, t, neg, corrupt_head, model=model)


def swa_attention(q, k, v, window: int, q_offset: int = 0,
                  use_pallas: Optional[bool] = None):
    """Sliding-window GQA attention.

    Accepts (B, H, S, d) tensors (ref layout); the pallas path folds heads.
    """
    if _use_pallas(flag=use_pallas):
        b, hq, sq, d = q.shape
        _, hkv, skv, _ = k.shape
        qf = q.reshape(b * hq, sq, d)
        kf = k.reshape(b * hkv, skv, d)
        vf = v.reshape(b * hkv, skv, d)
        out = swa_attention_pallas(qf, kf, vf, window=window, q_offset=q_offset,
                                   interpret=_INTERPRET)
        return out.reshape(b, hq, sq, d)
    return ref.swa_attention_ref(q, k, v, window=window, q_offset=q_offset)
