"""Fused cosine top-k Pallas kernel — the Bio-KGvec2go serving hot spot.

The paper's *top closest concepts* endpoint scans all N class vectors per
query. TPU adaptation: stream the (N, d) table through VMEM in
(block_n, d) slabs, compute q·Eᵀ on the MXU per slab, and keep a running
top-k (scores + global indices) in VMEM across grid steps — one HBM pass
over the table, no (Q, N) score matrix ever materialized.

Grid: (N // block_n,) — sequential on TPU, so the output block is safely
revisited and acts as the running accumulator. The merge is k rounds of
(max, argmax, mask) over the (Q, k + block_n) candidate row — k is small
(10 in the paper) so this stays in VREGs.

Serving-correctness contract (PR 1):
  * ``exclude_rows`` — per-query table row to mask out (−1 for none). The
    serving layer uses this for self-exclusion, replacing the old
    "ask for k+1 then filter in Python" dance, which silently returned
    k−1 results whenever the query row was *not* in the top k+1.
  * ``k`` is clamped to N at trace time, and a per-query ``valid`` count
    is returned: entries ``[valid:]`` of a row are sentinel padding
    (score −1e30, index 0) and must not be surfaced. Before this, k > N
    leaked sentinel rows pointing at entity 0 into API responses.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _topk_kernel(q_ref, e_ref, n_ref, x_ref, out_s_ref, out_i_ref, out_v_ref,
                 *, k: int, block_n: int, n_real: int, has_norms: bool):
    step = pl.program_id(0)
    excl = x_ref[...]                    # (Q, 1) int32, -1 = no exclusion

    @pl.when(step == 0)
    def _init():
        out_s_ref[...] = jnp.full_like(out_s_ref, NEG_INF)
        out_i_ref[...] = jnp.zeros_like(out_i_ref)
        excluded = ((excl >= 0) & (excl < n_real)).astype(jnp.int32)
        out_v_ref[...] = jnp.minimum(k, n_real - excluded)

    q = q_ref[...]                       # (Q, d)
    e = e_ref[...]                       # (block_n, d)
    if has_norms:
        # fold the per-row L2 norms into the score: the exact float32 ops
        # EmbeddingIndex.unit_rows uses, so raw mmap rows + sidecar norms
        # score bit-identically to a host-normalized table
        e = e / jnp.maximum(n_ref[...], 1e-12)            # (block_n, 1) bcast
    # MXU matmul in fp32 accumulation
    s = jnp.dot(q, e.T, preferred_element_type=jnp.float32)   # (Q, block_n)
    col = step * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < n_real, s, NEG_INF)                   # mask pad rows
    s = jnp.where(col == excl, NEG_INF, s)                    # self-exclusion

    cand_s = jnp.concatenate([out_s_ref[...], s], axis=1)          # (Q, k+bn)
    cand_i = jnp.concatenate([out_i_ref[...], col], axis=1)

    best_s = jnp.zeros((q.shape[0], k), jnp.float32)
    best_i = jnp.zeros((q.shape[0], k), jnp.int32)
    for j in range(k):                   # unrolled: k is small & static
        m = jnp.max(cand_s, axis=1)                                # (Q,)
        am = jnp.argmax(cand_s, axis=1)                            # (Q,)
        best_s = best_s.at[:, j].set(m)
        best_i = best_i.at[:, j].set(jnp.take_along_axis(cand_i, am[:, None], axis=1)[:, 0])
        hit = jax.lax.broadcasted_iota(jnp.int32, cand_s.shape, 1) == am[:, None]
        cand_s = jnp.where(hit, NEG_INF, cand_s)
    out_s_ref[...] = best_s
    out_i_ref[...] = best_i


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def topk_cosine_pallas(
    q_unit: jnp.ndarray,      # (Q, d) row-normalized queries
    e_unit: jnp.ndarray,      # (N, d) table — row-normalized unless norms given
    k: int,
    exclude_rows: Optional[jnp.ndarray] = None,   # (Q,) int32, -1 = none
    norms: Optional[jnp.ndarray] = None,          # (N,) per-row L2 norms
    block_n: int = 1024,
    interpret: bool = True,   # CPU container: interpret; on TPU pass False
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (scores (Q, k'), indices (Q, k'), valid (Q,)) with
    k' = min(k, N); rows are descending and entries past ``valid[q]`` are
    sentinel padding.  With ``norms``, ``e_unit`` may be the *raw* table
    and each streamed block is normalized in-kernel — no (N, d) unit copy
    ever exists."""
    qn, d = q_unit.shape
    n = e_unit.shape[0]
    k = min(k, n)                        # static clamp: k never exceeds N
    if exclude_rows is None:
        exclude_rows = jnp.full((qn,), -1, jnp.int32)
    excl = jnp.asarray(exclude_rows, jnp.int32).reshape(qn, 1)
    has_norms = norms is not None
    # pad N to a block multiple with -inf-scoring rows (zero vectors);
    # pad norms with 1.0 so the pad rows stay zero after division
    n_pad = -n % block_n
    if n_pad:
        e_unit = jnp.concatenate(
            [e_unit, jnp.zeros((n_pad, d), e_unit.dtype)], axis=0
        )
    if has_norms:
        nrm = jnp.asarray(norms, jnp.float32).reshape(n, 1)
        if n_pad:
            nrm = jnp.concatenate([nrm, jnp.ones((n_pad, 1), nrm.dtype)])
    else:
        nrm = jnp.ones((n + n_pad, 1), jnp.float32)
    n_total = n + n_pad
    grid = (n_total // block_n,)

    out_s, out_i, out_v = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, block_n=block_n, n_real=n,
                          has_norms=has_norms),
        grid=grid,
        in_specs=[
            pl.BlockSpec((qn, d), lambda i: (0, 0)),          # q resident
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),     # stream table
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),     # stream norms
            pl.BlockSpec((qn, 1), lambda i: (0, 0)),          # exclusions
        ],
        out_specs=[
            pl.BlockSpec((qn, k), lambda i: (0, 0)),          # running top-k
            pl.BlockSpec((qn, k), lambda i: (0, 0)),
            pl.BlockSpec((qn, 1), lambda i: (0, 0)),          # valid counts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
            jax.ShapeDtypeStruct((qn, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q_unit.astype(jnp.float32), e_unit.astype(jnp.float32), nrm, excl)

    return out_s, out_i, out_v[:, 0]
