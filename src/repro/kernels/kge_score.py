"""Fused KGE scoring Pallas kernel (TransE-L1/L2, DistMult).

PyKEEN materializes (B, K, d) corrupted-embedding tensors in HBM and scores
them in separate ops. Here the positive triple slab and the (B, K, d)
negative slab are tiled through VMEM together and both positive and negative
scores come out of one pass — the training-loop hot spot.

Grid: (B // block_b,). Each step holds (block_b, d) h/r/t slabs and the
(block_b, K, d) negative slab in VMEM; all reductions are lane-dimension
sums feeding the VPU, with the head/tail corruption select fused in.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kge_kernel(h_ref, r_ref, t_ref, neg_ref, ch_ref, pos_ref, negs_ref,
                *, model: str):
    h = h_ref[...].astype(jnp.float32)       # (bb, d)
    r = r_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    neg = neg_ref[...].astype(jnp.float32)   # (bb, K, d)
    ch = ch_ref[...]                          # (bb, K) int8/bool

    if model == "transe_l1":
        pos = -jnp.sum(jnp.abs(h + r - t), axis=-1)
        diff_h = neg + (r - t)[:, None, :]
        diff_t = (h + r)[:, None, :] - neg
        diff = jnp.where(ch[..., None] != 0, diff_h, diff_t)
        negs = -jnp.sum(jnp.abs(diff), axis=-1)
    elif model == "transe_l2":
        pos = -jnp.sqrt(jnp.sum((h + r - t) ** 2, axis=-1) + 1e-12)
        diff_h = neg + (r - t)[:, None, :]
        diff_t = (h + r)[:, None, :] - neg
        diff = jnp.where(ch[..., None] != 0, diff_h, diff_t)
        negs = -jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    elif model == "distmult":
        pos = jnp.sum(h * r * t, axis=-1)
        s_h = jnp.sum(neg * (r * t)[:, None, :], axis=-1)
        s_t = jnp.sum((h * r)[:, None, :] * neg, axis=-1)
        negs = jnp.where(ch != 0, s_h, s_t)
    else:
        raise ValueError(model)
    pos_ref[...] = pos
    negs_ref[...] = negs


@functools.partial(jax.jit, static_argnames=("model", "block_b", "interpret"))
def kge_score_pallas(
    h: jnp.ndarray,            # (B, d)
    r: jnp.ndarray,            # (B, d)
    t: jnp.ndarray,            # (B, d)
    neg: jnp.ndarray,          # (B, K, d)
    corrupt_head: jnp.ndarray, # (B, K) bool
    model: str = "transe_l1",
    block_b: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, d = h.shape
    kneg = neg.shape[1]
    pad = -b % block_b
    if pad:
        zpad = lambda a: jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
        )
        h, r, t, neg = map(zpad, (h, r, t, neg))
        corrupt_head = zpad(corrupt_head)
    bt = b + pad
    ch8 = corrupt_head.astype(jnp.int8)
    grid = (bt // block_b,)

    pos, negs = pl.pallas_call(
        functools.partial(_kge_kernel, model=model),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, kneg, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, kneg), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, kneg), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt,), jnp.float32),
            jax.ShapeDtypeStruct((bt, kneg), jnp.float32),
        ],
        interpret=interpret,
    )(h, r, t, neg, ch8)
    return pos[:b], negs[:b]
