"""Sliding-window flash attention (Pallas, TPU) — prefill and decode.

Used by h2o-danube (SWA 4096), recurrentgemma's local-attention layers
(window 2048), and as the beyond-paper windowed-decode override for dense
archs at 500k context.

Shape convention: heads are folded into the leading dim.
  q (B·Hq, Sq, d), k/v (B·Hkv, Skv, d); GQA group g = Hq/Hkv is resolved in
  the kv BlockSpec index_map (kv row = q row // g) — no materialized repeat.

Grid: (B·Hq, Sq/bq, Skv/bk), kv innermost; online-softmax state
(running max m, normalizer l, accumulator acc) lives in VMEM scratch and is
rescaled per kv block — the (Sq, Skv) logit matrix never exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                *, bq: int, bk: int, d: int, window: int, q_offset: int,
                scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...][:, 0]                           # (bq,)
    l_prev = l_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(kj == n_kv - 1)
    def _finalize():
        l = l_ref[...][:, 0]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "q_offset", "bq", "bk", "interpret")
)
def swa_attention_pallas(
    q: jnp.ndarray,      # (BHq, Sq, d)
    k: jnp.ndarray,      # (BHkv, Skv, d)
    v: jnp.ndarray,      # (BHkv, Skv, d)
    window: int,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    assert bhq % bhkv == 0
    g = bhq // bhkv
    scale = d ** -0.5

    pad_q = -sq % bq
    pad_k = -skv % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    sq_t, skv_t = sq + pad_q, skv + pad_k

    grid = (bhq, sq_t // bq, skv_t // bk)
    out = pl.pallas_call(
        functools.partial(
            _swa_kernel, bq=bq, bk=bk, d=d, window=window,
            q_offset=q_offset, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j, l: (i, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j, l, g=g: (i // g, l, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j, l, g=g: (i // g, l, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j, l: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq_t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
