from .triples import TripleLoader
from .walks import corpus, relation_token, skipgram_pairs, token_vocab

__all__ = ["TripleLoader", "corpus", "relation_token", "skipgram_pairs",
           "token_vocab"]
