"""Vectorized random-walk corpus generation for RDF2Vec.

pyRDF2Vec chases pointers on CPU; on TPU we walk *all* starts at once with a
``lax.scan`` over a padded CSR adjacency — each step is a dense gather + a
categorical draw, which maps to TPU-friendly vectorized memory ops.

A walk alternates entity and relation tokens like pyRDF2Vec:
  e0 -r0-> e1 -r1-> e2 ...
Token ids: entities keep their ids [0, N); relation r becomes N + r.
Dead ends (out-degree 0) self-loop and emit a PAD relation token (N + R),
masked out downstream.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..ontology.graph import KnowledgeGraph


def relation_token(n_entities: int, rel_id: jnp.ndarray) -> jnp.ndarray:
    return n_entities + rel_id


@functools.partial(jax.jit, static_argnames=("walk_length",))
def _walk(
    key: jax.Array,
    starts: jnp.ndarray,      # (W,) int32 entity ids
    neighbors: jnp.ndarray,   # (N, D) int32
    edge_rels: jnp.ndarray,   # (N, D) int32
    degrees: jnp.ndarray,     # (N,) int32
    pad_rel_token: jnp.ndarray,
    walk_length: int,
) -> jnp.ndarray:
    """Return (W, 2*walk_length+1) token sequences (entity/rel alternating)."""
    n_ent = neighbors.shape[0]

    def step(carry, key):
        cur = carry                                  # (W,)
        deg = degrees[cur]                           # (W,)
        u = jax.random.uniform(key, cur.shape)
        choice = jnp.minimum((u * jnp.maximum(deg, 1)).astype(jnp.int32), jnp.maximum(deg - 1, 0))
        nxt = neighbors[cur, choice]
        rel = edge_rels[cur, choice]
        dead = deg == 0
        nxt = jnp.where(dead, cur, nxt)
        rel_tok = jnp.where(dead, pad_rel_token, n_ent + rel)
        return nxt, (rel_tok, nxt)

    keys = jax.random.split(key, walk_length)
    _, (rel_toks, ent_toks) = jax.lax.scan(step, starts, keys)
    # interleave: e0 r0 e1 r1 e2 ...
    seq = jnp.zeros((starts.shape[0], 2 * walk_length + 1), jnp.int32)
    seq = seq.at[:, 0].set(starts)
    seq = seq.at[:, 1::2].set(rel_toks.T)
    seq = seq.at[:, 2::2].set(ent_toks.T)
    return seq


def token_vocab(kg: KnowledgeGraph, add_inverse: bool = True) -> list:
    """Symbolic names for the walk-token vocabulary, aligned with
    :func:`corpus`'s integer ids: entities [0, N) keep their identifiers,
    relation tokens are prefixed (``%rel%is_a``, ``%rel%is_a_inv``), and the
    PAD token is last. This is what makes rdf2vec warm-startable — two
    versions' token rows can be matched by name even though every integer
    id above an inserted entity shifts.
    """
    rels = list(kg.relations)
    if add_inverse:
        rels = rels + [r + "_inv" for r in kg.relations]
    return list(kg.entities) + [f"%rel%{r}" for r in rels] + ["%pad%"]


def corpus(
    kg: KnowledgeGraph,
    key: jax.Array,
    walks_per_entity: int = 10,
    walk_length: int = 4,
    add_inverse: bool = True,
) -> Tuple[np.ndarray, int, int]:
    """Generate the full walk corpus.

    Returns (walks (W, 2L+1) int32, vocab_size, pad_token).
    Vocabulary: [0, N) entities, [N, N+R') relations (R' doubled if
    add_inverse), pad token = N + R'.
    """
    trips = kg.triples
    if add_inverse:
        inv = np.stack([trips[:, 2], trips[:, 1] + kg.num_relations, trips[:, 0]], axis=1)
        all_trips = np.concatenate([trips, inv], axis=0)
        n_rel = 2 * kg.num_relations
    else:
        all_trips = trips
        n_rel = kg.num_relations
    aug = KnowledgeGraph(
        kg.entities,
        kg.relations + [r + "_inv" for r in kg.relations] if add_inverse else kg.relations,
        all_trips,
        kg.terms,
    )
    nbrs, rels, deg = aug.padded_csr()
    n = kg.num_entities
    pad_token = n + n_rel
    starts = np.tile(np.arange(n, dtype=np.int32), walks_per_entity)
    walks = _walk(
        key, jnp.asarray(starts), jnp.asarray(nbrs), jnp.asarray(rels),
        jnp.asarray(deg), jnp.asarray(pad_token, jnp.int32), walk_length,
    )
    return np.asarray(walks), pad_token + 1, pad_token


def skipgram_pairs(
    walks: np.ndarray, window: int, pad_token: int, seed: int = 0
) -> np.ndarray:
    """(P, 2) (center, context) pairs from walks, PAD-filtered, shuffled."""
    w, L = walks.shape
    pairs = []
    for off in range(1, window + 1):
        a = walks[:, :-off].reshape(-1)
        b = walks[:, off:].reshape(-1)
        keep = (a != pad_token) & (b != pad_token)
        pairs.append(np.stack([a[keep], b[keep]], axis=1))
        pairs.append(np.stack([b[keep], a[keep]], axis=1))
    out = np.concatenate(pairs, axis=0).astype(np.int32)
    rng = np.random.default_rng(seed)
    return out[rng.permutation(out.shape[0])]
