"""Triple batching pipeline: epoch shuffling, drop-remainder padding-free
batches, host-side numpy (cheap) feeding jit'd steps."""
from __future__ import annotations

from typing import Iterator

import numpy as np


class TripleLoader:
    """Infinite shuffled triple batches. Deterministic given seed."""

    def __init__(self, triples: np.ndarray, batch_size: int, seed: int = 0,
                 drop_remainder: bool = True):
        assert triples.ndim == 2 and triples.shape[1] == 3
        self.triples = np.asarray(triples, dtype=np.int32)
        self.batch_size = int(batch_size)
        self.rng = np.random.default_rng(seed)
        self.drop_remainder = drop_remainder

    @property
    def steps_per_epoch(self) -> int:
        m = self.triples.shape[0]
        if m == 0:
            return 0
        if self.drop_remainder:
            # a non-empty dataset smaller than one batch still yields one
            # (tiled) batch per epoch — a 0-step epoch would make __iter__
            # spin forever without ever yielding
            return max(1, m // self.batch_size)
        return -(-m // self.batch_size)

    def epoch(self) -> Iterator[np.ndarray]:
        m = self.triples.shape[0]
        if m == 0:
            raise ValueError("cannot iterate an empty TripleLoader")
        perm = self.rng.permutation(m)
        shuf = self.triples[perm]
        if self.drop_remainder and m < self.batch_size:
            reps = -(-self.batch_size // m)
            yield np.tile(shuf, (reps, 1))[: self.batch_size]
            return
        end = m - m % self.batch_size if self.drop_remainder else m
        for start in range(0, end, self.batch_size):
            batch = shuf[start : start + self.batch_size]
            if batch.shape[0] < self.batch_size:
                pad = self.batch_size - batch.shape[0]
                batch = np.concatenate([batch, shuf[:pad]], axis=0)
            yield batch

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield from self.epoch()
