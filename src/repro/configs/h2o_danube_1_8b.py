"""h2o-danube-1.8b — dense llama+mistral mix with sliding-window attention: 24L d2560 32H kv8 ff6912 vocab 32000.

[arXiv:2401.16818]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000,
    attention="sliding_window", window=4096,
    source="arXiv:2401.16818",
)

REDUCED = ArchConfig(
    arch_id="h2o-danube-1.8b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512,
    attention="sliding_window", window=64,
)
