"""whisper-base — enc-dec audio backbone: 6L(x2) d512 8H ff2048 vocab 51865; conv/mel frontend stubbed.

[arXiv:2212.04356]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    norm="layernorm", act="gelu", rope_theta=0.0,
    tie_embeddings=True, dec_len_cap=448,
    source="arXiv:2212.04356",
)

REDUCED = ArchConfig(
    arch_id="whisper-base-reduced", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512,
    norm="layernorm", act="gelu", rope_theta=0.0,
    tie_embeddings=True, dec_len_cap=32,
)
