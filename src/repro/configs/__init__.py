from .shapes import SHAPES, InputShape, applicable

__all__ = ["SHAPES", "InputShape", "applicable"]
