"""The four assigned input shapes, and which step each lowers.

  train_4k     — train_step   (loss + grads + Adam update)
  prefill_32k  — prefill_step (build KV cache / recurrent state, last logits)
  decode_32k   — serve_step   (ONE new token against a seq_len cache)
  long_500k    — serve_step, sub-quadratic archs only (SSM / hybrid /
                 sliding-window dense); full-attention archs are skipped
                 and recorded in DESIGN.md.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    step: str                    # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def applicable(arch_cfg, shape: InputShape) -> bool:
    """long_500k only for sub-quadratic archs (per the assignment)."""
    if shape.name != "long_500k":
        return True
    from ..models import build
    return build(arch_cfg).supports_long_context()
