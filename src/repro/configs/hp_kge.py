"""The paper's second workload: Human Phenotype Ontology KGE training.

HP [Köhler et al., NAR 2021]: >18 000 classes, a pure-is_a DAG, releases
every ~1-2 months via GitHub. Same six models, dim=200, 100 epochs.
"""

from repro.ontology.synthetic import HP_SPEC
from repro.kge.train import TrainConfig
from .go_kge import KGEWorkload

CONFIG = KGEWorkload(name="hp", spec=HP_SPEC, n_terms=18_000)
REDUCED = KGEWorkload(name="hp", spec=HP_SPEC, n_terms=300,
                      train=TrainConfig(epochs=2, batch_size=128))
