"""grok-1-314b — MoE: 64L d6144 48H kv8 ff32768/expert, 8 experts top-2, vocab 131072.

[hf:xai-org/grok-1]
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
    source="hf:xai-org/grok-1",
)

REDUCED = ArchConfig(
    arch_id="grok-1-314b-reduced", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.25),
)
