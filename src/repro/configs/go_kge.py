"""The paper's own workload: Gene Ontology KGE training + serving.

GO [Aleksander et al., Genetics 2023]: >40 000 classes, three namespaces
(biological_process, molecular_function, cellular_component), is_a majority
plus part_of/regulates side relations, monthly releases. The paper trains
all six KGE models at dim=200 for 100 epochs (PyKEEN defaults otherwise).

Offline adaptation: the synthetic GO generator reproduces those structural
statistics; ``n_terms`` defaults to the full 40k for benchmarks and is
reduced in tests/examples.
"""
import dataclasses

from repro.kge import PAPER_DIM, PAPER_EPOCHS
from repro.kge.train import TrainConfig
from repro.ontology.synthetic import GO_SPEC, OntologySpec


@dataclasses.dataclass(frozen=True)
class KGEWorkload:
    name: str
    spec: OntologySpec
    n_terms: int
    dim: int = PAPER_DIM
    models: tuple = ("transe", "transr", "distmult", "hole", "boxe", "rdf2vec")
    train: TrainConfig = dataclasses.field(
        default_factory=lambda: TrainConfig(epochs=PAPER_EPOCHS))
    n_versions: int = 6          # paper hosts six versions per ontology


CONFIG = KGEWorkload(name="go", spec=GO_SPEC, n_terms=40_000)
REDUCED = KGEWorkload(name="go", spec=GO_SPEC, n_terms=400,
                      train=TrainConfig(epochs=2, batch_size=128))
#: GO-profile release series at KG-Hub scale (ROADMAP item 1): 100k terms
#: exercises the streaming top-k residency, 100k-label autocomplete
#: sidecars and OBO stream-parsing end to end.  Short training (the scale
#: axis under test is N, not epochs) keeps train→publish tractable on CPU.
SCALE = KGEWorkload(name="go-scale", spec=GO_SPEC, n_terms=100_000,
                    models=("transe",),
                    train=TrainConfig(epochs=1, batch_size=1024),
                    n_versions=3)
