"""The paper's own workload: Gene Ontology KGE training + serving.

GO [Aleksander et al., Genetics 2023]: >40 000 classes, three namespaces
(biological_process, molecular_function, cellular_component), is_a majority
plus part_of/regulates side relations, monthly releases. The paper trains
all six KGE models at dim=200 for 100 epochs (PyKEEN defaults otherwise).

Offline adaptation: the synthetic GO generator reproduces those structural
statistics; ``n_terms`` defaults to the full 40k for benchmarks and is
reduced in tests/examples.
"""
import dataclasses

from repro.kge import PAPER_DIM, PAPER_EPOCHS
from repro.kge.train import TrainConfig
from repro.ontology.synthetic import GO_SPEC, OntologySpec


@dataclasses.dataclass(frozen=True)
class KGEWorkload:
    name: str
    spec: OntologySpec
    n_terms: int
    dim: int = PAPER_DIM
    models: tuple = ("transe", "transr", "distmult", "hole", "boxe", "rdf2vec")
    train: TrainConfig = dataclasses.field(
        default_factory=lambda: TrainConfig(epochs=PAPER_EPOCHS))
    n_versions: int = 6          # paper hosts six versions per ontology


CONFIG = KGEWorkload(name="go", spec=GO_SPEC, n_terms=40_000)
REDUCED = KGEWorkload(name="go", spec=GO_SPEC, n_terms=400,
                      train=TrainConfig(epochs=2, batch_size=128))
