"""falcon-mamba-7b — attention-free mamba1 SSM: 64L d4096, ssm_state=16, vocab 65024.

[arXiv:2410.05355]
"""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=1,
    d_ff=0, vocab=65024, attention="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2410.05355",
)

REDUCED = ArchConfig(
    arch_id="falcon-mamba-7b-reduced", family="ssm",
    n_layers=2, d_model=256, n_heads=0, n_kv_heads=1,
    d_ff=0, vocab=512, attention="none",
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
)
