"""internlm2-20b — dense GQA: 48L d6144 48H kv8 ff16384 vocab 92544.

[arXiv:2403.17297]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92544, rope_theta=1_000_000.0,
    source="arXiv:2403.17297",
)

REDUCED = ArchConfig(
    arch_id="internlm2-20b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512,
)
