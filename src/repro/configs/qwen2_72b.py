"""qwen2-72b — dense GQA with QKV bias: 80L d8192 64H kv8 ff29568 vocab 152064.

[arXiv:2407.10671]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
    source="arXiv:2407.10671",
)

REDUCED = ArchConfig(
    arch_id="qwen2-72b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512, qkv_bias=True,
)
