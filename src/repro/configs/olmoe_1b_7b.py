"""olmoe-1b-7b — MoE: 16L d2048 16H kv16 ff1024/expert, 64 experts top-8, vocab 50304.

[arXiv:2409.02060]
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, capacity_factor=1.25),
    source="arXiv:2409.02060",
)

REDUCED = ArchConfig(
    arch_id="olmoe-1b-7b-reduced", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.25),
)
