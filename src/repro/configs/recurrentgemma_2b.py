"""recurrentgemma-2b — Griffin hybrid (RG-LRU : local attention 1:2): 26L d2560 10H (MQA kv=1) ff7680 vocab 256000.

[arXiv:2402.19427]
"""
from repro.models.config import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    arch_id="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, act="gelu",
    hybrid=HybridConfig(pattern=("recurrent", "recurrent", "attention"),
                        lru_width=2560, conv_width=4, window=2048),
    source="arXiv:2402.19427",
)

REDUCED = ArchConfig(
    arch_id="recurrentgemma-2b-reduced", family="hybrid",
    n_layers=5, d_model=256, n_heads=2, n_kv_heads=1,
    d_ff=512, vocab=512, act="gelu",
    hybrid=HybridConfig(pattern=("recurrent", "recurrent", "attention"),
                        lru_width=256, conv_width=4, window=64),
)
