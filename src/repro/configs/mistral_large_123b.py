"""mistral-large-123b — dense: 88L d12288 96H kv8 ff28672 vocab 32768.

[hf:mistralai/Mistral-Large-Instruct-2407]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab=32768, rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

REDUCED = ArchConfig(
    arch_id="mistral-large-123b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512,
)
