"""llava-next-34b — VLM: 60L d7168 56H (GQA kv=8) ff20480 vocab 64000, anyres tiling.

[hf:llava-hf/llava-v1.6-mistral-7b-hf scaled to the 34B variant (Nous-Hermes-2-Yi-34B backbone)]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, rope_theta=5_000_000.0,
    n_frontend_tokens=2880,   # anyres: (1 base + 4 sub-tiles) * 576 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

REDUCED = ArchConfig(
    arch_id="llava-next-34b-reduced", family="vlm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512, n_frontend_tokens=16,
)
