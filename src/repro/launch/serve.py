"""Serving launcher — the paper's deployment mode.

Stands up the Bio-KGvec2go serving engine over a registry (training the
snapshots first if the registry is empty), then runs a batched request
session against the three endpoints and reports latency:

    PYTHONPATH=src python -m repro.launch.serve --registry /tmp/biokg \
        --requests 200 --batch 32

The Flask/Apache layer of the paper is a thin HTTP shim over exactly these
calls (see DESIGN.md §8); this driver exercises the same engine the way the
production WSGI worker would.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--registry", default="/tmp/biokgvec2go")
    ap.add_argument("--ontology", default="go")
    ap.add_argument("--model", default="transe")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--train-if-missing", action="store_true", default=True)
    args = ap.parse_args()

    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest

    registry = EmbeddingRegistry(args.registry)
    if not registry.versions(args.ontology):
        print(f"[serve] registry empty; training {args.ontology} snapshots")
        from .train import train_kge
        train_kge(args.ontology, args.registry, steps=150, n_terms=800)

    engine = ServingEngine(registry)
    ids, labels, emb, meta = registry.get(args.ontology, args.model)
    print(f"[serve] {args.ontology}/{meta['version']}/{args.model}: "
          f"{len(ids)} classes, dim={meta['dim']}")

    rng = np.random.default_rng(0)

    # -- endpoint 1: download ------------------------------------------- #
    t0 = time.perf_counter()
    payload = engine.download(args.ontology, args.model)
    print(f"[serve] download: {len(payload)/1e6:.2f} MB JSON "
          f"in {time.perf_counter()-t0:.2f}s")

    # -- endpoint 2: similarity ----------------------------------------- #
    lat = []
    for _ in range(args.requests):
        a, b = (ids[i] for i in rng.integers(0, len(ids), 2))
        t0 = time.perf_counter()
        engine.similarity(args.ontology, args.model, a, b)
        lat.append(time.perf_counter() - t0)
    lat = np.array(lat) * 1e3
    print(f"[serve] similarity: p50={np.percentile(lat,50):.3f}ms "
          f"p99={np.percentile(lat,99):.3f}ms over {args.requests} requests")

    # -- endpoint 3: top-k closest, batched ------------------------------ #
    sched = BatchScheduler(engine, max_batch=args.batch)
    t0 = time.perf_counter()
    tickets = [sched.submit(TopKRequest(args.ontology, args.model,
                                        ids[int(i)], args.k))
               for i in rng.integers(0, len(ids), args.requests)]
    results = sched.flush()
    dt = time.perf_counter() - t0
    print(f"[serve] top-{args.k}: {args.requests} requests in {dt:.2f}s "
          f"({args.requests/dt:.0f} req/s batched; "
          f"{sched.stats['batches']} micro-batches, "
          f"{sched.stats['padded_queries']} padded) "
          f"cache={engine.cache_stats()}")
    sample = results[tickets[0]]
    print("[serve] sample result:")
    for c in sample[:3]:
        print(f"    {c.identifier:12s} {c.score:.4f}  {c.label[:40]}  {c.url}")


if __name__ == "__main__":
    main()
