"""Serving launcher — the paper's deployment mode, over the gateway API.

Stands up the Bio-KGvec2go gateway over a registry (training the
snapshots first if the registry is empty), then runs a concurrent
request session against the v1 endpoints and reports latency:
``--threads`` client threads call the typed gateway methods, which
submit future-style tickets that the BatchScheduler's background flush
loop resolves under its deadline policy (``--flush-after-ms`` or a full
``--batch``, whichever first). With more than one jax device, the
embedding table is sharded P("data", None) across them and top-k runs
through the sharded local+merge kernel path.

    PYTHONPATH=src python -m repro.launch.serve --registry /tmp/biokg \
        --requests 200 --batch 32 --threads 8 --flush-after-ms 2

With ``--http PORT`` the driver instead stands up the real HTTP service
(``repro.api.http``) over the same gateway and serves in the foreground
until interrupted — the paper's deployment mode:

    PYTHONPATH=src python -m repro.launch.serve --registry /tmp/biokg \
        --http 8080
    curl 'localhost:8080/closest-concepts/go/transe?query=GO:0000001&k=5'
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--registry", default="/tmp/biokgvec2go")
    ap.add_argument("--ontology", default="go")
    ap.add_argument("--model", default="transe")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--threads", type=int, default=8,
                    help="concurrent client threads")
    ap.add_argument("--flush-after-ms", type=float, default=2.0,
                    help="flush-loop deadline")
    ap.add_argument("--page", type=int, default=2000,
                    help="download page size (cursor pagination)")
    ap.add_argument("--no-shard", action="store_true",
                    help="force the single-device path even on multi-device")
    ap.add_argument("--train-if-missing", action="store_true", default=True)
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve the gateway over HTTP on PORT (foreground; "
                         "0 = ephemeral) instead of running the client "
                         "session")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --http")
    ap.add_argument("--workers", type=int, default=1,
                    help="with --http: pre-fork this many worker "
                         "processes (SO_REUSEPORT) over the shared "
                         "mmap-resident snapshot store; 1 = classic "
                         "single-process serving")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="scheduler intake bound (per worker); past it "
                         "submissions fast-reject with OVERLOADED / "
                         "HTTP 429 + Retry-After instead of queueing "
                         "without bound")
    ap.add_argument("--cache-entries", type=int, default=4096,
                    help="version-keyed result-cache entry bound "
                         "(0 disables the cache)")
    ap.add_argument("--cache-bytes", type=int, default=32 << 20,
                    help="result-cache wire-byte bound (0 disables)")
    ap.add_argument("--max-jobs-queued", type=int, default=8,
                    help="batch-job queue bound (per worker); past it "
                         "job submissions fast-reject with OVERLOADED / "
                         "HTTP 429 + Retry-After")
    args = ap.parse_args()

    from repro.api import Gateway
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine
    from .mesh import make_serving_mesh

    registry = EmbeddingRegistry(args.registry)
    if not registry.versions(args.ontology):
        print(f"[serve] registry empty; training {args.ontology} snapshots")
        if args.http is not None and args.workers > 1:
            # train in a subprocess: training runs jax ops, and an
            # initialized XLA backend must never cross the fork the
            # worker pool is about to do
            import os
            import subprocess
            import sys
            code = ("from repro.launch.train import train_kge; "
                    f"train_kge({args.ontology!r}, {args.registry!r}, "
                    f"steps=150, n_terms=800)")
            env = dict(os.environ)
            env["PYTHONPATH"] = os.pathsep.join(
                p for p in sys.path if p) + os.pathsep + env.get(
                    "PYTHONPATH", "")
            subprocess.run([sys.executable, "-c", code], env=env, check=True)
        else:
            from .train import train_kge
            train_kge(args.ontology, args.registry, steps=150, n_terms=800)

    if args.http is not None and args.workers > 1:
        from repro.api.workers import WorkerPool
        pool = WorkerPool(args.registry, port=args.http, host=args.host,
                          workers=args.workers, max_batch=args.batch,
                          flush_after_ms=args.flush_after_ms,
                          max_pending=args.max_pending,
                          result_cache_entries=args.cache_entries,
                          result_cache_bytes=args.cache_bytes,
                          max_jobs_queued=args.max_jobs_queued)
        pool.start()
        pool.wait_ready()
        base = pool.url
        print(f"[serve] HTTP service on {base} — {args.workers} workers "
              f"(pids {', '.join(map(str, pool.pids()))}; "
              f"{'SO_REUSEPORT' if pool.reuseport else 'inherited listener'})")
        print(f"[serve]   curl '{base}/health'")
        print(f"[serve]   curl '{base}/closest-concepts/{args.ontology}/"
              f"{args.model}?query=GO:0000001&k=5'")
        print(f"[serve]   curl -X POST '{base}/jobs/submit' -d "
              f"'{{\"kind\": \"knn-join\", \"ontology\": \"{args.ontology}\", "
              f"\"model\": \"{args.model}\", "
              f"\"classes\": [\"GO:0000001\"], \"k\": 5}}'")
        print(f"[serve]   curl '{base}/stats'   # merged across workers")
        try:
            threading.Event().wait()
        except KeyboardInterrupt:
            print("\n[serve] shutting down worker pool")
        finally:
            pool.stop()
        return

    mesh = None if args.no_shard else make_serving_mesh()
    engine = ServingEngine(registry, mesh=mesh)
    gw = Gateway(engine, max_batch=args.batch,
                 flush_after_ms=args.flush_after_ms,
                 max_pending=args.max_pending,
                 result_cache_entries=args.cache_entries,
                 result_cache_bytes=args.cache_bytes,
                 max_jobs_queued=args.max_jobs_queued)

    if args.http is not None:
        from repro.api.http import serve_http
        server = serve_http(gw, host=args.host, port=args.http, start=False)
        base = server.url
        print(f"[serve] HTTP service on {base} — the paper's endpoints:")
        q = "GO:0000001"
        for line in (
                f"curl '{base}/health'",
                f"curl '{base}/get-vector/{args.ontology}/{args.model}"
                f"?query={q}'",
                f"curl '{base}/sim/{args.ontology}/{args.model}"
                f"?a={q}&b=GO:0000002'",
                f"curl '{base}/closest-concepts/{args.ontology}/{args.model}"
                f"?query={q}&k=5'",
                f"curl '{base}/download/{args.ontology}/{args.model}"
                f"?limit=3'   # ETag + If-None-Match -> 304",
                f"curl '{base}/download/{args.ontology}/{args.model}"
                f"?stream=true'   # chunked full table",
                f"curl '{base}/autocomplete/{args.ontology}/{args.model}"
                f"?prefix=term'",
                f"curl -X POST '{base}/jobs/submit' -d '{{\"kind\": "
                f"\"knn-join\", \"ontology\": \"{args.ontology}\", "
                f"\"model\": \"{args.model}\", \"classes\": [\"{q}\"], "
                f"\"k\": 5}}'   # -> {{job_id}}; poll /jobs/{{job_id}}",
                f"curl '{base}/jobs/JOB_ID/result?stream=true'"
                f"   # chunked rows once DONE",
                f"curl '{base}/stats'   # per-route latency histograms"):
            print(f"[serve]   {line}")
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            print("\n[serve] shutting down")
        finally:
            server.server_close()
            gw.close()
        return

    vers = gw.versions(args.ontology)
    total = gw.download(args.ontology, args.model, version=vers.latest,
                        limit=1).total
    print(f"[serve] {args.ontology}/{vers.latest}/{args.model}: "
          f"{total} classes, versions={vers.versions}, "
          f"{'sharded over ' + str(mesh.devices.size) + ' devices' if mesh else 'single device'}")

    rng = np.random.default_rng(0)

    # -- endpoint: download (cursor-paginated); ids collected here so the
    # table is paged exactly once ---------------------------------------- #
    t0 = time.perf_counter()
    ids, nbytes, pages, offset = [], 0, 0, 0
    while offset is not None:
        page = gw.download(args.ontology, args.model, version=vers.latest,
                           offset=offset, limit=args.page)
        ids.extend(r[0] for r in page.rows)
        nbytes += sum(len(r[0]) + 8 * len(r[1]) for r in page.rows)
        offset = page.next_offset
        pages += 1
    print(f"[serve] download: {page.total} classes over {pages} pages "
          f"(~{nbytes/1e6:.1f} MB) in {time.perf_counter()-t0:.2f}s")

    # -- endpoint: sim (batch-first through the scheduler) -------------- #
    lat = []
    for _ in range(args.requests):
        a, b = (ids[i] for i in rng.integers(0, len(ids), 2))
        t0 = time.perf_counter()
        gw.similarity(args.ontology, args.model, a, b)
        lat.append(time.perf_counter() - t0)
    lat = np.array(lat) * 1e3
    print(f"[serve] similarity: p50={np.percentile(lat,50):.3f}ms "
          f"p99={np.percentile(lat,99):.3f}ms over {args.requests} requests")

    # -- endpoint: closest-concepts, concurrent clients + flush loop ---- #
    # warm every power-of-two padding-bucket jit shape first, so the
    # timed region measures serving, not retraces
    from repro.api.schema import ClosestConceptsRequest
    b = 1
    while b <= args.batch:
        gw.closest_concepts_batch(
            [ClosestConceptsRequest(args.ontology, args.model,
                                    ids[i % len(ids)], args.k)
             for i in range(b)])
        b <<= 1
    warm_stats = dict(gw.scheduler.stats)   # report only the timed region

    queries = [ids[int(i)] for i in rng.integers(0, len(ids), args.requests)]
    chunks = [queries[i::args.threads] for i in range(args.threads)]
    lat, lat_lock = [], threading.Lock()
    sample = {}

    def client(cid, mine):
        out = []
        for q in mine:
            t1 = time.perf_counter()
            resp = gw.closest_concepts(args.ontology, args.model, q, k=args.k)
            out.append(time.perf_counter() - t1)
            if cid == 0 and not sample:
                sample[0] = resp
        with lat_lock:
            lat.extend(out)

    t0 = time.perf_counter()
    workers = [threading.Thread(target=client, args=(i, c))
               for i, c in enumerate(chunks)]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    dt = time.perf_counter() - t0
    run_stats = {k: gw.scheduler.stats[k] - warm_stats[k] for k in warm_stats}
    lat_ms = np.array(lat) * 1e3
    print(f"[serve] top-{args.k}: {args.requests} requests from "
          f"{args.threads} clients in {dt:.2f}s "
          f"({args.requests/dt:.0f} req/s; "
          f"{run_stats['batches']} micro-batches, "
          f"{run_stats['full_flushes']} full / "
          f"{run_stats['deadline_flushes']} deadline flushes, "
          f"{run_stats['padded_queries']} padded) "
          f"p50={np.percentile(lat_ms,50):.2f}ms "
          f"p99={np.percentile(lat_ms,99):.2f}ms")

    # -- ops endpoints via the wire entry point ------------------------- #
    health = gw.handle("/health")
    stats = gw.handle("/stats")
    print(f"[serve] health={health['status']} "
          f"cache={stats['cache']} "
          f"gateway={{requests: {stats['gateway']['requests']}, "
          f"errors: {stats['gateway']['errors']}}}")
    print("[serve] sample result:")
    for c in sample[0].results[:3]:
        print(f"    {c.identifier:12s} {c.score:.4f}  {c.label[:40]}  {c.url}")
    gw.close()


if __name__ == "__main__":
    main()
