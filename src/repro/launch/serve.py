"""Serving launcher — the paper's deployment mode.

Stands up the Bio-KGvec2go serving engine over a registry (training the
snapshots first if the registry is empty), then runs a concurrent request
session against the three endpoints and reports latency: ``--threads``
client threads submit future-style tickets that the BatchScheduler's
background flush loop resolves under its deadline policy
(``--flush-after-ms`` or a full ``--batch``, whichever first). With more
than one jax device, the embedding table is sharded P("data", None)
across them and top-k runs through the sharded local+merge kernel path.

    PYTHONPATH=src python -m repro.launch.serve --registry /tmp/biokg \
        --requests 200 --batch 32 --threads 8 --flush-after-ms 2

The Flask/Apache layer of the paper is a thin HTTP shim over exactly these
calls (see DESIGN.md §8); this driver exercises the same engine the way the
production WSGI workers would — many independent clients, one scheduler.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--registry", default="/tmp/biokgvec2go")
    ap.add_argument("--ontology", default="go")
    ap.add_argument("--model", default="transe")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--threads", type=int, default=8,
                    help="concurrent client threads")
    ap.add_argument("--flush-after-ms", type=float, default=2.0,
                    help="flush-loop deadline")
    ap.add_argument("--no-shard", action="store_true",
                    help="force the single-device path even on multi-device")
    ap.add_argument("--train-if-missing", action="store_true", default=True)
    args = ap.parse_args()

    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest
    from .mesh import make_serving_mesh

    registry = EmbeddingRegistry(args.registry)
    if not registry.versions(args.ontology):
        print(f"[serve] registry empty; training {args.ontology} snapshots")
        from .train import train_kge
        train_kge(args.ontology, args.registry, steps=150, n_terms=800)

    mesh = None if args.no_shard else make_serving_mesh()
    engine = ServingEngine(registry, mesh=mesh)
    ids, labels, emb, meta = registry.get(args.ontology, args.model)
    print(f"[serve] {args.ontology}/{meta['version']}/{args.model}: "
          f"{len(ids)} classes, dim={meta['dim']}, "
          f"{'sharded over ' + str(mesh.devices.size) + ' devices' if mesh else 'single device'}")

    rng = np.random.default_rng(0)

    # -- endpoint 1: download ------------------------------------------- #
    t0 = time.perf_counter()
    payload = engine.download(args.ontology, args.model)
    print(f"[serve] download: {len(payload)/1e6:.2f} MB JSON "
          f"in {time.perf_counter()-t0:.2f}s")

    # -- endpoint 2: similarity ----------------------------------------- #
    lat = []
    for _ in range(args.requests):
        a, b = (ids[i] for i in rng.integers(0, len(ids), 2))
        t0 = time.perf_counter()
        engine.similarity(args.ontology, args.model, a, b)
        lat.append(time.perf_counter() - t0)
    lat = np.array(lat) * 1e3
    print(f"[serve] similarity: p50={np.percentile(lat,50):.3f}ms "
          f"p99={np.percentile(lat,99):.3f}ms over {args.requests} requests")

    # -- endpoint 3: top-k closest, concurrent clients + flush loop ------ #
    queries = [ids[int(i)] for i in rng.integers(0, len(ids), args.requests)]
    chunks = [queries[i::args.threads] for i in range(args.threads)]
    lat, lat_lock = [], threading.Lock()
    sample = {}

    def client(cid, mine):
        out = []
        for q in mine:
            t1 = time.perf_counter()
            ticket = sched.submit(TopKRequest(args.ontology, args.model,
                                              q, args.k))
            res = ticket.result(timeout=60)
            out.append(time.perf_counter() - t1)
            if cid == 0 and not sample:
                sample[0] = res
        with lat_lock:
            lat.extend(out)

    with BatchScheduler(engine, max_batch=args.batch,
                        flush_after_ms=args.flush_after_ms) as sched:
        t0 = time.perf_counter()
        workers = [threading.Thread(target=client, args=(i, c))
                   for i, c in enumerate(chunks)]
        for w in workers:
            w.start()
        for w in workers:
            w.join()
        dt = time.perf_counter() - t0
    lat_ms = np.array(lat) * 1e3
    print(f"[serve] top-{args.k}: {args.requests} requests from "
          f"{args.threads} clients in {dt:.2f}s "
          f"({args.requests/dt:.0f} req/s; "
          f"{sched.stats['batches']} micro-batches, "
          f"{sched.stats['full_flushes']} full / "
          f"{sched.stats['deadline_flushes']} deadline flushes, "
          f"{sched.stats['padded_queries']} padded) "
          f"p50={np.percentile(lat_ms,50):.2f}ms "
          f"p99={np.percentile(lat_ms,99):.2f}ms "
          f"cache={engine.cache_stats()}")
    print("[serve] sample result:")
    for c in sample[0][:3]:
        print(f"    {c.identifier:12s} {c.score:.4f}  {c.label[:40]}  {c.url}")


if __name__ == "__main__":
    main()
