"""Production mesh definitions.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(min_devices: int = 2):
    """1-D ("data",) mesh over all local devices for the sharded serving
    top-k path (embedding tables laid out P("data", None); see
    kernels.ops.topk_cosine_sharded). Returns None when fewer than
    ``min_devices`` are available — the caller then uses the unchanged
    single-device path."""
    n = jax.device_count()
    if n < min_devices:
        return None
    return jax.make_mesh((n,), ("data",))
