"""Training launcher.

Two modes, matching the paper's two sides:

  KGE (the paper's workload):
      PYTHONPATH=src python -m repro.launch.train --workload go \
          --registry /tmp/biokg --steps 200
    Generates the synthetic GO/HP release, trains all six KGE models
    (paper defaults: dim=200, epochs=100 — cap with --steps on CPU), and
    publishes versioned snapshots with PROV metadata.

  LM zoo (assigned architectures; reduced configs on CPU):
      PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b \
          --reduced --steps 50
    Runs real optimizer steps on synthetic token streams and reports the
    loss curve. On TPU the same driver takes the full config + the
    production mesh (see launch/dryrun.py for the lowering path).
"""
from __future__ import annotations

import argparse
import importlib
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_kge(workload: str, registry_dir: str, steps: int | None,
              n_terms: int | None, seed: int = 0) -> None:
    from repro.core.registry import EmbeddingRegistry
    from repro.core.updater import Updater
    from repro.ontology.synthetic import generate
    from repro.ontology import obo

    wl_mod = importlib.import_module(f"repro.configs.{workload}_kge")
    wl = wl_mod.CONFIG if n_terms is None else wl_mod.REDUCED
    n = n_terms or wl.n_terms

    print(f"[train] generating synthetic {workload.upper()} ({n} terms)")
    kg = generate(wl.spec, seed=seed, n_terms=n)
    print(f"[train] {kg.num_entities} entities, {len(kg.triples)} triples, "
          f"{kg.num_relations} relations")

    registry = EmbeddingRegistry(registry_dir)
    updater = Updater(registry, models=wl.models, dim=wl.dim,
                      train_cfg=wl.train, steps_override=steps)

    class _Once:
        name = workload
        def latest(self):
            return "2023-01-01", kg

    rep = updater.run_once(_Once(), seed=seed)
    print(f"[train] published {rep.trained_models} v{rep.version} "
          f"in {rep.wall_s:.1f}s")
    for m, d in rep.details.items():
        print(f"  {m:10s} loss={d['final_loss']:.4f} "
              f"{d['triples_per_s']:.0f} triples/s")


def train_lm(arch: str, reduced: bool, steps: int, batch: int, seq: int,
             seed: int = 0) -> None:
    from repro.models import get_model
    from repro.models.steps import make_train_step

    cfg, model = get_model(arch, reduced=reduced)
    print(f"[train] {cfg.arch_id}: {cfg.n_params()/1e6:.1f}M params "
          f"({cfg.n_active_params()/1e6:.1f}M active)")
    key = jax.random.key(seed)
    params = model.init(key)
    step, optimizer = make_train_step(model)
    opt_state = optimizer.init(params)
    jstep = jax.jit(step, donate_argnums=(0, 1))

    rng = np.random.default_rng(seed)
    spec = model.batch_spec(batch, seq)

    def make_batch():
        out = {}
        for k, v in spec.items():
            if v.dtype == jnp.int32:
                out[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab, v.shape), jnp.int32)
            else:
                out[k] = jnp.asarray(rng.normal(size=v.shape), v.dtype)
        return out

    t0 = time.perf_counter()
    for i in range(steps):
        params, opt_state, metrics = jstep(params, opt_state, make_batch())
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            print(f"  step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['acc']):.3f}")
    dt = time.perf_counter() - t0
    tok = steps * batch * seq
    print(f"[train] {steps} steps, {dt:.1f}s, {tok/dt:.0f} tok/s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["go", "hp"], default=None)
    ap.add_argument("--registry", default="/tmp/biokgvec2go")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--n-terms", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.workload:
        train_kge(args.workload, args.registry, args.steps, args.n_terms,
                  args.seed)
    elif args.arch:
        train_lm(args.arch, args.reduced, args.steps or 20, args.batch,
                 args.seq, args.seed)
    else:
        raise SystemExit("pass --workload go|hp or --arch <id>")


if __name__ == "__main__":
    main()
