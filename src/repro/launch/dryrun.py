import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, with ShapeDtypeStruct inputs
(zero allocation), and extract memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --kge go

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json and
feed benchmarks/roofline.py -> EXPERIMENTS.md.

The two XLA_FLAGS lines above MUST stay the first statements: jax locks the
device count at first init. Everything else (smoke tests, benches) sees the
single real CPU device.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

REPO = Path(__file__).resolve().parents[3]
sys.path.insert(0, str(REPO))

from benchmarks.roofline import (
    analyze_hlo, memory_traffic_proxy, model_flops, roofline_terms)
from repro.configs.shapes import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.models import ARCH_IDS, build, get_config
from repro.models import runtime
from repro.models.sharding import (batch_pspec, batch_shardings,
                                   cache_shardings, param_shardings)
from repro.models.steps import (make_prefill_step, make_serve_step,
                                make_train_step, prefill_specs, serve_specs,
                                train_specs)
from repro.optim.adam import OptState

RESULTS = REPO / "benchmarks" / "results" / "dryrun"

#: last compiled HLO text (benchmarks/inspect_hlo.py reads this)
_LAST_HLO = ""

#: production runtime carries one KV slot per model-axis shard
PROD_KV_GROUPS = 16


def _mem_analysis(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_analysis(compiled):
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return {k: float(v) for k, v in dict(c).items()
            if isinstance(v, (int, float))}


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               save: bool = True, force: bool = False,
               override=None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    out_path = RESULTS / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    shape = SHAPES[shape_name]
    dp_blocks = 32 if multi_pod else 16
    cfg = get_config(arch).with_(kv_groups=PROD_KV_GROUPS,
                                 moe_dp_blocks=dp_blocks,
                                 moe_impl="shard_map")
    if cfg.moe is not None and cfg.moe.n_experts % 16:
        # virtual ff-split so experts divide the model axis (grok: 8e -> 16)
        import math as _math
        cfg = cfg.with_(moe_ff_split=16 // _math.gcd(cfg.moe.n_experts, 16))
    if cfg.d_model <= 2560 and shape.step == "train":
        # small-activation archs: full activations fit HBM comfortably, and
        # dropping remat removes the recomputed per-layer collectives
        # (measured: danube train bound 5.91 -> 4.92 s; §Perf)
        cfg = cfg.with_(remat="none")
    if override:
        cfg = cfg.with_(**override)
    if not applicable(cfg, shape):
        rec = {"tag": tag, "status": "skipped",
               "reason": "full-attention arch at 524k decode (quadratic); "
                         "see DESIGN.md shape-applicability"}
        if save:
            RESULTS.mkdir(parents=True, exist_ok=True)
            out_path.write_text(json.dumps(rec, indent=2))
        return rec

    model = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.perf_counter()

    with mesh, runtime.use_mesh(mesh):
        if shape.step == "train":
            step, optimizer = make_train_step(model)
            params, opt_state, batch = train_specs(
                model, shape.global_batch, shape.seq_len)
            p_sh = param_shardings(cfg, mesh, params)
            o_sh = OptState(NamedSharding(mesh, P()),
                            param_shardings(cfg, mesh, opt_state.mu),
                            param_shardings(cfg, mesh, opt_state.nu))
            b_sh = batch_shardings(mesh, shape.global_batch, batch)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params, opt_state, batch)
        elif shape.step == "prefill":
            step = make_prefill_step(model)
            params, batch = prefill_specs(model, shape.global_batch,
                                          shape.seq_len)
            p_sh = param_shardings(cfg, mesh, params)
            b_sh = batch_shardings(mesh, shape.global_batch, batch)
            cache_sds = model.cache_spec(shape.global_batch, shape.seq_len)
            c_sh = cache_shardings(cfg, mesh, shape.global_batch, cache_sds)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(None, c_sh))
            lowered = fn.lower(params, batch)
        else:  # decode
            step = make_serve_step(model)
            params, cache, token, pos = serve_specs(
                model, shape.global_batch, shape.seq_len)
            p_sh = param_shardings(cfg, mesh, params)
            c_sh = cache_shardings(cfg, mesh, shape.global_batch, cache)
            t_sh = NamedSharding(mesh, batch_pspec(mesh, shape.global_batch, 2))
            fn = jax.jit(step,
                         in_shardings=(p_sh, c_sh, t_sh,
                                       NamedSharding(mesh, P())),
                         out_shardings=(t_sh, c_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params, cache, token, pos)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    global _LAST_HLO
    cost = _cost_analysis(compiled)
    mem = _mem_analysis(compiled)
    _LAST_HLO = compiled.as_text()
    hlo = analyze_hlo(_LAST_HLO)
    coll = {"n_ops": hlo["n_collectives"],
            "traffic_bytes": hlo["collective_bytes"],
            "by_kind": hlo["by_kind"]}

    # loop-aware totals (XLA cost_analysis counts scan bodies once; see
    # benchmarks/roofline.py). memory: buffer-assignment traffic proxy.
    flops_dev = hlo["flops"]
    bytes_dev = float(memory_traffic_proxy(mem)) or cost.get("bytes accessed", 0.0)
    terms = roofline_terms(flops_dev, bytes_dev, coll["traffic_bytes"])

    dec_len = None
    if cfg.family == "audio":
        from repro.models.encdec import _dec_len
        dec_len = _dec_len(shape.seq_len, cfg.dec_len_cap)
    mf = model_flops(
        cfg.n_active_params() if cfg.moe else cfg.n_params(),
        shape.step, shape.global_batch, shape.seq_len, dec_len)

    rec = {
        "tag": tag, "status": "ok",
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": int(n_dev), "step": shape.step,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "xla_cost": cost, "memory": mem, "collectives": coll,
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_dev": mf / n_dev,
        "useful_ratio": (mf / n_dev) / flops_dev if flops_dev else None,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
    }
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
    return rec


def dryrun_kge(workload: str, multi_pod: bool, save: bool = True,
               force: bool = False) -> dict:
    """The paper's own workload on the production mesh: sharded KGE train
    step over the full-size synthetic GO/HP (40k/18k entities, dim 200)."""
    mesh_name = "multi" if multi_pod else "single"
    tag = f"kge-{workload}__train__{mesh_name}"
    out_path = RESULTS / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    import importlib
    wl = importlib.import_module(f"repro.configs.{workload}_kge").CONFIG
    from repro.kge import make_model
    from repro.kge.train import TrainConfig, make_train_step as kge_step
    from repro.optim import OPTIMIZERS

    n_ent = wl.n_terms
    model = make_model("transe", n_ent, 3, dim=wl.dim)
    tc = TrainConfig(batch_size=8192, num_negs=32)
    optimizer = OPTIMIZERS[tc.optimizer](tc.lr)
    step, _ = kge_step(model, optimizer, tc)
    mesh = make_production_mesh(multi_pod=multi_pod)

    params = jax.eval_shape(model.init, jax.random.key(0))
    opt_state = jax.eval_shape(optimizer.init, params)
    triples = jax.ShapeDtypeStruct((tc.batch_size, 3), jnp.int32)
    key = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)

    with mesh:
        pspec = model.param_shardings("model", axis_size=mesh.shape["model"])
        p_sh = {k: NamedSharding(mesh, v) for k, v in pspec.items()}
        o_sh = OptState(NamedSharding(mesh, P()),
                        {k: p_sh[k] for k in p_sh},
                        {k: p_sh[k] for k in p_sh})
        dp = ("pod", "data") if multi_pod else ("data",)
        b_sh = NamedSharding(mesh, P(dp, None))
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh,
                                         NamedSharding(mesh, P())),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        t0 = time.perf_counter()
        lowered = fn.lower(params, opt_state, triples, key)
        compiled = lowered.compile()
        dt = time.perf_counter() - t0

    cost = _cost_analysis(compiled)
    mem = _mem_analysis(compiled)
    hlo = analyze_hlo(compiled.as_text())
    coll = {"n_ops": hlo["n_collectives"],
            "traffic_bytes": hlo["collective_bytes"],
            "by_kind": hlo["by_kind"]}
    terms = roofline_terms(hlo["flops"],
                           float(memory_traffic_proxy(mem)),
                           coll["traffic_bytes"])
    rec = {"tag": tag, "status": "ok", "workload": workload,
           "n_entities": n_ent, "dim": wl.dim,
           "n_devices": int(mesh.devices.size),
           "compile_s": round(dt, 2), "xla_cost": cost,
           "memory": mem, "collectives": coll,
           "roofline": terms}
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kge", default=None, choices=["go", "hp"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    jobs = []
    if args.kge:
        for mp in meshes:
            jobs.append(("kge", args.kge, None, mp))
    elif args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in meshes:
                    jobs.append(("arch", arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all/--kge)"
        for mp in meshes:
            jobs.append(("arch", args.arch, args.shape, mp))

    failures = 0
    for kind, a, s, mp in jobs:
        label = f"{a}__{s}__{'multi' if mp else 'single'}" if s else \
            f"kge-{a}__{'multi' if mp else 'single'}"
        t0 = time.perf_counter()
        try:
            if kind == "kge":
                rec = dryrun_kge(a, mp, force=args.force)
            else:
                rec = dryrun_one(a, s, mp, force=args.force)
            dt = time.perf_counter() - t0
            status = rec["status"]
            if status == "ok":
                r = rec["roofline"]
                print(f"[{dt:7.1f}s] {label:55s} OK "
                      f"dom={r['dominant']:12s} bound={r['bound_s']:.3e}s "
                      f"coll={rec['collectives']['traffic_bytes']:.2e}B",
                      flush=True)
            else:
                print(f"[{dt:7.1f}s] {label:55s} SKIP ({rec['reason'][:60]})",
                      flush=True)
        except Exception as e:
            failures += 1
            print(f"[{time.perf_counter()-t0:7.1f}s] {label:55s} FAIL {e}",
                  flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
