"""Wire schema for the Bio-KGvec2go gateway API v1.

Typed request/response dataclasses for the five paper endpoints
(``get-vector``, ``sim``, ``closest-concepts``, ``download``,
``autocomplete``) plus the ops endpoints (``health``, ``stats``,
``versions``, ``lineage``), a JSON codec (:func:`to_wire` /
:func:`from_wire`), and the structured error model (:class:`ApiError`)
that replaces the bare ``KeyError`` / ``ValueError`` surface of the
pre-gateway ``ServingEngine`` methods.

Everything here is transport-agnostic plain data: an HTTP shim maps
``ApiError.status`` to its response code and ``to_wire`` output to the
body; an in-process caller just uses the dataclasses directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

# --------------------------------------------------------------------- #
# error model
# --------------------------------------------------------------------- #

#: stable machine-readable error codes -> default HTTP-ish status.
#: These strings are the public contract; the scheduler attaches them to
#: rejected tickets (see core/serving.py) and clients switch on them.
CODE_STATUS: Dict[str, int] = {
    "UNKNOWN_ONTOLOGY": 404,
    "UNKNOWN_MODEL": 404,
    "UNKNOWN_VERSION": 404,
    "UNKNOWN_CLASS": 404,
    "NOT_FOUND": 404,                # unknown *route* — not a bad payload
    "BAD_REQUEST": 400,
    "TIMEOUT": 408,
    "OVERLOADED": 429,               # admission control: intake bound hit
    "SHUTTING_DOWN": 503,
    "INTERNAL": 500,
    "JOB_NOT_FOUND": 404,            # unknown (or already-evicted) job id
    "JOB_CANCELLED": 409,            # results requested for a cancelled job
}

#: legacy exception type per code — what the deprecated ServingEngine
#: delegates re-raise so pre-gateway callers keep their except clauses
_LEGACY = {
    "UNKNOWN_ONTOLOGY": KeyError, "UNKNOWN_MODEL": KeyError,
    "UNKNOWN_VERSION": KeyError, "UNKNOWN_CLASS": KeyError,
    "NOT_FOUND": KeyError,
    "BAD_REQUEST": ValueError, "TIMEOUT": TimeoutError,
    "OVERLOADED": RuntimeError,
    "SHUTTING_DOWN": RuntimeError, "INTERNAL": RuntimeError,
    "JOB_NOT_FOUND": KeyError, "JOB_CANCELLED": RuntimeError,
}


class ApiError(Exception):
    """A gateway failure with a stable code, a human message, an
    HTTP-ish status, and machine-readable ``details`` (e.g. the *full*
    list of unresolvable class names under ``details["missing"]``)."""

    def __init__(self, code: str, message: str,
                 details: Optional[Dict[str, Any]] = None,
                 status: Optional[int] = None):
        if code not in CODE_STATUS:
            raise ValueError(f"unknown ApiError code {code!r}")
        super().__init__(message)
        self.code = code
        self.message = message
        self.details: Dict[str, Any] = dict(details or {})
        self.status = CODE_STATUS[code] if status is None else int(status)

    def to_wire(self) -> Dict[str, Any]:
        return {"type": "error", "code": self.code, "message": self.message,
                "status": self.status, "details": self.details}

    def legacy(self) -> Exception:
        """The pre-gateway exception equivalent (KeyError for UNKNOWN_*,
        ValueError for BAD_REQUEST, ...) for deprecated delegates."""
        return _LEGACY[self.code](self.message)

    def __eq__(self, other):
        if not isinstance(other, ApiError):
            return NotImplemented
        return (self.code, self.message, self.status, self.details) == \
               (other.code, other.message, other.status, other.details)

    def __hash__(self):
        return hash((self.code, self.message, self.status))

    def __repr__(self):
        return (f"ApiError({self.code}, {self.message!r}, "
                f"status={self.status}, details={self.details})")


# --------------------------------------------------------------------- #
# requests — one per route
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class GetVectorRequest:
    ontology: str
    model: str
    query: str
    fuzzy: bool = False
    version: Optional[str] = None    # None = latest at handle time


@dataclasses.dataclass
class SimilarityRequest:
    ontology: str
    model: str
    a: str
    b: str
    fuzzy: bool = False
    version: Optional[str] = None


@dataclasses.dataclass
class ClosestConceptsRequest:
    ontology: str
    model: str
    query: str
    k: int = 10
    fuzzy: bool = False
    version: Optional[str] = None


@dataclasses.dataclass
class DownloadRequest:
    """Cursor-paginated download: rows ``[offset, offset+limit)`` of the
    entity table. Pin ``version`` (echo back ``DownloadPage.version``) to
    keep the cursor stable across a mid-pagination release."""
    ontology: str
    model: str
    version: Optional[str] = None
    offset: int = 0
    limit: int = 1000


@dataclasses.dataclass
class AutocompleteRequest:
    ontology: str
    model: str
    prefix: str
    limit: int = 10
    version: Optional[str] = None


@dataclasses.dataclass
class HealthRequest:
    pass


@dataclasses.dataclass
class StatsRequest:
    pass


@dataclasses.dataclass
class VersionsRequest:
    ontology: str


@dataclasses.dataclass
class LineageRequest:
    ontology: str
    version: Optional[str] = None    # None = latest


@dataclasses.dataclass
class JobSubmitRequest:
    """Submit one async analytics job (``POST /v1/jobs/submit``).

    ``kind`` selects the workload:

    * ``"knn-join"`` — all-pairs top-``k`` neighbors for ``classes``
      (required, non-empty) under (ontology, model[, version]);
    * ``"drift"`` — per-entity neighborhood churn between ``version``
      (older; default: the release before ``version_b``) and
      ``version_b`` (newer; default: latest);
    * ``"compare"`` — per-model eval metrics for ``models`` (default:
      every model published under the resolved version), optionally
      subsampling the eval split to ``sample`` triples.
    """
    kind: str
    ontology: str
    model: Optional[str] = None      # knn-join/drift: required
    version: Optional[str] = None
    version_b: Optional[str] = None  # drift only: newer release
    classes: Optional[List[str]] = None
    k: int = 10
    models: Optional[List[str]] = None
    sample: Optional[int] = None


@dataclasses.dataclass
class JobStatusRequest:
    job_id: str


@dataclasses.dataclass
class JobResultRequest:
    """Cursor-paginated job results — same contract as ``download``:
    rows ``[offset, offset+limit)`` of the finished job's result table."""
    job_id: str
    offset: int = 0
    limit: int = 1000


@dataclasses.dataclass
class JobCancelRequest:
    job_id: str


@dataclasses.dataclass
class JobListRequest:
    pass


# --------------------------------------------------------------------- #
# responses
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class ConceptHit:
    """One row of a closest-concepts ranking (paper Fig. 1 table)."""
    identifier: str
    label: str
    score: float
    url: str


@dataclasses.dataclass
class VectorResponse:
    ontology: str
    model: str
    version: str
    identifier: str                  # the resolved entity id
    label: str
    vector: List[float]


@dataclasses.dataclass
class SimilarityResponse:
    ontology: str
    model: str
    version: str
    a: str
    b: str
    score: float


@dataclasses.dataclass
class ClosestConceptsResponse:
    ontology: str
    model: str
    version: str
    query: str
    k: int
    results: List[ConceptHit]


@dataclasses.dataclass
class DownloadPage:
    """One page of the download payload. ``rows`` is a list of
    ``[identifier, vector]`` pairs in stable entity-table order, at the
    registry's full float32 precision (bit-identical to ``get-vector``
    for the same class — no endpoint-private quantization);
    ``next_offset`` is None on the final page.

    ``limit`` is the *effective* page size (the server clamps to its
    ``page_limit_max``); ``requested_limit`` echoes what the client
    asked for, so a shrunk page is visible, not silent. ``etag`` is a
    strong validator over ``(ontology, model, version, offset, limit,
    requested_limit)`` — a pinned page is immutable, so those
    coordinates determine the page's exact bytes and an
    ``If-None-Match`` re-fetch can be answered 304 with no index
    work."""
    ontology: str
    model: str
    version: str
    offset: int
    limit: int
    total: int
    rows: List[List[Any]]
    next_offset: Optional[int]
    requested_limit: Optional[int] = None
    etag: Optional[str] = None


@dataclasses.dataclass
class AutocompleteResponse:
    ontology: str
    model: str
    version: str
    prefix: str
    completions: List[str]


@dataclasses.dataclass
class HealthResponse:
    status: str                      # "ok" | "shutting_down"
    api_version: str
    ontologies: List[str]
    scheduler_running: bool


@dataclasses.dataclass
class StatsResponse:
    """Ops counters plus per-route latency histograms: ``latency`` maps
    route name -> a ``LatencyHistogram.snapshot()`` (fixed log-spaced
    buckets, p50/p99 derivable — see ``repro.core.metrics``); the
    scheduler's submit->resolve histogram rides in
    ``scheduler["latency_ms"]``."""
    scheduler: Dict[str, Any]
    cache: Dict[str, Any]
    gateway: Dict[str, Any]
    latency: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class VersionsResponse:
    ontology: str
    versions: List[str]
    latest: str
    models: List[str]                # models published under ``latest``


@dataclasses.dataclass
class JobStatusResponse:
    """One job's lifecycle snapshot (also the submit acknowledgement).

    ``state`` is one of PENDING / RUNNING / DONE / FAILED / CANCELLED;
    ``progress`` is a monotone fraction in [0, 1] (1.0 only at DONE);
    ``total`` is the expected result-row count once known; ``wall_s``
    is populated on terminal states; ``owner_pid`` names the worker
    process the job is pinned to (poll any worker — non-owners answer
    from the shared job state)."""
    job_id: str
    kind: str
    state: str
    progress: float
    ontology: str
    model: Optional[str] = None
    version: Optional[str] = None
    version_b: Optional[str] = None
    k: Optional[int] = None
    submitted_at: float = 0.0
    wall_s: Optional[float] = None
    total: Optional[int] = None
    error: Optional[str] = None
    summary: Optional[Dict[str, Any]] = None
    owner_pid: int = 0


@dataclasses.dataclass
class JobListResponse:
    jobs: List[JobStatusResponse]


@dataclasses.dataclass
class JobResultPage:
    """One page of a DONE job's result table. Mirrors the
    :class:`DownloadPage` cursor contract (effective ``limit`` vs
    ``requested_limit``, ``next_offset`` None on the final page) so the
    HTTP layer's ETag / If-None-Match / chunked-streaming machinery
    applies unchanged: a finished job's rows are immutable, so
    ``(job_id, offset, limit, requested_limit)`` determine the page's
    exact bytes. Row shape per kind — ``knn-join``:
    ``[identifier, [[neighbor_id, score], ...]]``; ``drift``:
    ``[identifier, jaccard]``; ``compare``: ``[model, metrics_dict]``."""
    job_id: str
    kind: str
    offset: int
    limit: int
    total: int
    rows: List[List[Any]]
    next_offset: Optional[int]
    requested_limit: Optional[int] = None
    etag: Optional[str] = None


@dataclasses.dataclass
class LineageResponse:
    """Per-model lineage metadata of one (ontology, version): how each
    snapshot was produced ({"parent_version", "mode", "delta"} — PR 3),
    or None for snapshots published without lineage."""
    ontology: str
    version: str
    lineage: Dict[str, Optional[Dict[str, Any]]]


# --------------------------------------------------------------------- #
# codec
# --------------------------------------------------------------------- #
_TYPES = {
    GetVectorRequest: "get_vector_request",
    SimilarityRequest: "similarity_request",
    ClosestConceptsRequest: "closest_concepts_request",
    DownloadRequest: "download_request",
    AutocompleteRequest: "autocomplete_request",
    HealthRequest: "health_request",
    StatsRequest: "stats_request",
    VersionsRequest: "versions_request",
    LineageRequest: "lineage_request",
    JobSubmitRequest: "job_submit_request",
    JobStatusRequest: "job_status_request",
    JobResultRequest: "job_result_request",
    JobCancelRequest: "job_cancel_request",
    JobListRequest: "job_list_request",
    ConceptHit: "concept_hit",
    VectorResponse: "vector_response",
    SimilarityResponse: "similarity_response",
    ClosestConceptsResponse: "closest_concepts_response",
    DownloadPage: "download_page",
    AutocompleteResponse: "autocomplete_response",
    HealthResponse: "health_response",
    StatsResponse: "stats_response",
    VersionsResponse: "versions_response",
    LineageResponse: "lineage_response",
    JobStatusResponse: "job_status_response",
    JobListResponse: "job_list_response",
    JobResultPage: "job_result_page",
}
_BY_NAME = {name: cls for cls, name in _TYPES.items()}

#: list-of-dataclass fields that from_wire must reconstruct
_NESTED = {ClosestConceptsResponse: {"results": ConceptHit},
           JobListResponse: {"jobs": JobStatusResponse}}


def payload_to(cls, payload: Dict[str, Any]):
    """Build a schema dataclass from an untyped payload dict, rejecting
    unknown and missing fields with BAD_REQUEST (the codec validates
    *shape*; semantic validation — k > 0, non-empty query — happens at
    the gateway boundary)."""
    if not isinstance(payload, dict):
        raise ApiError("BAD_REQUEST",
                       f"payload must be an object, got {type(payload).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(payload) - set(fields))
    if unknown:
        raise ApiError("BAD_REQUEST",
                       f"unknown field(s) for {_TYPES[cls]}: {', '.join(unknown)}",
                       details={"unknown_fields": unknown})
    missing = sorted(
        name for name, f in fields.items()
        if name not in payload
        and f.default is dataclasses.MISSING
        and f.default_factory is dataclasses.MISSING)
    if missing:
        raise ApiError("BAD_REQUEST",
                       f"missing field(s) for {_TYPES[cls]}: {', '.join(missing)}",
                       details={"missing_fields": missing})
    kwargs = dict(payload)
    for fname, sub in _NESTED.get(cls, {}).items():
        if fname in kwargs and isinstance(kwargs[fname], list):
            kwargs[fname] = [payload_to(sub, x) if isinstance(x, dict) else x
                             for x in kwargs[fname]]
    return cls(**kwargs)


def to_wire(obj) -> Dict[str, Any]:
    """Schema object (or ApiError) -> JSON-serializable dict with a
    ``"type"`` tag."""
    if isinstance(obj, ApiError):
        return obj.to_wire()
    cls = type(obj)
    if cls not in _TYPES:
        raise ValueError(f"not a wire type: {cls.__name__}")
    return {"type": _TYPES[cls], **dataclasses.asdict(obj)}


def from_wire(data: Dict[str, Any]):
    """Inverse of :func:`to_wire`. Error payloads come back as ApiError
    *instances* (returned, not raised — the caller decides). Malformed
    input raises ApiError(BAD_REQUEST)."""
    if not isinstance(data, dict):
        raise ApiError("BAD_REQUEST",
                       f"wire value must be an object, got {type(data).__name__}")
    tag = data.get("type")
    if tag == "error":
        body = {k: v for k, v in data.items() if k != "type"}
        unknown = sorted(set(body) - {"code", "message", "status", "details"})
        if unknown or not isinstance(body.get("code"), str) \
                or not isinstance(body.get("details", {}), dict) \
                or isinstance(body.get("status"), bool) \
                or not isinstance(body.get("status", 0), int):
            raise ApiError("BAD_REQUEST", f"malformed error payload: {data!r}")
        try:
            return ApiError(body["code"], body.get("message", ""),
                            details=body.get("details"),
                            status=body.get("status"))
        except ValueError as e:
            raise ApiError("BAD_REQUEST", str(e))
    cls = _BY_NAME.get(tag)
    if cls is None:
        raise ApiError("BAD_REQUEST", f"unknown wire type {tag!r}",
                       details={"type": tag})
    return payload_to(cls, {k: v for k, v in data.items() if k != "type"})
