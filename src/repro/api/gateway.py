"""The batch-first gateway: one ``handle(route, payload)`` entry point
over the serving runtime.

``Gateway`` is the transport-agnostic public surface of Bio-KGvec2go
(the real HTTP front end over it lives in ``repro.api.http``). Design
points:

* **batch-first routing** — every similarity-shaped read (``sim`` AND
  single-query ``closest-concepts``) is submitted to the
  ``BatchScheduler``, so concurrent clients coalesce into micro-batched
  kernel calls instead of each taking a private launch. With a flush
  loop running (``flush_after_ms=``) callers block on their ticket while
  the loop drains; without one the gateway drives a synchronous
  ``flush()`` after submit — same contract, no idle thread.
* **boundary validation** — ``k <= 0``, ``limit <= 0``, empty
  query/prefix, wrong payload shapes and unknown routes all fail with
  structured ``ApiError`` codes *before* anything reaches the kernel
  path.
* **cursor-paginated download** — ``DownloadPage`` rows are a stable
  slice of the entity table for a pinned version; clients echo
  ``page.version``/``page.next_offset`` back to walk the full table
  consistently across a mid-pagination release.
* **freshness hook** — the gateway registers an invalidate listener on
  the engine; the updater's publish→invalidate evicts the cached
  versions/models metadata so ``versions``/``lineage`` reflect a new
  release immediately.
* **version-keyed result cache** — ``sim`` / ``closest-concepts`` /
  ``get-vector`` responses are deterministic per pinned snapshot
  version, so they are cached whole (``repro.api.cache.ResultCache``,
  bounded by entries and bytes) under a key that includes the
  *resolved* version; the same invalidate listener purges an ontology's
  entries on publish, so a new release can never serve stale bytes.
* **admission control** — ``max_pending`` bounds scheduler intake
  (fast ``OVERLOADED`` rejects instead of an unbounded backlog) and
  per-route deadline budgets (``route_budgets``) let queued tickets
  expire before burning kernel time once their client has given up.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
import time
from collections import Counter
from typing import Any, Dict, List, Optional, Tuple

from ..core.metrics import LatencyHistogram
from ..core.serving import (BatchScheduler, SchedulerError, ServingEngine,
                            SimRequest, Ticket, TopKRequest)
from .cache import ResultCache, canonical_payload
from .jobs import JOB_KINDS, JobManager
from .schema import (ApiError, AutocompleteRequest, AutocompleteResponse,
                     ClosestConceptsRequest, ClosestConceptsResponse,
                     ConceptHit, DownloadPage, DownloadRequest,
                     GetVectorRequest, HealthRequest, HealthResponse,
                     JobCancelRequest, JobListRequest, JobListResponse,
                     JobResultPage, JobResultRequest, JobStatusRequest,
                     JobStatusResponse, JobSubmitRequest, LineageRequest,
                     LineageResponse, SimilarityRequest, SimilarityResponse,
                     StatsRequest, StatsResponse, VectorResponse,
                     VersionsRequest, VersionsResponse, payload_to, to_wire)

API_VERSION = "v1"

#: route names whose handlers round-trip a scheduler Ticket — the async
#: front end must provide a future-bridged implementation for each of
#: these (AsyncGateway asserts coverage at construction)
TICKET_ROUTES = ("sim", "closest-concepts")

#: routes whose responses are pure functions of (resolved version,
#: payload) — the only ones the result cache may serve. download is
#: excluded (the HTTP layer already has ETag/304 + streaming for it),
#: ops routes report live state.
CACHED_ROUTES = ("sim", "closest-concepts", "get-vector")


def download_etag(ontology: str, model: str, version: str,
                  offset: int, limit: int,
                  requested_limit: Optional[int] = None) -> str:
    """Strong ETag for one download page. A pinned
    (ontology, model, version) snapshot is immutable, so the page's
    coordinates fully determine its bytes — hashing them (plus the API
    version, so a wire-format change invalidates cached pages) gives a
    validator the HTTP layer can check *without* building or touching
    the index. ``limit`` is the effective (clamped) page size;
    ``requested_limit`` (default: same) is what the client asked for —
    it is part of the key because the page *echoes* it, and a strong
    validator must identify bytes, not just rows (two clamped requests
    with different requested limits serve different bodies)."""
    if requested_limit is None:
        requested_limit = limit
    key = (f"{API_VERSION}|{ontology}|{model}|{version}|{offset}"
           f"|{limit}|{requested_limit}")
    return '"' + hashlib.sha1(key.encode("utf-8")).hexdigest()[:24] + '"'


def job_etag(job_id: str, offset: int, limit: int,
             requested_limit: Optional[int] = None) -> str:
    """Strong ETag for one job-result page. A DONE job's rows are
    immutable and job ids are never reused (pid + per-process sequence),
    so the page coordinates fully determine its bytes — the same
    argument as :func:`download_etag`, with the job id standing in for
    the snapshot coordinates. The HTTP layer still verifies the job is
    actually DONE before vouching a 304 (an in-flight job has no page
    to validate against)."""
    if requested_limit is None:
        requested_limit = limit
    key = (f"{API_VERSION}|job|{job_id}|{offset}|{limit}"
           f"|{requested_limit}")
    return '"' + hashlib.sha1(key.encode("utf-8")).hexdigest()[:24] + '"'


# ------------------------- boundary validation ------------------------- #
def _req_str(name: str, value) -> str:
    if not isinstance(value, str) or not value.strip():
        raise ApiError("BAD_REQUEST",
                       f"{name} must be a non-empty string, got {value!r}",
                       details={"field": name})
    return value


def _req_int(name: str, value, minimum: int) -> int:
    if isinstance(value, bool) or not isinstance(value, int) \
            or value < minimum:
        raise ApiError("BAD_REQUEST",
                       f"{name} must be an integer >= {minimum}, "
                       f"got {value!r}", details={"field": name})
    return value


def _opt_version(value) -> Optional[str]:
    if value is None:
        return None
    return _req_str("version", value)


def _error_from_ticket(e: SchedulerError) -> ApiError:
    """SchedulerError (possibly carrying a structured code from the
    scheduler) -> ApiError. Unclassified faults surface as INTERNAL."""
    return ApiError(e.code or "INTERNAL", str(e), details=e.details)


class Gateway:
    """Versioned (v1) gateway over a :class:`ServingEngine`.

    Owns a :class:`BatchScheduler` unless one is passed in. All five
    paper endpoints plus the ops endpoints dispatch through
    :meth:`handle`; typed per-endpoint methods are the same handlers
    without the wire codec.
    """

    def __init__(self, engine: ServingEngine,
                 scheduler: Optional[BatchScheduler] = None, *,
                 max_batch: int = 64,
                 flush_after_ms: Optional[float] = None,
                 timeout_s: float = 30.0,
                 page_limit_max: int = 10_000,
                 max_pending: Optional[int] = None,
                 route_budgets: Optional[Dict[str, float]] = None,
                 result_cache_entries: int = 4096,
                 result_cache_bytes: int = 32 << 20,
                 max_jobs_queued: int = 8,
                 jobs_keep_finished: int = 64,
                 jobs_yield_s: float = 0.002,
                 jobs_yield_duty: float = 1.0,
                 jobs_slab: int = 64,
                 jobs_state_dir: Optional[str] = None):
        self.engine = engine
        self.scheduler = scheduler or BatchScheduler(
            engine, max_batch=max_batch, flush_after_ms=flush_after_ms,
            max_pending=max_pending, default_budget_s=timeout_s)
        self._owns_scheduler = scheduler is None
        self.timeout_s = timeout_s
        self.page_limit_max = page_limit_max
        #: route name -> deadline budget in seconds; unlisted ticket
        #: routes default to ``timeout_s`` (the client's own collect
        #: timeout — once that fires nobody reads the answer anyway)
        self.route_budgets: Dict[str, float] = dict(route_budgets or {})
        #: whole-response cache for CACHED_ROUTES; None = disabled
        #: (pass ``result_cache_entries=0``)
        self.result_cache: Optional[ResultCache] = None
        if result_cache_entries > 0 and result_cache_bytes > 0:
            self.result_cache = ResultCache(result_cache_entries,
                                            result_cache_bytes)
        self._closed = False
        self._meta_lock = threading.Lock()
        #: ("versions", ont) -> [versions]; ("models", ont, ver) -> [models]
        self._meta_cache: Dict[Tuple, List[str]] = {}
        self.counters: Dict[str, Any] = {
            "requests": 0, "errors": 0, "invalidations": 0,
            "by_route": Counter(), "by_code": Counter()}
        #: route name -> wall-time histogram over every _run (ok + error)
        self.latency: Dict[str, LatencyHistogram] = {}
        #: async batch-analytics jobs, pinned to this process's executor
        self.jobs = JobManager(
            engine, max_queued=max_jobs_queued,
            keep_finished=jobs_keep_finished, yield_s=jobs_yield_s,
            yield_duty=jobs_yield_duty, slab=jobs_slab,
            state_dir=jobs_state_dir)
        engine.add_invalidate_listener(self._on_invalidate)
        self._routes = (
            ("get-vector", ("get-vector", "{ontology}", "{model}"),
             GetVectorRequest, self._handle_get_vector),
            ("sim", ("sim", "{ontology}", "{model}"),
             SimilarityRequest, self._handle_similarity),
            ("closest-concepts", ("closest-concepts", "{ontology}", "{model}"),
             ClosestConceptsRequest, self._handle_closest),
            ("download", ("download", "{ontology}", "{model}"),
             DownloadRequest, self._handle_download),
            ("autocomplete", ("autocomplete", "{ontology}", "{model}"),
             AutocompleteRequest, self._handle_autocomplete),
            ("health", ("health",), HealthRequest, self._handle_health),
            ("stats", ("stats",), StatsRequest, self._handle_stats),
            ("versions", ("versions", "{ontology}"),
             VersionsRequest, self._handle_versions),
            ("lineage", ("lineage", "{ontology}"),
             LineageRequest, self._handle_lineage),
            # the "submit" literal MUST precede the {job_id} wildcard:
            # _match takes the first full match among equal-length
            # patterns, and both are two segments under /jobs
            ("job-submit", ("jobs", "submit"),
             JobSubmitRequest, self._handle_job_submit),
            ("job-result", ("jobs", "{job_id}", "result"),
             JobResultRequest, self._handle_job_result),
            ("job-cancel", ("jobs", "{job_id}", "cancel"),
             JobCancelRequest, self._handle_job_cancel),
            ("jobs", ("jobs",), JobListRequest, self._handle_jobs_list),
            ("job-status", ("jobs", "{job_id}"),
             JobStatusRequest, self._handle_job_status),
        )

    # --------------------------- lifecycle ----------------------------- #
    def close(self, timeout: float = 60.0) -> None:
        """Stop accepting requests; drain the owned scheduler so every
        in-flight ticket resolves. Post-close calls fail SHUTTING_DOWN.
        Unregisters the invalidate listener so the engine doesn't keep
        (and keep notifying) a dead gateway."""
        self._closed = True
        self.engine.remove_invalidate_listener(self._on_invalidate)
        self.jobs.close()
        if self._owns_scheduler:
            self.scheduler.stop(drain=True, timeout=timeout)

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise ApiError("SHUTTING_DOWN", "gateway is shutting down")

    # ------------------------ freshness hook --------------------------- #
    def _on_invalidate(self, ontology: str, version: Optional[str]) -> None:
        """Invalidate listener: a publish landed — evict this ontology's
        cached versions/models so ops endpoints see it immediately, and
        purge its result-cache entries. (Version keying alone already
        prevents stale hits — a new release resolves to a new version
        and thus a new key — the eager purge just frees the capacity.)"""
        with self._meta_lock:
            self.counters["invalidations"] += 1
            for key in [k for k in self._meta_cache if k[1] == ontology]:
                del self._meta_cache[key]
        if self.result_cache is not None:
            self.result_cache.invalidate_ontology(ontology)

    def _versions(self, ontology: str,
                  want: Optional[str] = None) -> List[str]:
        """Cached version list; re-reads the store when empty-cached or
        when ``want`` isn't in the cached list (so a pinned read of a
        just-published, not-yet-invalidated version still resolves)."""
        key = ("versions", ontology)
        with self._meta_lock:
            vs = self._meta_cache.get(key)
        if vs is None or (want is not None and want not in vs):
            vs = self.engine.registry.store.versions(ontology)
            # never cache an empty list: it would grow the cache without
            # bound under unique bogus names, and would 404 an ontology
            # forever if it is later published without an invalidate
            if vs:
                with self._meta_lock:
                    self._meta_cache[key] = vs
        return vs

    def _models(self, ontology: str, version: str,
                want: Optional[str] = None) -> List[str]:
        key = ("models", ontology, version)
        with self._meta_lock:
            ms = self._meta_cache.get(key)
        if ms is None or (want is not None and want not in ms):
            ms = self.engine.registry.store.models(ontology, version)
            if ms:                           # same no-empty-entries rule
                with self._meta_lock:
                    self._meta_cache[key] = ms
        return ms

    def _resolve_coords(self, ontology: str, model: Optional[str],
                        version: Optional[str]) -> str:
        """Validate (ontology, model, version) existence at the boundary;
        returns the resolved version. ``model=None`` skips model checks
        (version-level endpoints like lineage)."""
        _req_str("ontology", ontology)
        versions = self._versions(ontology, want=version)
        if not versions:
            raise ApiError("UNKNOWN_ONTOLOGY",
                           f"unknown ontology {ontology!r}",
                           details={"ontology": ontology})
        if version is None:
            version = self.engine.latest_version(ontology)
        elif version not in versions:
            raise ApiError("UNKNOWN_VERSION",
                           f"unknown version {version!r} for {ontology!r}",
                           details={"ontology": ontology, "version": version,
                                    "known_versions": versions})
        if model is not None:
            _req_str("model", model)
            models = self._models(ontology, version, want=model)
            if model not in models:
                raise ApiError(
                    "UNKNOWN_MODEL",
                    f"unknown model {model!r} for {ontology}/{version}",
                    details={"ontology": ontology, "version": version,
                             "model": model, "known_models": models})
        return version

    # ---------------------- scheduler round trip ----------------------- #
    def _route_budget(self, route_key: str) -> float:
        """Deadline budget for one ticket route (seconds): configured
        ``route_budgets`` entry, else the gateway-wide ``timeout_s``."""
        return float(self.route_budgets.get(route_key, self.timeout_s))

    def _collect_ticket(self, ticket: Ticket,
                        timeout: Optional[float] = None):
        """Block on an already-flushing ticket; translate failures."""
        if timeout is None:
            timeout = self.timeout_s
        try:
            return ticket.result(timeout=timeout)
        except SchedulerError as e:
            raise _error_from_ticket(e) from None
        except TimeoutError:
            raise ApiError(
                "TIMEOUT",
                f"request unresolved after {timeout}s",
                details={"ticket": ticket.id}) from None

    def _await_ticket(self, ticket: Ticket,
                      timeout: Optional[float] = None):
        """Block until the ticket resolves. Without a flush loop the
        gateway drives a synchronous flush itself (queues are popped
        under the scheduler lock, so coexisting callers/loops each
        resolve a ticket exactly once)."""
        if not self.scheduler.running():
            self.scheduler.flush()
        return self._collect_ticket(ticket, timeout=timeout)

    def _submit_similarity(self, req: SimilarityRequest) -> Ticket:
        self._check_open()
        _req_str("a", req.a)
        _req_str("b", req.b)
        version = self._resolve_coords(req.ontology, req.model,
                                       _opt_version(req.version))
        return self.scheduler.submit(SimRequest(
            req.ontology, req.model, req.a, req.b,
            fuzzy=bool(req.fuzzy), version=version,
            budget_s=self._route_budget("sim")))

    def _similarity_response(self, req: SimilarityRequest, ticket: Ticket,
                             score: float) -> SimilarityResponse:
        return SimilarityResponse(
            ontology=req.ontology, model=req.model, version=ticket.version,
            a=req.a, b=req.b, score=float(score))

    def _submit_closest(self, req: ClosestConceptsRequest) -> Ticket:
        self._check_open()
        _req_str("query", req.query)
        _req_int("k", req.k, minimum=1)
        version = self._resolve_coords(req.ontology, req.model,
                                       _opt_version(req.version))
        return self.scheduler.submit(TopKRequest(
            req.ontology, req.model, req.query, req.k,
            version=version, fuzzy=bool(req.fuzzy),
            budget_s=self._route_budget("closest-concepts")))

    def _closest_response(self, req: ClosestConceptsRequest, ticket: Ticket,
                          result) -> ClosestConceptsResponse:
        hits = [ConceptHit(c.identifier, c.label, float(c.score), c.url)
                for c in result]
        return ClosestConceptsResponse(
            ontology=req.ontology, model=req.model, version=ticket.version,
            query=req.query, k=req.k, results=hits)

    # ---------------------------- handlers ----------------------------- #
    def _handle_similarity(self, req: SimilarityRequest) -> SimilarityResponse:
        ticket = self._submit_similarity(req)
        score = self._await_ticket(ticket,
                                   timeout=self._route_budget("sim"))
        return self._similarity_response(req, ticket, score)

    def _handle_closest(self,
                        req: ClosestConceptsRequest) -> ClosestConceptsResponse:
        ticket = self._submit_closest(req)
        result = self._await_ticket(
            ticket, timeout=self._route_budget("closest-concepts"))
        return self._closest_response(req, ticket, result)

    def _handle_get_vector(self, req: GetVectorRequest) -> VectorResponse:
        self._check_open()
        _req_str("query", req.query)
        version = self._resolve_coords(req.ontology, req.model,
                                       _opt_version(req.version))
        index = self.engine._index(req.ontology, req.model, version)
        row = index.resolve(req.query, fuzzy=bool(req.fuzzy))
        if row is None:
            raise ApiError("UNKNOWN_CLASS",
                           f"unknown class {req.query!r}",
                           details={"missing": [req.query]})
        return VectorResponse(
            ontology=req.ontology, model=req.model, version=version,
            identifier=index.entity_ids[row], label=index.labels[row],
            vector=[float(x) for x in index.embeddings[row]])

    def _handle_download(self, req: DownloadRequest) -> DownloadPage:
        self._check_open()
        offset = _req_int("offset", req.offset, minimum=0)
        requested = _req_int("limit", req.limit, minimum=1)
        # clamp to the server's page cap, but ECHO both limits: a client
        # paging with limit=20_000 must see the shrink, not infer it
        limit = min(requested, self.page_limit_max)
        version = self._resolve_coords(req.ontology, req.model,
                                       _opt_version(req.version))
        index = self.engine._index(req.ontology, req.model, version)
        total = len(index.entity_ids)
        ids = index.entity_ids[offset:offset + limit]
        vecs = index.embeddings[offset:offset + limit]
        # full registry precision: the same class must serialize to the
        # same bytes here and on get-vector (wire-fidelity contract)
        rows = [[ident, [float(x) for x in vec]]
                for ident, vec in zip(ids, vecs)]
        end = offset + len(rows)
        return DownloadPage(
            ontology=req.ontology, model=req.model, version=version,
            offset=offset, limit=limit, total=total, rows=rows,
            next_offset=end if end < total else None,
            requested_limit=requested,
            etag=download_etag(req.ontology, req.model, version,
                               offset, limit, requested))

    def _handle_autocomplete(self,
                             req: AutocompleteRequest) -> AutocompleteResponse:
        self._check_open()
        _req_str("prefix", req.prefix)
        limit = _req_int("limit", req.limit, minimum=1)
        version = self._resolve_coords(req.ontology, req.model,
                                       _opt_version(req.version))
        index = self.engine._index(req.ontology, req.model, version)
        return AutocompleteResponse(
            ontology=req.ontology, model=req.model, version=version,
            prefix=req.prefix, completions=index.autocomplete(req.prefix,
                                                              limit))

    def _handle_health(self, req: HealthRequest) -> HealthResponse:
        accepting = not self._closed and self.scheduler.accepting()
        return HealthResponse(
            status="ok" if accepting else "shutting_down",
            api_version=API_VERSION,
            ontologies=self.engine.registry.store.ontologies(),
            scheduler_running=self.scheduler.running())

    def _handle_stats(self, req: StatsRequest) -> StatsResponse:
        with self.scheduler._lock:
            sched = dict(self.scheduler.stats)
        sched["pending"] = self.scheduler.pending()
        #: submit->resolve latency over every ticket (scheduler-side)
        sched["latency_ms"] = self.scheduler.latency.snapshot()
        with self._meta_lock:
            gw = {"requests": self.counters["requests"],
                  "errors": self.counters["errors"],
                  "invalidations": self.counters["invalidations"],
                  "by_route": dict(self.counters["by_route"]),
                  "by_code": dict(self.counters["by_code"])}
            hists = dict(self.latency)
        if self.result_cache is not None:
            gw["result_cache"] = self.result_cache.stats()
        gw["jobs"] = self.jobs.stats()
        return StatsResponse(
            scheduler=sched, cache=self.engine.cache_stats(), gateway=gw,
            latency={route: h.snapshot()
                     for route, h in sorted(hists.items())})

    def _handle_versions(self, req: VersionsRequest) -> VersionsResponse:
        _req_str("ontology", req.ontology)
        versions = self._versions(req.ontology)
        if not versions:
            raise ApiError("UNKNOWN_ONTOLOGY",
                           f"unknown ontology {req.ontology!r}",
                           details={"ontology": req.ontology})
        latest = self.engine.latest_version(req.ontology)
        return VersionsResponse(
            ontology=req.ontology, versions=list(versions), latest=latest,
            models=self._models(req.ontology, latest))

    # ------------------------- job handlers ---------------------------- #
    @staticmethod
    def _job_status_response(pub: Dict[str, Any]) -> JobStatusResponse:
        fields = {f.name for f in dataclasses.fields(JobStatusResponse)}
        return JobStatusResponse(**{k: v for k, v in pub.items()
                                    if k in fields})

    def _req_str_list(self, name: str, value) -> List[str]:
        if not isinstance(value, list) or not value or \
                not all(isinstance(x, str) and x.strip() for x in value):
            raise ApiError(
                "BAD_REQUEST",
                f"{name} must be a non-empty list of non-empty strings",
                details={"field": name})
        return list(value)

    def _validate_job_submit(self, req: JobSubmitRequest
                             ) -> Tuple[str, Dict[str, Any]]:
        """Full boundary validation of one job submission — coordinates
        resolve, per-kind required fields are present, defaults (latest
        version, previous release, all models) are pinned here so the
        job's status echoes exactly what will run. No analytics work
        happens before the queue-bound check in ``JobManager.submit``
        (which this precedes only by dict lookups — the OVERLOADED
        fast-reject budget stays in the sub-millisecond range)."""
        kind = _req_str("kind", req.kind)
        if kind not in JOB_KINDS:
            raise ApiError(
                "BAD_REQUEST",
                f"unknown job kind {kind!r}; expected one of "
                f"{', '.join(JOB_KINDS)}",
                details={"kind": kind, "known_kinds": list(JOB_KINDS)})
        ontology = _req_str("ontology", req.ontology)
        k = _req_int("k", req.k, minimum=1)
        spec: Dict[str, Any] = {"ontology": ontology, "k": k,
                                "model": None, "version": None,
                                "version_b": None}
        if kind == "knn-join":
            model = _req_str("model", req.model)
            classes = self._req_str_list("classes", req.classes)
            spec["model"] = model
            spec["version"] = self._resolve_coords(
                ontology, model, _opt_version(req.version))
            spec["classes"] = classes
        elif kind == "drift":
            model = _req_str("model", req.model)
            spec["model"] = model
            version_b = self._resolve_coords(
                ontology, model, _opt_version(req.version_b))
            if req.version is None:
                versions = self._versions(ontology)
                i = versions.index(version_b)
                if i == 0:
                    raise ApiError(
                        "BAD_REQUEST",
                        f"drift needs two releases: {version_b!r} is the "
                        f"oldest published version of {ontology!r}",
                        details={"ontology": ontology,
                                 "version_b": version_b})
                version_a = versions[i - 1]
            else:
                version_a = _req_str("version", req.version)
            if version_a == version_b:
                raise ApiError(
                    "BAD_REQUEST",
                    f"drift versions must differ, got {version_a!r} twice",
                    details={"version": version_a})
            # the older release must also carry this model
            self._resolve_coords(ontology, model, version_a)
            spec["version"] = version_a
            spec["version_b"] = version_b
            spec["classes"] = (None if req.classes is None
                               else self._req_str_list("classes",
                                                       req.classes))
        else:  # compare
            version = self._resolve_coords(ontology, None,
                                           _opt_version(req.version))
            spec["version"] = version
            if req.models is None:
                models = self._models(ontology, version)
            else:
                models = self._req_str_list("models", req.models)
                for m in models:
                    self._resolve_coords(ontology, m, version)
            spec["models"] = models
            spec["sample"] = (None if req.sample is None
                              else _req_int("sample", req.sample,
                                            minimum=1))
        return kind, spec

    def _handle_job_submit(self, req: JobSubmitRequest) -> JobStatusResponse:
        self._check_open()
        kind, spec = self._validate_job_submit(req)
        return self._job_status_response(self.jobs.submit(kind, spec))

    def _handle_job_status(self, req: JobStatusRequest) -> JobStatusResponse:
        _req_str("job_id", req.job_id)
        return self._job_status_response(self.jobs.status(req.job_id))

    def _handle_job_result(self, req: JobResultRequest) -> JobResultPage:
        self._check_open()
        _req_str("job_id", req.job_id)
        offset = _req_int("offset", req.offset, minimum=0)
        requested = _req_int("limit", req.limit, minimum=1)
        limit = min(requested, self.page_limit_max)
        kind, rows = self.jobs.result_rows(req.job_id)
        total = len(rows)
        page = rows[offset:offset + limit]
        end = offset + len(page)
        return JobResultPage(
            job_id=req.job_id, kind=kind, offset=offset, limit=limit,
            total=total, rows=page,
            next_offset=end if end < total else None,
            requested_limit=requested,
            etag=job_etag(req.job_id, offset, limit, requested))

    def _handle_job_cancel(self, req: JobCancelRequest) -> JobStatusResponse:
        _req_str("job_id", req.job_id)
        return self._job_status_response(self.jobs.cancel(req.job_id))

    def _handle_jobs_list(self, req: JobListRequest) -> JobListResponse:
        return JobListResponse(jobs=[self._job_status_response(d)
                                     for d in self.jobs.list_jobs()])

    def _handle_lineage(self, req: LineageRequest) -> LineageResponse:
        version = self._resolve_coords(req.ontology, None,
                                       _opt_version(req.version))
        store = self.engine.registry.store
        lineage = {m: store.load_metadata(req.ontology, version, m
                                          ).get("lineage")
                   for m in self._models(req.ontology, version)}
        return LineageResponse(ontology=req.ontology, version=version,
                               lineage=lineage)

    # ------------------------- typed front door ------------------------ #
    def get_vector(self, ontology: str, model: str, query: str, *,
                   fuzzy: bool = False,
                   version: Optional[str] = None) -> VectorResponse:
        return self._run("get-vector", GetVectorRequest(
            ontology, model, query, fuzzy, version), self._handle_get_vector)

    def similarity(self, ontology: str, model: str, a: str, b: str, *,
                   fuzzy: bool = False,
                   version: Optional[str] = None) -> SimilarityResponse:
        return self._run("sim", SimilarityRequest(
            ontology, model, a, b, fuzzy, version), self._handle_similarity)

    def closest_concepts(self, ontology: str, model: str, query: str, *,
                         k: int = 10, fuzzy: bool = False,
                         version: Optional[str] = None
                         ) -> ClosestConceptsResponse:
        return self._run("closest-concepts", ClosestConceptsRequest(
            ontology, model, query, k, fuzzy, version), self._handle_closest)

    def closest_concepts_batch(self, requests, *,
                               return_exceptions: bool = False
                               ) -> List:
        """Submit a page of closest-concepts requests as one wave, then
        collect — the blocking-thread equivalent of the async gather
        fan-out, and how a client should issue a burst (one submit per
        call would serialize on each ticket and defeat coalescing).

        With ``return_exceptions`` failed items come back as their
        ApiError in place; otherwise the first failure raises (tickets
        already in flight still resolve — results are discarded).
        """
        requests = list(requests)            # may be a one-shot iterable
        staged: List = []                    # Ticket | ApiError, in order
        try:
            for req in requests:
                try:
                    staged.append(self._run("closest-concepts", req,
                                            self._submit_closest))
                except ApiError as e:
                    if not return_exceptions:
                        raise
                    staged.append(e)
        finally:
            # flush even when a later submit raised: in sync mode nothing
            # else would drain the already-staged tickets
            if not self.scheduler.running():
                self.scheduler.flush()
        out: List = []
        for req, t in zip(requests, staged):
            if isinstance(t, ApiError):
                out.append(t)
                continue
            if isinstance(t, ClosestConceptsResponse):
                out.append(t)            # result-cache hit at staging time
                continue
            try:
                resp = self._closest_response(req, t,
                                              self._collect_ticket(t))
                self._cache_store(self._cache_key("closest-concepts", req),
                                  resp)
                out.append(resp)
            except ApiError as e:
                self._count_error(e)
                if not return_exceptions:
                    raise
                out.append(e)
        return out

    def download(self, ontology: str, model: str, *,
                 version: Optional[str] = None, offset: int = 0,
                 limit: int = 1000) -> DownloadPage:
        return self._run("download", DownloadRequest(
            ontology, model, version, offset, limit), self._handle_download)

    def autocomplete(self, ontology: str, model: str, prefix: str, *,
                     limit: int = 10, version: Optional[str] = None
                     ) -> AutocompleteResponse:
        return self._run("autocomplete", AutocompleteRequest(
            ontology, model, prefix, limit, version),
            self._handle_autocomplete)

    def health(self) -> HealthResponse:
        return self._run("health", HealthRequest(), self._handle_health)

    def stats(self) -> StatsResponse:
        return self._run("stats", StatsRequest(), self._handle_stats)

    def versions(self, ontology: str) -> VersionsResponse:
        return self._run("versions", VersionsRequest(ontology),
                         self._handle_versions)

    def lineage(self, ontology: str,
                version: Optional[str] = None) -> LineageResponse:
        return self._run("lineage", LineageRequest(ontology, version),
                         self._handle_lineage)

    def submit_job(self, kind: str, ontology: str, *,
                   model: Optional[str] = None,
                   version: Optional[str] = None,
                   version_b: Optional[str] = None,
                   classes: Optional[List[str]] = None, k: int = 10,
                   models: Optional[List[str]] = None,
                   sample: Optional[int] = None) -> JobStatusResponse:
        return self._run("job-submit", JobSubmitRequest(
            kind=kind, ontology=ontology, model=model, version=version,
            version_b=version_b, classes=classes, k=k, models=models,
            sample=sample), self._handle_job_submit)

    def job_status(self, job_id: str) -> JobStatusResponse:
        return self._run("job-status", JobStatusRequest(job_id),
                         self._handle_job_status)

    def job_result(self, job_id: str, *, offset: int = 0,
                   limit: int = 1000) -> JobResultPage:
        return self._run("job-result",
                         JobResultRequest(job_id, offset, limit),
                         self._handle_job_result)

    def job_cancel(self, job_id: str) -> JobStatusResponse:
        return self._run("job-cancel", JobCancelRequest(job_id),
                         self._handle_job_cancel)

    def jobs_list(self) -> JobListResponse:
        return self._run("jobs", JobListRequest(), self._handle_jobs_list)

    def job_wait(self, job_id: str, *, poll_s: float = 0.02,
                 timeout: Optional[float] = None) -> JobStatusResponse:
        """Poll until the job reaches a terminal state (test/CLI helper;
        network clients poll the route themselves)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            st = self.job_status(job_id)
            if st.state in ("DONE", "FAILED", "CANCELLED"):
                return st
            if deadline is not None and time.monotonic() > deadline:
                raise ApiError("TIMEOUT",
                               f"job {job_id} unresolved after {timeout}s",
                               details={"job_id": job_id,
                                        "state": st.state})
            time.sleep(poll_s)

    # ---------------------------- dispatch ----------------------------- #
    def _count_error(self, e: ApiError) -> None:
        if getattr(e, "_counted", False):
            return
        e._counted = True
        with self._meta_lock:
            self.counters["errors"] += 1
            self.counters["by_code"][e.code] += 1

    def _route_latency(self, route_key: str) -> LatencyHistogram:
        h = self.latency.get(route_key)
        if h is None:
            with self._meta_lock:
                h = self.latency.setdefault(route_key, LatencyHistogram())
        return h

    # ------------------------- result cache ---------------------------- #
    def _cache_key(self, route_key: str, req) -> Optional[Tuple]:
        """Cache key for a request on a cacheable route, or None when the
        request can't (or shouldn't) be cached. The key pins the
        *resolved* version — a publish moves latest to a new version and
        therefore a new key — and carries the payload as canonical JSON:
        a raw field tuple would alias ``True`` with ``1`` (equal ints in
        Python) and serve a cached hit for a payload the validator
        rejects."""
        if self.result_cache is None or route_key not in CACHED_ROUTES \
                or self._closed:
            return None
        try:
            version = self._resolve_coords(req.ontology, req.model,
                                           _opt_version(req.version))
        except ApiError:
            return None        # let the handler classify and raise
        payload = dataclasses.asdict(req)
        # the resolved version already keys the entry: dropping the raw
        # field folds ``version=None`` and an explicit pin of the same
        # version onto one entry (their responses are identical bytes)
        payload.pop("version", None)
        canon = canonical_payload(payload)
        if canon is None:
            return None
        return (route_key, req.ontology, req.model, version, canon)

    def _cache_store(self, key: Optional[Tuple], resp) -> None:
        if key is None or self.result_cache is None:
            return
        try:
            nbytes = len(json.dumps(to_wire(resp)))
        except (TypeError, ValueError):
            return             # non-JSON response object: don't cache
        self.result_cache.put(key, resp, nbytes)

    def _run(self, route_key: str, req, handler):
        with self._meta_lock:
            self.counters["requests"] += 1
            self.counters["by_route"][route_key] += 1
        t0 = time.perf_counter()
        try:
            key = self._cache_key(route_key, req)
            if key is not None:
                hit = self.result_cache.get(key)
                if hit is not None:
                    return hit
            resp = handler(req)
            # ticket-submitting handlers (the async front end, batch
            # staging) return the Ticket itself — the caller stores the
            # built response once it settles
            if key is not None and not isinstance(resp, Ticket):
                self._cache_store(key, resp)
            return resp
        except ApiError as e:
            self._count_error(e)
            raise
        except Exception as e:
            err = ApiError("INTERNAL", f"internal error: {e}")
            self._count_error(err)
            raise err from e
        finally:
            # errors get timed too: a latency histogram that only sees
            # successes hides exactly the slow-path (timeout) traffic
            self._route_latency(route_key).observe(time.perf_counter() - t0)

    def _match(self, route: str):
        if not isinstance(route, str):
            raise ApiError("BAD_REQUEST",
                           f"route must be a string, got {route!r}")
        parts = tuple(p for p in route.strip("/").split("/") if p)
        for name, pattern, cls, handler in self._routes:
            if len(parts) != len(pattern):
                continue
            params = {}
            for seg, pat in zip(parts, pattern):
                if pat.startswith("{"):
                    params[pat[1:-1]] = seg
                elif seg != pat:
                    break
            else:
                return name, cls, handler, params
        # a distinct code from BAD_REQUEST: transports can map status
        # straight from the code, and by_code stats keep bad URLs apart
        # from malformed payloads
        raise ApiError("NOT_FOUND", f"unknown route {route!r}",
                       details={"route": route})

    def _build_request(self, route: str,
                       payload: Optional[Dict[str, Any]], match=None):
        """Shared route+payload -> (name, handler, request) parsing for
        the sync and async ``handle`` entry points; raises ApiError on
        any malformed input. ``match`` lets a transport that already ran
        :meth:`_match` (for query coercion) pass its result through
        instead of paying the route table twice per request."""
        if payload is None:
            payload = {}
        if not isinstance(payload, dict):
            raise ApiError(
                "BAD_REQUEST",
                f"payload must be an object, got {type(payload).__name__}")
        name, cls, handler, params = match or self._match(route)
        clash = sorted(k for k in params
                       if k in payload and payload[k] != params[k])
        if clash:
            # silently letting the path win would answer against the
            # wrong coordinates — surface the client mistake instead
            raise ApiError(
                "BAD_REQUEST",
                f"payload field(s) conflict with route: {', '.join(clash)}",
                details={"conflicting_fields": clash, "route": route})
        return name, handler, payload_to(cls, {**payload, **params})

    def handle(self, route: str,
               payload: Optional[Dict[str, Any]] = None, *,
               match=None) -> Dict[str, Any]:
        """THE entry point: dispatch a route string + payload dict to its
        handler; returns a wire dict (response, or a structured error
        payload — this method never raises on request faults)."""
        try:
            name, handler, req = self._build_request(route, payload, match)
            return to_wire(self._run(name, req, handler))
        except ApiError as e:
            self._count_error(e)
            return e.to_wire()
