"""Bio-KGvec2go gateway API v1 — the public surface of the service.

The paper's contribution is a *Web API* over versioned biomedical KG
embeddings (Portisch et al.'s KGvec2go design, extended with dynamic
versioning). This package is that API, transport-agnostic: a
:class:`Gateway` dispatches route strings to typed handlers, every
similarity-shaped read rides the ``BatchScheduler`` (PR 2's concurrent
runtime), and :class:`AsyncGateway` exposes the same surface as
awaitables. An HTTP layer is a thin shim over ``Gateway.handle``.

Paper endpoint -> route -> schema types:

=================  ====================================  =========================================================
endpoint (paper)   route                                 request -> response
=================  ====================================  =========================================================
get-vector         ``/get-vector/{ontology}/{model}``    ``GetVectorRequest`` -> ``VectorResponse``
similarity         ``/sim/{ontology}/{model}``           ``SimilarityRequest`` -> ``SimilarityResponse``
closest concepts   ``/closest-concepts/{onto}/{model}``  ``ClosestConceptsRequest`` -> ``ClosestConceptsResponse``
download           ``/download/{ontology}/{model}``      ``DownloadRequest`` -> ``DownloadPage`` (cursor-paginated)
autocomplete       ``/autocomplete/{ontology}/{model}``  ``AutocompleteRequest`` -> ``AutocompleteResponse``
=================  ====================================  =========================================================

Ops endpoints (not in the paper, required to run it as a service):

=========  ==========================  ===================================
endpoint   route                       request -> response
=========  ==========================  ===================================
health     ``/health``                 ``HealthRequest`` -> ``HealthResponse``
stats      ``/stats``                  ``StatsRequest`` -> ``StatsResponse``
versions   ``/versions/{ontology}``    ``VersionsRequest`` -> ``VersionsResponse``
lineage    ``/lineage/{ontology}``     ``LineageRequest`` -> ``LineageResponse``
=========  ==========================  ===================================

Failures are structured: :class:`ApiError` with a stable code
(``UNKNOWN_ONTOLOGY``, ``UNKNOWN_MODEL``, ``UNKNOWN_VERSION``,
``UNKNOWN_CLASS``, ``NOT_FOUND`` (unknown route), ``BAD_REQUEST``,
``TIMEOUT``, ``SHUTTING_DOWN``, ``INTERNAL``), an HTTP status, and
machine-readable ``details`` (e.g. the *full* list of unresolvable
class names). ``to_wire`` / ``from_wire`` round-trip every request,
response and error through plain JSON-able dicts.

The HTTP front end (:mod:`repro.api.http` — ``serve_http``) serves
exactly these routes over a real socket: GET query strings or POST
JSON bodies in, the same wire dicts out, ``ApiError.status`` as the
response status, ETag/304 and chunked streaming on ``download``.
"""
from .aio import AsyncGateway, ticket_future
from .cache import ResultCache
from .gateway import API_VERSION, CACHED_ROUTES, Gateway, download_etag
from .http import GatewayHTTPServer, serve_http
from .workers import StoreWatcher, WorkerPool, merge_stats_wires
from .schema import (CODE_STATUS, ApiError, AutocompleteRequest,
                     AutocompleteResponse, ClosestConceptsRequest,
                     ClosestConceptsResponse, ConceptHit, DownloadPage,
                     DownloadRequest, GetVectorRequest, HealthRequest,
                     HealthResponse, LineageRequest, LineageResponse,
                     SimilarityRequest, SimilarityResponse, StatsRequest,
                     StatsResponse, VectorResponse, VersionsRequest,
                     VersionsResponse, from_wire, payload_to, to_wire)

__all__ = [
    "API_VERSION", "AsyncGateway", "Gateway", "ticket_future",
    "ResultCache", "CACHED_ROUTES",
    "GatewayHTTPServer", "serve_http", "download_etag",
    "WorkerPool", "StoreWatcher", "merge_stats_wires",
    "CODE_STATUS", "ApiError", "from_wire", "payload_to", "to_wire",
    "GetVectorRequest", "VectorResponse",
    "SimilarityRequest", "SimilarityResponse",
    "ClosestConceptsRequest", "ClosestConceptsResponse", "ConceptHit",
    "DownloadRequest", "DownloadPage",
    "AutocompleteRequest", "AutocompleteResponse",
    "HealthRequest", "HealthResponse", "StatsRequest", "StatsResponse",
    "VersionsRequest", "VersionsResponse",
    "LineageRequest", "LineageResponse",
]
