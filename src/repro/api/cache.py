"""Version-keyed result cache for the gateway read path.

Real biomedical-API traffic is heavily repeated: a small set of popular
terms dominates (KGvec2go served exactly this shape as a public web
API), so the same ``sim`` / ``closest-concepts`` / ``get-vector``
requests arrive over and over. Everything upstream of the kernel is
deterministic *per pinned snapshot version*, which makes the full typed
response safely cacheable as long as the key carries the resolved
version — a new release changes the version, so it can never be served
stale bytes, and the publish→invalidate listener purges the old
ontology's entries eagerly anyway.

The cache is an LRU ordered dict with per-entry hit counters and an
LFU-biased eviction: when over budget we look at a small window of the
coldest (least recently used) entries and evict the least *frequently*
used among them. That keeps one-hit-wonder scan traffic from flushing
the hot Zipf head the way pure LRU would, without the bookkeeping of a
full frequency heap. Capacity is bounded twice — by entry count and by
(approximate, caller-reported) response bytes — so a burst of large
``closest-concepts`` pages cannot balloon resident memory.

Keys are built by the gateway as
``(route, ontology, model, resolved_version, canonical_payload)`` where
``canonical_payload`` is a sorted-key JSON dump of the request payload.
JSON canonicalisation matters: a tuple of raw field values would alias
``True`` with ``1`` (equal ints in Python) and serve a cached response
for a payload the validator should reject; ``json.dumps`` keeps them
distinct (``true`` vs ``1``).

Thread-safe; every public method takes the internal lock.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Dict, Hashable, Optional, Tuple

__all__ = ["ResultCache", "canonical_payload"]

# How many cold-end entries the evictor considers before dropping the
# least frequently used among them (the "LFU window" of the LRU order).
_EVICT_WINDOW = 8


def canonical_payload(payload: Dict[str, Any]) -> Optional[str]:
    """Deterministic string form of a request payload, or None if the
    payload contains something non-JSON (then it is simply not cached)."""
    import json
    try:
        return json.dumps(payload, sort_keys=True, separators=(",", ":"))
    except (TypeError, ValueError):
        return None


class _Entry:
    __slots__ = ("value", "nbytes", "hits")

    def __init__(self, value: Any, nbytes: int) -> None:
        self.value = value
        self.nbytes = nbytes
        self.hits = 0


class ResultCache:
    """Bounded LFU/LRU map from request keys to typed response objects.

    Both bounds must be positive — to disable caching the gateway simply
    does not construct a cache (``result_cache_entries=0``) rather than
    carrying an unbounded mode here.
    """

    def __init__(self, max_entries: int = 4096,
                 max_bytes: int = 32 << 20) -> None:
        if max_entries <= 0:
            raise ValueError(f"max_entries must be positive, got {max_entries}")
        if max_bytes <= 0:
            raise ValueError(f"max_bytes must be positive, got {max_bytes}")
        self.max_entries = int(max_entries)
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._data: "OrderedDict[Tuple[Hashable, ...], _Entry]" = OrderedDict()
        self._bytes = 0
        self._hits = 0
        self._misses = 0
        self._insertions = 0
        self._evictions = 0
        self._invalidations = 0
        self._oversize = 0

    # ------------------------------------------------------------- core
    def get(self, key: Tuple[Hashable, ...]) -> Optional[Any]:
        """Return the cached response for ``key`` or None. Hits move the
        entry to the hot end and bump its frequency counter."""
        with self._lock:
            entry = self._data.get(key)
            if entry is None:
                self._misses += 1
                return None
            entry.hits += 1
            self._data.move_to_end(key)
            self._hits += 1
            return entry.value

    def put(self, key: Tuple[Hashable, ...], value: Any, nbytes: int) -> bool:
        """Insert ``value`` under ``key``; ``nbytes`` is the caller's
        estimate of the response's serialized size (used for the byte
        bound). Returns False when the entry alone exceeds ``max_bytes``
        (it is refused rather than flushing the whole cache for it)."""
        nbytes = int(nbytes)
        if nbytes > self.max_bytes:
            with self._lock:
                self._oversize += 1
            return False
        with self._lock:
            old = self._data.pop(key, None)
            if old is not None:
                self._bytes -= old.nbytes
            self._data[key] = _Entry(value, nbytes)
            self._bytes += nbytes
            self._insertions += 1
            self._evict_locked()
            return True

    def _evict_locked(self) -> None:
        while len(self._data) > self.max_entries or self._bytes > self.max_bytes:
            # LFU over a window of the LRU cold end: among the oldest
            # few entries, drop the one with the fewest hits.
            victim = None
            victim_hits = None
            for i, (k, e) in enumerate(self._data.items()):
                if i >= _EVICT_WINDOW:
                    break
                if victim_hits is None or e.hits < victim_hits:
                    victim, victim_hits = k, e.hits
            if victim is None:  # pragma: no cover - empty cache can't be over
                return
            entry = self._data.pop(victim)
            self._bytes -= entry.nbytes
            self._evictions += 1

    # ----------------------------------------------------- invalidation
    def invalidate_ontology(self, ontology: str) -> int:
        """Drop every entry whose key names ``ontology`` (key slot 1).

        Called from the engine's publish→invalidate listener. Version
        keying already makes stale hits impossible (a new release
        resolves to a new version and therefore a new key); the eager
        purge just stops superseded versions from squatting on capacity.
        """
        with self._lock:
            dead = [k for k in self._data if len(k) > 1 and k[1] == ontology]
            for k in dead:
                self._bytes -= self._data.pop(k).nbytes
            self._invalidations += len(dead)
            return len(dead)

    def clear(self) -> int:
        with self._lock:
            n = len(self._data)
            self._data.clear()
            self._bytes = 0
            self._invalidations += n
            return n

    # ------------------------------------------------------------ stats
    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._bytes

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "entries": len(self._data),
                "bytes": self._bytes,
                "max_entries": self.max_entries,
                "max_bytes": self.max_bytes,
                "hits": self._hits,
                "misses": self._misses,
                "insertions": self._insertions,
                "evictions": self._evictions,
                "invalidations": self._invalidations,
                "oversize_rejects": self._oversize,
            }
