"""Pre-forked multi-process HTTP serving — the zero-copy snapshot plane.

One Python process tops out well below the in-process gateway because the
accept loop, the flush loop, response serialization and the kernel all
contend on a single GIL (``BENCH_http.json``).  Published snapshots are
immutable and — since the raw store layout (``SnapshotStore.open_table``)
— mmap-able, which is exactly the shape for horizontal scaling on one
box: N worker processes, each a full Gateway/scheduler/HTTP stack, all
serving read-only views of the *same* page-cache-resident tables.

Architecture::

    WorkerPool (parent / supervisor)
      ├─ anchor socket: SO_REUSEPORT, bound, NEVER listening — reserves
      │  the concrete port (also when the caller asked for port 0) while
      │  receiving no connections itself
      ├─ fork() × N  ─────────────►  worker process
      │                               ├─ own SO_REUSEPORT listening socket
      │                               │  (kernel load-balances accepts)
      │                               ├─ EmbeddingRegistry → ServingEngine
      │                               │  → Gateway → GatewayHTTPServer
      │                               │  (built AFTER fork: jax backends
      │                               │  must never cross a fork)
      │                               ├─ StoreWatcher: polls the store,
      │                               │  fires engine.invalidate when a
      │                               │  sealed version lands → publish
      │                               │  propagates to every worker
      │                               └─ stats dumper: periodic atomic
      │                                  snapshot to the state dir;
      │                                  /stats merges the siblings'
      └─ supervisor thread: per-pid waitpid(WNOHANG); restarts dead
         workers (SIGKILL mid-storm included) and records restarts

Where ``SO_REUSEPORT`` is unavailable the pool falls back to one
parent-bound listening socket that every fork inherits and accepts from
(contended accept, same correctness).

Fork safety: the parent may *import* jax modules but must never have run
a jax operation (XLA backend initialization is lazy and does not survive
``fork``).  Each worker initializes its own backend on first kernel
call.  ``launch.serve --workers`` therefore trains in a subprocess
before the pool starts.
"""
from __future__ import annotations

import json
import os
import signal
import socket
import sys
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

from ..core.metrics import LatencyHistogram

#: state-file names: worker-<idx>.json + supervisor.json
_WORKER_STATE = "worker-{idx}.json"
_SUPERVISOR_STATE = "supervisor.json"


def reuseport_available() -> bool:
    """True when this kernel supports SO_REUSEPORT load balancing."""
    if not hasattr(socket, "SO_REUSEPORT"):
        return False
    try:
        with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
        return True
    except OSError:
        return False


def make_listen_socket(host: str, port: int, *, reuseport: bool,
                       listen: bool = True,
                       backlog: int = 128) -> socket.socket:
    """A bound TCP socket; with ``listen=False`` it only reserves the
    port (the pool's anchor) and never receives connections."""
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    try:
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        if reuseport:
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
        s.bind((host, port))
        if listen:
            s.listen(backlog)
    except BaseException:
        s.close()
        raise
    return s


def _atomic_write_json(path: Path, payload: Dict[str, Any]) -> None:
    tmp = path.with_name(path.name + f".tmp{os.getpid()}")
    tmp.write_text(json.dumps(payload))
    os.replace(tmp, path)


# --------------------------------------------------------------------- #
#                         cross-worker stats merge                      #
# --------------------------------------------------------------------- #

def _merge_counter_dicts(a: Dict[str, Any], b: Dict[str, Any]) -> None:
    """Add b's numeric leaves into a (in place), recursing into dicts and
    unioning keys — the shape shared by scheduler/gateway/cache/http
    counter blocks."""
    for k, v in b.items():
        if isinstance(v, dict):
            _merge_counter_dicts(a.setdefault(k, {}), v)
        elif isinstance(v, bool) or not isinstance(v, (int, float)):
            a.setdefault(k, v)
        else:
            a[k] = a.get(k, 0) + v


def merge_stats_wires(wires: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold N workers' ``/stats`` wire bodies into one pool-wide body.

    Counters add; the fixed-bucket ``LatencyHistogram`` snapshots merge
    exactly by adding bucket counts (``LatencyHistogram.merge_snapshots``);
    per-route histogram maps union their routes.  ``cache.capacity`` adds
    too: it is the pool's total index budget."""
    if not wires:
        return {}
    sched: Dict[str, Any] = {}
    cache: Dict[str, Any] = {}
    gw: Dict[str, Any] = {}
    lat_routes: Dict[str, List[Dict[str, Any]]] = {}
    sched_lat: List[Dict[str, Any]] = []
    for w in wires:
        s = dict(w.get("scheduler") or {})
        snap = s.pop("latency_ms", None)
        if snap is not None:
            sched_lat.append(snap)
        _merge_counter_dicts(sched, s)
        _merge_counter_dicts(cache, w.get("cache") or {})
        _merge_counter_dicts(gw, w.get("gateway") or {})
        for route, snap in (w.get("latency") or {}).items():
            lat_routes.setdefault(route, []).append(snap)
    if sched_lat:
        sched["latency_ms"] = LatencyHistogram.merge_snapshots(sched_lat)
    return {
        "type": "stats_response",
        "scheduler": sched,
        "cache": cache,
        "gateway": gw,
        "latency": {route: LatencyHistogram.merge_snapshots(snaps)
                    for route, snaps in sorted(lat_routes.items())},
    }


def _read_worker_states(state_dir: Path,
                        skip_idx: Optional[int] = None
                        ) -> List[Dict[str, Any]]:
    out = []
    for p in sorted(state_dir.glob("worker-*.json")):
        try:
            state = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue                    # mid-replace or torn — skip
        if skip_idx is not None and state.get("idx") == skip_idx:
            continue
        out.append(state)
    return out


# --------------------------------------------------------------------- #
#                            store watcher                              #
# --------------------------------------------------------------------- #

class StoreWatcher:
    """Publish→invalidate propagation for processes that don't run the
    updater: polls the snapshot store and fires ``engine.invalidate``
    when a new version becomes adoptable.

    A version is adoptable when it is *sealed* (the updater's
    ``registry.seal`` after all models are on disk); for ontologies with
    no seal markers at all (pre-seal stores, hand-published registries)
    the newest version with at least one complete model — metadata.json
    present — is adopted instead.  Polling cost is a couple of
    ``stat(2)`` calls per ontology per tick."""

    def __init__(self, engine, interval_s: float = 0.25):
        self.engine = engine
        self.interval_s = interval_s
        self._seen: Dict[str, Optional[str]] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        #: adoption counter (exposed in worker state dumps)
        self.adoptions = 0
        # baseline: don't fire for what is already current at start
        for ont in self._store().ontologies():
            self._seen[ont] = self._candidate(ont)

    def _store(self):
        return self.engine.registry.store

    def _candidate(self, ontology: str) -> Optional[str]:
        store = self._store()
        sealed = store.sealed_versions(ontology)
        if sealed:
            return sealed[-1]
        for v in reversed(store.versions(ontology)):
            for m in store.models(ontology, v):
                if (store._dir(ontology, v, m) / "metadata.json").exists():
                    return v
        return None

    def poll_once(self) -> List[str]:
        """One scan; returns the ontologies whose pointer moved."""
        moved = []
        for ont in self._store().ontologies():
            v = self._candidate(ont)
            if v is not None and v != self._seen.get(ont):
                self.engine.invalidate(ont, v)
                self._seen[ont] = v
                self.adoptions += 1
                moved.append(ont)
        return moved

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.poll_once()
            except Exception:
                pass        # a torn half-written dir must not kill the loop

    def start(self) -> "StoreWatcher":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="store-watcher", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


# --------------------------------------------------------------------- #
#                            worker process                             #
# --------------------------------------------------------------------- #

def _worker_main(idx: int, registry_root: str, host: str, port: int,
                 state_dir: Path, *, inherited: Optional[socket.socket],
                 max_batch: int, flush_after_ms: float,
                 cache_capacity: int, watch_interval_s: float,
                 stats_interval_s: float,
                 max_pending: Optional[int] = None,
                 result_cache_entries: int = 4096,
                 result_cache_bytes: int = 32 << 20,
                 max_jobs_queued: int = 8) -> None:
    """Body of one worker process (runs post-fork; exits via os._exit).

    Builds the full serving stack from scratch — registry, engine,
    gateway, HTTP server — because nothing jax-backed may cross the
    fork.  The embedding tables themselves arrive by mmap, so "from
    scratch" costs an open+map, not a copy."""
    # the child must not run the parent's signal handlers
    signal.signal(signal.SIGINT, signal.SIG_IGN)

    from ..core.registry import EmbeddingRegistry
    from ..core.serving import ServingEngine
    from .gateway import Gateway
    from .http import GatewayHTTPServer

    registry = EmbeddingRegistry(registry_root)
    engine = ServingEngine(registry, cache_capacity=cache_capacity)
    # jobs_state_dir is shared across the pool: each worker's JobManager
    # mirrors its jobs there, so any worker can answer status/result/
    # cancel for a job pinned to a sibling — and report a SIGKILL'd
    # sibling's in-flight jobs as FAILED (the orphan rule)
    gw = Gateway(engine, max_batch=max_batch, flush_after_ms=flush_after_ms,
                 max_pending=max_pending,
                 result_cache_entries=result_cache_entries,
                 result_cache_bytes=result_cache_bytes,
                 max_jobs_queued=max_jobs_queued,
                 jobs_state_dir=state_dir / "jobs")

    if inherited is not None:
        sock = inherited                      # fallback: contended accept
    else:
        sock = make_listen_socket(host, port, reuseport=True)

    def stats_hook(wire: Dict[str, Any]) -> Dict[str, Any]:
        siblings = _read_worker_states(state_dir, skip_idx=idx)
        merged = merge_stats_wires(
            [wire] + [s["stats"] for s in siblings if s.get("stats")])
        http_counts: Dict[str, Any] = {}
        # locked accessor: copying the live dict races request threads
        _merge_counter_dicts(http_counts, server.http_counts())
        for s in siblings:
            _merge_counter_dicts(http_counts, s.get("http") or {})
        # 304 latency histograms merge by bucket-adding snapshots —
        # explicitly, never through _merge_counter_dicts (it would keep
        # the first worker's bucket list and drop the rest)
        nm_snaps = [server.not_modified_latency.snapshot()]
        for s in siblings:
            snap = (s.get("http_latency") or {}).get("not_modified")
            if snap:
                nm_snaps.append(snap)
        http_counts["latency_ms"] = {
            "not_modified": LatencyHistogram.merge_snapshots(nm_snaps)}
        sup: Dict[str, Any] = {}
        try:
            sup = json.loads((state_dir / _SUPERVISOR_STATE).read_text())
        except (OSError, json.JSONDecodeError):
            pass
        merged["workers"] = {
            "count": 1 + len(siblings),
            "pids": sorted([os.getpid()] + [s["pid"] for s in siblings
                                            if s.get("pid")]),
            "restarts": sup.get("restarts", 0),
            "http": http_counts,
        }
        return merged

    server = GatewayHTTPServer(gw, (host, port), sock=sock,
                               stats_hook=stats_hook)
    watcher = StoreWatcher(engine, interval_s=watch_interval_s).start()

    def dump_state() -> None:
        # /stats through gw.handle would inflate the request counters the
        # dump is reporting — snapshot through the handler directly
        from .schema import StatsRequest, to_wire
        _atomic_write_json(state_dir / _WORKER_STATE.format(idx=idx), {
            "idx": idx, "pid": os.getpid(), "port": server.port,
            "ts": time.time(), "adoptions": watcher.adoptions,
            "http": server.http_counts(),
            "http_latency": {
                "not_modified": server.not_modified_latency.snapshot()},
            "stats": to_wire(gw._handle_stats(StatsRequest())),
        })

    stop_dumping = threading.Event()
    parent_pid = os.getppid()

    def dump_loop() -> None:
        while not stop_dumping.wait(stats_interval_s):
            try:
                dump_state()
            except Exception:
                # a torn sibling state file or a full disk must not kill
                # the dump loop — the next interval retries
                pass
            # orphan guard: if the supervisor was SIGKILLed (a crashed
            # driver, a shell timeout), nothing will ever reap or stop
            # this worker — shut down instead of serving forever
            if os.getppid() != parent_pid:
                threading.Thread(target=server.shutdown,
                                 daemon=True).start()
                return

    dump_state()
    threading.Thread(target=dump_loop, name="stats-dump",
                     daemon=True).start()

    def on_term(signum, frame):
        # shutdown() blocks until serve_forever exits — it must not run
        # on the thread serve_forever occupies (signals land on main)
        threading.Thread(target=server.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, on_term)

    try:
        server.serve_forever()
    except Exception:
        # an accept-loop crash still falls through to the cleanup below;
        # the supervisor sees the exit and restarts the worker
        pass
    finally:
        stop_dumping.set()
        watcher.stop()
        try:
            dump_state()                      # final counters for mergers
        except Exception:
            # best-effort: losing the final counter dump only understates
            # the pool-merged /stats, never blocks worker exit
            pass
        try:
            server.server_close()
            gw.close()
        except Exception:
            # best-effort close on the way into os._exit — the OS reaps
            # the socket and threads regardless
            pass
        os._exit(0)


# --------------------------------------------------------------------- #
#                         the pool / supervisor                         #
# --------------------------------------------------------------------- #

class WorkerPool:
    """N pre-forked HTTP serving workers over one snapshot store.

    The parent never serves traffic: it reserves the port, forks, then
    supervises — a worker that dies (crash, SIGKILL) is reaped via
    per-pid ``waitpid(WNOHANG)`` (never ``waitpid(-1)``, which would
    steal unrelated children from an embedding process) and replaced
    within one supervision tick.  Connections sitting in a dead worker's
    accept queue are lost — the client retries and the kernel routes the
    new connection to a live worker; that is the "at most one retryable
    error" contract.
    """

    def __init__(self, registry_root: str | Path, port: int = 0,
                 host: str = "127.0.0.1", workers: int = 2, *,
                 max_batch: int = 64, flush_after_ms: float = 2.0,
                 cache_capacity: int = 8,
                 max_pending: Optional[int] = None,
                 result_cache_entries: int = 4096,
                 result_cache_bytes: int = 32 << 20,
                 max_jobs_queued: int = 8,
                 state_dir: Optional[str | Path] = None,
                 use_reuseport: Optional[bool] = None,
                 watch_interval_s: float = 0.25,
                 stats_interval_s: float = 0.5,
                 restart: bool = True,
                 supervise_interval_s: float = 0.05):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.registry_root = str(registry_root)
        self.host = host
        self.requested_port = port
        self.workers = workers
        self.max_batch = max_batch
        self.flush_after_ms = flush_after_ms
        self.cache_capacity = cache_capacity
        self.max_pending = max_pending
        self.result_cache_entries = result_cache_entries
        self.result_cache_bytes = result_cache_bytes
        self.max_jobs_queued = max_jobs_queued
        self.restart = restart
        self.watch_interval_s = watch_interval_s
        self.stats_interval_s = stats_interval_s
        self.supervise_interval_s = supervise_interval_s
        self.reuseport = (reuseport_available() if use_reuseport is None
                          else use_reuseport)
        self.state_dir = Path(state_dir) if state_dir is not None else Path(
            tempfile.mkdtemp(prefix="biokg-workers-"))
        self.state_dir.mkdir(parents=True, exist_ok=True)
        self._anchor: Optional[socket.socket] = None
        self._pids: Dict[int, int] = {}       # idx -> pid
        self.restarts = 0
        self._stopping = False
        self._lock = threading.Lock()
        self._supervisor: Optional[threading.Thread] = None
        self.port: Optional[int] = None

    # ----------------------------- lifecycle --------------------------- #
    def start(self) -> "WorkerPool":
        if self._anchor is not None:
            return self
        # warm sys.modules before forking: children then never touch the
        # import machinery (whose lock another parent thread could hold at
        # fork time). Importing jax *modules* here is fork-safe — only
        # backend initialization (first jax op) is not, and nothing below
        # runs one.
        from ..core.registry import EmbeddingRegistry      # noqa: F401
        from ..core.serving import ServingEngine           # noqa: F401
        from .gateway import Gateway                       # noqa: F401
        from .http import GatewayHTTPServer                # noqa: F401
        from .schema import StatsRequest, to_wire          # noqa: F401
        if self.reuseport:
            # bound but never listening: reserves the concrete port (incl.
            # resolving port 0) yet receives no connections — every accept
            # goes to a worker's own listening socket
            self._anchor = make_listen_socket(
                self.host, self.requested_port, reuseport=True, listen=False)
        else:
            # fallback: one parent-bound listener every fork inherits
            self._anchor = make_listen_socket(
                self.host, self.requested_port, reuseport=False, listen=True)
        self.port = self._anchor.getsockname()[1]
        self._stopping = False
        for idx in range(self.workers):
            self._spawn(idx)
        self._write_supervisor_state()
        self._supervisor = threading.Thread(
            target=self._supervise, name="worker-supervisor", daemon=True)
        self._supervisor.start()
        return self

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def pids(self) -> List[int]:
        with self._lock:
            return sorted(self._pids.values())

    def _spawn(self, idx: int) -> int:
        pid = os.fork()
        if pid == 0:
            try:
                if self.reuseport and self._anchor is not None:
                    # the child serves from its own REUSEPORT socket; the
                    # inherited anchor fd is dead weight
                    self._anchor.close()
                _worker_main(
                    idx, self.registry_root, self.host, int(self.port),
                    self.state_dir,
                    inherited=None if self.reuseport else self._anchor,
                    max_batch=self.max_batch,
                    flush_after_ms=self.flush_after_ms,
                    cache_capacity=self.cache_capacity,
                    watch_interval_s=self.watch_interval_s,
                    stats_interval_s=self.stats_interval_s,
                    max_pending=self.max_pending,
                    result_cache_entries=self.result_cache_entries,
                    result_cache_bytes=self.result_cache_bytes,
                    max_jobs_queued=self.max_jobs_queued)
            finally:
                # _worker_main exits via its own os._exit(0); reaching
                # here means it raised before serving
                os._exit(1)
        with self._lock:
            self._pids[idx] = pid
        return pid

    def _write_supervisor_state(self) -> None:
        with self._lock:
            state = {"pid": os.getpid(), "port": self.port,
                     "workers": dict(self._pids), "restarts": self.restarts,
                     "reuseport": self.reuseport, "ts": time.time()}
        try:
            _atomic_write_json(self.state_dir / _SUPERVISOR_STATE, state)
        except OSError:
            pass

    def _supervise(self) -> None:
        while True:
            if self._stopping:
                return
            for idx, pid in list(self._pids.items()):
                try:
                    done, _ = os.waitpid(pid, os.WNOHANG)
                except ChildProcessError:
                    done = pid                # already reaped elsewhere
                if done and not self._stopping:
                    if not self.restart:
                        with self._lock:
                            self._pids.pop(idx, None)
                        continue
                    self._spawn(idx)
                    with self._lock:
                        self.restarts += 1
                    self._write_supervisor_state()
            time.sleep(self.supervise_interval_s)

    def wait_ready(self, timeout_s: float = 30.0) -> None:
        """Block until the pool answers /health over a real socket."""
        import urllib.request
        deadline = time.monotonic() + timeout_s
        last: Optional[Exception] = None
        while time.monotonic() < deadline:
            try:
                with urllib.request.urlopen(
                        f"{self.url}/health", timeout=2.0) as resp:
                    if resp.status == 200:
                        return
            except Exception as e:
                last = e
            time.sleep(0.05)
        raise TimeoutError(
            f"worker pool not serving on {self.url} after {timeout_s}s "
            f"(last error: {last})")

    def kill_one(self, sig: int = signal.SIGKILL) -> int:
        """Kill one worker (crash-drill helper); returns its pid."""
        pid = self.pids()[0]
        os.kill(pid, sig)
        return pid

    def stop(self, timeout_s: float = 10.0) -> None:
        self._stopping = True
        if self._supervisor is not None:
            self._supervisor.join(timeout=timeout_s)
            self._supervisor = None
        with self._lock:
            pids = dict(self._pids)
            self._pids.clear()
        for pid in pids.values():
            try:
                os.kill(pid, signal.SIGTERM)
            except ProcessLookupError:
                pass
        deadline = time.monotonic() + timeout_s
        for pid in pids.values():
            while time.monotonic() < deadline:
                try:
                    done, _ = os.waitpid(pid, os.WNOHANG)
                except ChildProcessError:
                    break
                if done:
                    break
                time.sleep(0.02)
            else:
                try:
                    os.kill(pid, signal.SIGKILL)
                    os.waitpid(pid, 0)
                except (ProcessLookupError, ChildProcessError):
                    pass
        if self._anchor is not None:
            self._anchor.close()
            self._anchor = None

    def __enter__(self) -> "WorkerPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# --------------------------------------------------------------------- #
#                                  CLI                                  #
# --------------------------------------------------------------------- #

def main(argv: Optional[List[str]] = None) -> None:
    """``python -m repro.api.workers --registry R --port P --workers N``

    Serves an existing registry (publish first — e.g. via
    ``launch.serve`` or a bench script) and prints one ``READY`` line
    once /health answers, so drivers can wait on stdout.  The process is
    driver-attached by design: if the launching process dies without
    stopping it (SIGKILL, shell timeout), the pool notices the reparent
    and shuts itself down rather than leak forever — daemonize via
    ``launch.serve --workers`` if you want a standalone service."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--registry", required=True)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--flush-after-ms", type=float, default=2.0)
    ap.add_argument("--state-dir", default=None)
    ap.add_argument("--watch-interval-ms", type=float, default=250.0)
    ap.add_argument("--stats-interval-ms", type=float, default=500.0)
    ap.add_argument("--max-pending", type=int, default=None,
                    help="per-worker scheduler intake bound; past it "
                         "submissions fast-reject with HTTP 429")
    ap.add_argument("--cache-entries", type=int, default=4096,
                    help="result-cache entry bound per worker (0 disables)")
    ap.add_argument("--cache-bytes", type=int, default=32 << 20,
                    help="result-cache byte bound per worker (0 disables)")
    ap.add_argument("--max-jobs-queued", type=int, default=8,
                    help="per-worker batch-job queue bound; past it "
                         "submissions fast-reject with HTTP 429")
    ap.add_argument("--no-reuseport", action="store_true",
                    help="force the inherited-listener fallback")
    args = ap.parse_args(argv)

    pool = WorkerPool(
        args.registry, port=args.port, host=args.host, workers=args.workers,
        max_batch=args.max_batch, flush_after_ms=args.flush_after_ms,
        max_pending=args.max_pending,
        result_cache_entries=args.cache_entries,
        result_cache_bytes=args.cache_bytes,
        max_jobs_queued=args.max_jobs_queued,
        state_dir=args.state_dir,
        use_reuseport=False if args.no_reuseport else None,
        watch_interval_s=args.watch_interval_ms / 1e3,
        stats_interval_s=args.stats_interval_ms / 1e3)
    pool.start()
    try:
        pool.wait_ready()
    except TimeoutError as e:
        print(f"[workers] {e}", file=sys.stderr)
        pool.stop()
        raise SystemExit(1)
    print(f"READY port={pool.port} pids={','.join(map(str, pool.pids()))} "
          f"reuseport={int(pool.reuseport)} state_dir={pool.state_dir}",
          flush=True)

    stop = threading.Event()

    def on_signal(signum, frame):
        stop.set()

    signal.signal(signal.SIGTERM, on_signal)
    signal.signal(signal.SIGINT, on_signal)
    parent_pid = os.getppid()
    while not stop.is_set():
        stop.wait(0.2)
        # orphan guard: the launching driver died without stopping us
        # (SIGKILL, shell timeout) — take the pool down with it
        if os.getppid() != parent_pid:
            break
    pool.stop()
    try:
        print("[workers] stopped", flush=True)
    except OSError:
        pass            # driver died first: stdout pipe is already gone


if __name__ == "__main__":
    main()
