"""HTTP service layer over the gateway — the paper's actual web API.

Stdlib-only (``http.server.ThreadingHTTPServer``; the container adds no
deps): URL paths map 1:1 onto the existing ``Gateway.handle(route,
payload)`` route table, so the HTTP surface *is* the v1 wire schema —
a body served over a socket is byte-for-byte the dict ``handle``
returns in-process, and ``ApiError.status``/``code`` become the real
HTTP status line plus a structured JSON error body.

Transport semantics added on top of the gateway (and only transport
semantics — nothing here reaches past ``Gateway``'s public surface):

* **GET + query strings** — ``GET /sim/go/transe?a=GO:1&b=GO:2``.
  Query values are strings; they are coerced to the matched request
  dataclass's field types (int/bool) before dispatch, so GET and POST
  hit identical validation. ``POST`` takes the payload as a JSON body;
  query params on a POST URL merge into it (they are part of the
  resource identity — caches key on the full URL), and a body/query
  disagreement is a 400.
* **keep-alive** — HTTP/1.1 with correct framing (Content-Length or
  chunked), so a client connection serves many requests; the
  ``ThreadingHTTPServer`` gives each connection its own thread and the
  shared ``BatchScheduler`` coalesces across all of them.
* **ETag / If-None-Match** — every download page carries a strong ETag
  keyed ``(ontology, model, version, offset, limit)`` (pinned pages are
  immutable). A conditional re-fetch whose ETag matches is answered
  ``304 Not Modified`` *before* the gateway runs: no kernel, no index
  build, no download-route counter increment.
* **streaming download** — ``GET /download/{ont}/{model}?stream=true``
  answers ``Transfer-Encoding: chunked``, walking the gateway's cursor
  pages (pinned to the first page's version) and emitting the paper's
  ``{class: vector}`` JSON object one page-sized chunk at a time — the
  full body of a >100k-class ontology is never materialized.
* **batch-job results** — ``GET /jobs/{id}/result`` rides the same
  cursor machinery: pages of a DONE job carry strong ETags (304-able —
  a finished job's rows are immutable), and ``?stream=true`` chunks the
  full row set as one JSON array, one page in memory at a time.
* **latency histograms** — requests dispatch through ``Gateway._run``,
  so ``/stats`` over HTTP reports the same per-route histograms as the
  in-process gateway, now including this transport's traffic.

Usage::

    server = serve_http(gateway, port=8080)       # daemon thread
    ...                                           # curl away
    server.close()

or ``python -m repro.launch.serve --http 8080`` for a full service.
"""
from __future__ import annotations

import dataclasses
import json
import math
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional, Tuple
from urllib.parse import parse_qsl, urlsplit

from ..core.metrics import LatencyHistogram
from .gateway import API_VERSION, Gateway, download_etag, job_etag
from .schema import ApiError, DownloadRequest, JobResultRequest

_TRUE = frozenset(("1", "true", "yes", "on"))
_FALSE = frozenset(("0", "false", "no", "off"))

#: download defaults come from the schema, not a re-typed literal — a
#: drifted copy here would silently kill the 304 fast path (the ETag is
#: keyed on the effective limit)
_DOWNLOAD_DEFAULTS = {f.name: f.default
                      for f in dataclasses.fields(DownloadRequest)}
_JOB_RESULT_DEFAULTS = {f.name: f.default
                        for f in dataclasses.fields(JobResultRequest)}

#: routes whose responses are paged cursors: they accept the transport
#: `stream` flag and carry a strong ETag on every page
_PAGED_ROUTES = frozenset(("download", "job-result"))


def _parse_bool(raw) -> Any:
    """Query-string boolean; non-boolean text passes through so the
    schema boundary rejects it with a structured BAD_REQUEST."""
    if isinstance(raw, bool):
        return raw
    if isinstance(raw, str):
        if raw.lower() in _TRUE:
            return True
        if raw.lower() in _FALSE:
            return False
    return raw


#: per-request-class field->type-string maps (constant per class; the
#: hot path must not rebuild them per request)
_FIELD_TYPES: Dict[type, Dict[str, str]] = {}


def coerce_query_params(cls, raw: Dict[str, str]) -> Dict[str, Any]:
    """Coerce query-string values (always strings) to the matched
    request dataclass's field types, so GET requests go through exactly
    the same boundary validation as typed/POST payloads. Values that
    don't parse pass through unchanged — the schema layer turns them
    into structured BAD_REQUEST errors instead of a transport 500."""
    types = _FIELD_TYPES.get(cls)
    if types is None:
        types = {f.name: str(f.type) for f in dataclasses.fields(cls)}
        _FIELD_TYPES[cls] = types
    out: Dict[str, Any] = {}
    for name, value in raw.items():
        t = types.get(name, "str")
        if "bool" in t:
            out[name] = _parse_bool(value)
        elif "int" in t:
            try:
                out[name] = int(value)
            except (TypeError, ValueError):
                out[name] = value
        else:
            out[name] = value
    return out


def _params_dict(query: str):
    """Query string -> dict, surfacing conflicting duplicate keys
    (?a=x&a=y) instead of silently keeping the last — the same
    no-silent-winner rule applied to body/query and payload/route
    conflicts. Returns (params, conflicting_keys)."""
    out: Dict[str, str] = {}
    dup = set()
    for k, v in parse_qsl(query, keep_blank_values=True):
        if k in out and out[k] != v:
            dup.add(k)
        out[k] = v
    return out, sorted(dup)


def _etag_matches(if_none_match: str, etag: str) -> bool:
    """RFC 7232 weak comparison over an If-None-Match header list."""
    if if_none_match.strip() == "*":
        return True
    for candidate in if_none_match.split(","):
        candidate = candidate.strip()
        if candidate.startswith("W/"):
            candidate = candidate[2:]
        if candidate == etag:
            return True
    return False


class GatewayHTTPHandler(BaseHTTPRequestHandler):
    """One request — GET (query-string payload) or POST (JSON body) —
    dispatched to ``server.gateway.handle``."""

    protocol_version = "HTTP/1.1"          # keep-alive by default
    server_version = f"BioKGvec2go/{API_VERSION}"
    #: write-buffer the response so status line + headers + body leave in
    #: one send(); with Nagle off (below) small replies never sit behind
    #: a delayed-ACK stall
    wbufsize = 64 * 1024
    disable_nagle_algorithm = True

    # quiet by default: a 16-client benchmark must not serialize on
    # stderr writes (set server.verbose_log = True to re-enable)
    def log_message(self, fmt, *args):
        if getattr(self.server, "verbose_log", False):
            super().log_message(fmt, *args)

    #: (unix_second, formatted) — strftime per response is measurable at
    #: micro-batch request rates; one render per second is plenty
    _date_cache = (0, "")

    def date_time_string(self, timestamp=None):
        if timestamp is not None:
            return super().date_time_string(timestamp)
        now = int(time.time())
        cached = GatewayHTTPHandler._date_cache
        if cached[0] != now:
            cached = (now, super().date_time_string(now))
            GatewayHTTPHandler._date_cache = cached
        return cached[1]

    # ------------------------------ verbs ------------------------------ #
    def do_GET(self) -> None:
        self.server._count("requests")
        split = urlsplit(self.path)
        raw, dup = _params_dict(split.query)
        if dup:
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"conflicting duplicate query parameter(s): "
                f"{', '.join(dup)}",
                details={"conflicting_fields": dup}))
        self._dispatch(split.path, raw, coerce=True)

    #: request bodies past this are refused outright (the largest legal
    #: payload is a download request — a few hundred bytes)
    max_body_bytes = 1 << 20

    def do_POST(self) -> None:
        self.server._count("requests")
        split = urlsplit(self.path)
        te = self.headers.get("Transfer-Encoding")
        if te:
            # a chunked request body would sit unread in the pipe and
            # desync every later request on this keep-alive connection —
            # refuse it loudly and drop the connection
            self.close_connection = True
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"encoded request bodies are not supported "
                f"(Transfer-Encoding: {te}); send Content-Length"))
        length = self.headers.get("Content-Length")
        try:
            n = int(length) if length is not None else 0
        except ValueError:
            n = -1
        if n < 0 or n > self.max_body_bytes:
            # unreadable framing: the body (if any) is still in the pipe,
            # so keep-alive would parse garbage — close after answering.
            # A negative length must never reach read(): read(-1) blocks
            # until the client hangs up.
            self.close_connection = True
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"missing, malformed or oversized Content-Length: "
                f"{length!r}"))
        body = self.rfile.read(n) if n else b""
        if not body:
            payload: Dict[str, Any] = {}
        else:
            try:
                payload = json.loads(body)
            except json.JSONDecodeError as e:
                return self._send_error(ApiError(
                    "BAD_REQUEST", f"request body is not valid JSON: {e}"))
        if not isinstance(payload, dict):
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"request body must be a JSON object, "
                f"got {type(payload).__name__}"))
        # query params on a POST URL (incl. the stream flag) are handled
        # by _dispatch: merged into the payload, conflicts rejected
        extra, dup = _params_dict(split.query)
        if dup:
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"conflicting duplicate query parameter(s): "
                f"{', '.join(dup)}",
                details={"conflicting_fields": dup}))
        self._dispatch(split.path, payload, coerce=False, extra=extra)

    # ---------------------------- dispatch ----------------------------- #
    def _dispatch(self, path: str, payload: Dict[str, Any],
                  coerce: bool, extra: Optional[Dict[str, str]] = None
                  ) -> None:
        gw: Gateway = self.server.gateway
        try:
            # match first: unknown paths 404 before any payload work, and
            # the matched request class drives query-string coercion
            try:
                name, cls, _handler, route_params = gw._match(path)
            except ApiError:
                name, cls, _handler, route_params = None, None, None, {}
            # `stream` is a transport flag on the paged routes (download,
            # job-result) only; on any other route it stays in the
            # payload so the schema rejects it exactly like the
            # in-process entry point would
            stream = False
            if name in _PAGED_ROUTES:
                flags = []
                if "stream" in payload:
                    flags.append(payload.pop("stream"))
                if extra and "stream" in extra:
                    flags.append(extra.pop("stream"))
                parsed_flags = []
                for raw in flags:
                    parsed = _parse_bool(raw)
                    if not isinstance(parsed, bool):
                        # a typo'd flag must fail loudly, not quietly
                        # serve one page where the client wanted a stream
                        return self._send_error(ApiError(
                            "BAD_REQUEST",
                            f"stream must be a boolean, got {raw!r}",
                            details={"field": "stream"}))
                    parsed_flags.append(parsed)
                if len(set(parsed_flags)) > 1:
                    # body and query disagreeing is a client error, the
                    # same rule every other field follows
                    return self._send_error(ApiError(
                        "BAD_REQUEST",
                        "query parameter(s) conflict with request body: "
                        "stream",
                        details={"conflicting_fields": ["stream"]}))
                stream = bool(parsed_flags and parsed_flags[0])
            if cls is not None and coerce:
                payload = coerce_query_params(cls, payload)
            if extra:
                # POST: query-string params are part of the resource
                # identity (caches key on the full URL) — merge them into
                # the body payload; a disagreement is a client error,
                # never a silent winner
                qp = coerce_query_params(cls, extra) if cls is not None \
                    else dict(extra)
                clash = sorted(k for k in qp
                               if k in payload and payload[k] != qp[k])
                if clash:
                    return self._send_error(ApiError(
                        "BAD_REQUEST",
                        f"query parameter(s) conflict with request body: "
                        f"{', '.join(clash)}",
                        details={"conflicting_fields": clash}))
                payload = {**qp, **payload}
            if name == "download":
                # 304 is defined only for GET/HEAD (RFC 9110): a POST
                # with a stored validator must execute, not short-circuit
                if not stream and self.command == "GET" \
                        and self._maybe_not_modified(gw, route_params,
                                                     payload):
                    return
                if stream:
                    return self._stream_download(gw, route_params, payload)
            elif name == "job-result":
                if not stream and self.command == "GET" \
                        and self._maybe_job_not_modified(gw, route_params,
                                                         payload):
                    return
                if stream:
                    return self._stream_job_result(gw, route_params, payload)
            match = (name, cls, _handler, route_params) if name else None
            wire = gw.handle(path, payload, match=match)
            if wire.get("type") == "stats_response":
                if self.server.stats_hook is not None:
                    # multi-process serving: the pool installs a hook that
                    # folds the sibling workers' counter/histogram
                    # snapshots into this worker's stats body
                    # (fixed-bucket histograms merge by adding counts)
                    try:
                        wire = self.server.stats_hook(wire) or wire
                    except Exception:
                        self.server._count("internal_errors")
                # transport-level block appended after any merge: 304s
                # and streams are answered before dispatch, so without
                # this they'd be invisible exactly when ETag traffic
                # makes "cheap hit" the common case. In a worker pool
                # this block is *this* worker's transport; the hook's
                # ["workers"]["http"] block carries the pool-wide merge.
                wire = {**wire, "http": self.server.http_snapshot()}
            status = wire.get("status", 200) if wire.get("type") == "error" \
                else 200
            headers: Tuple[Tuple[str, str], ...] = ()
            if wire.get("type") in ("download_page", "job_result_page") \
                    and wire.get("etag"):
                headers = (("ETag", wire["etag"]),)
            self._send_json(status, wire, headers)
        except (BrokenPipeError, ConnectionResetError):
            self.close_connection = True
        except Exception as e:                       # pragma: no cover
            self.server._count("internal_errors")
            try:
                self._send_error(ApiError("INTERNAL",
                                          f"http layer error: {e}"))
            except Exception:
                self.close_connection = True

    # ------------------------- conditional GET ------------------------- #
    def _maybe_not_modified(self, gw: Gateway, route_params: Dict[str, str],
                            payload: Dict[str, Any]) -> bool:
        """If-None-Match short circuit for download pages. Computes the
        expected ETag from the request coordinates alone — coordinate
        *existence* is validated through the gateway's cached metadata
        (version lists, latest pointer), so a 304 does zero kernel/index
        work and never increments the gateway's download route counter.
        Any validation failure falls through to the full path, which
        produces the proper structured 4xx — ETags are computable by
        anyone, so a matching validator must never vouch for
        coordinates the gateway would reject."""
        t0 = time.perf_counter()
        inm = self.headers.get("If-None-Match")
        if not inm or gw._closed:
            # a draining gateway must answer 503 everywhere — a 304 from
            # the shortcut would keep load balancers routing here
            return False
        # the shortcut must be at least as strict as the full path: an
        # unknown field, a payload/route clash, or any malformed value
        # falls through so the gateway produces its structured 4xx
        ontology = route_params.get("ontology")
        model = route_params.get("model")
        if set(payload) - set(_DOWNLOAD_DEFAULTS):
            return False               # unknown fields → full path 400s
        if payload.get("ontology", ontology) != ontology \
                or payload.get("model", model) != model:
            return False               # route conflict → full path 400s
        version = payload.get("version")
        offset = payload.get("offset", _DOWNLOAD_DEFAULTS["offset"])
        limit = payload.get("limit", _DOWNLOAD_DEFAULTS["limit"])
        if not (isinstance(ontology, str) and isinstance(model, str)
                and isinstance(offset, int) and isinstance(limit, int)
                and not isinstance(offset, bool)
                and not isinstance(limit, bool)
                and (version is None or isinstance(version, str))
                and limit >= 1 and offset >= 0):
            return False               # malformed → full path rejects it
        try:
            version = gw._resolve_coords(ontology, model, version)
        except Exception:
            return False               # unknown coords → full path 404s
        etag = download_etag(ontology, model, version, offset,
                             min(limit, gw.page_limit_max), limit)
        if not _etag_matches(inm, etag):
            return False
        self.server._count("not_modified")
        # 304s never reach Gateway._run, so they get their own transport
        # histogram — otherwise the cheapest responses in the system
        # would be the only ones with no latency record
        self.server._observe_304(time.perf_counter() - t0)
        self.send_response(304)
        self.send_header("ETag", etag)
        self.end_headers()             # 304 carries no body by definition
        return True

    def _maybe_job_not_modified(self, gw: Gateway,
                                route_params: Dict[str, str],
                                payload: Dict[str, Any]) -> bool:
        """If-None-Match short circuit for job-result pages. Same
        strictness contract as the download shortcut, plus one extra
        gate: the stored validator only vouches for a **DONE** job —
        a matching ETag presented while the job is still running (or
        cancelled/failed) falls through so the gateway produces its
        structured per-state error instead of a bogus 304."""
        t0 = time.perf_counter()
        inm = self.headers.get("If-None-Match")
        if not inm or gw._closed:
            return False
        job_id = route_params.get("job_id")
        if set(payload) - set(_JOB_RESULT_DEFAULTS):
            return False               # unknown fields → full path 400s
        if payload.get("job_id", job_id) != job_id:
            return False               # route conflict → full path 400s
        offset = payload.get("offset", _JOB_RESULT_DEFAULTS["offset"])
        limit = payload.get("limit", _JOB_RESULT_DEFAULTS["limit"])
        if not (isinstance(job_id, str) and job_id.strip()
                and isinstance(offset, int) and isinstance(limit, int)
                and not isinstance(offset, bool)
                and not isinstance(limit, bool)
                and limit >= 1 and offset >= 0):
            return False               # malformed → full path rejects it
        try:
            state = gw.jobs.status(job_id).get("state")
        except Exception:
            return False               # unknown job → full path 404s
        if state != "DONE":
            return False
        etag = job_etag(job_id, offset, min(limit, gw.page_limit_max),
                        limit)
        if not _etag_matches(inm, etag):
            return False
        self.server._count("not_modified")
        self.server._observe_304(time.perf_counter() - t0)
        self.send_response(304)
        self.send_header("ETag", etag)
        self.end_headers()
        return True

    # ------------------------- streaming download ---------------------- #
    def _stream_download(self, gw: Gateway, route_params: Dict[str, str],
                         payload: Dict[str, Any]) -> None:
        """Chunked ``{class: vector}`` stream over the gateway's cursor
        pages. ``offset``/``limit`` select rows ``[offset,
        offset+limit)`` exactly like the page endpoint, but the limits
        differ by design: with no ``limit`` the stream serves to the
        end of the table, and an explicit ``limit`` is not clamped by
        ``page_limit_max`` — streaming exists precisely to move the
        bodies the page cap refuses. The page size is the server's
        ``stream_page_rows`` knob. Every page after the first is pinned
        to the first page's version, so a release landing mid-stream
        cannot tear the body. Peak memory is one page of encoded rows,
        never the full table."""
        known = set(_DOWNLOAD_DEFAULTS)          # the schema's field set
        unknown = sorted(set(payload) - known)
        if unknown:
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"unknown field(s) for download stream: {', '.join(unknown)}",
                details={"unknown_fields": unknown}))
        # the same route-vs-payload conflict rule as _build_request: the
        # URL's coordinates win or the request fails, never a silent
        # payload override (a URL-keyed cache would store the wrong body)
        clash = sorted(k for k in route_params
                       if k in payload and payload[k] != route_params[k])
        if clash:
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"payload field(s) conflict with route: {', '.join(clash)}",
                details={"conflicting_fields": clash}))
        ontology = route_params.get("ontology")
        model = route_params.get("model")
        cap = payload.get("limit")
        if cap is not None and (isinstance(cap, bool)
                                or not isinstance(cap, int) or cap < 1):
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"limit must be an integer >= 1, got {cap!r}",
                details={"field": "limit"}))
        page_rows = self.server.stream_page_rows
        try:
            page = gw.download(
                ontology, model, version=payload.get("version"),
                offset=payload.get("offset", 0),
                limit=page_rows if cap is None else min(cap, page_rows))
        except ApiError as e:
            return self._send_error(e)
        self.server._count("streams")
        self.send_response(200)
        self.send_header("Content-Type", "application/json")
        self.send_header("Transfer-Encoding", "chunked")
        self.send_header("X-Bio-KGvec2go-Version", page.version)
        self.send_header("X-Bio-KGvec2go-Total", str(page.total))
        self.end_headers()
        try:
            self._write_chunk(b"{")
            first = True
            remaining = cap
            while True:
                rows = page.rows if remaining is None \
                    else page.rows[:remaining]
                parts = []
                for ident, vec in rows:
                    parts.append(("" if first else ", ")
                                 + json.dumps(ident) + ": " + json.dumps(vec))
                    first = False
                if parts:
                    self._write_chunk("".join(parts).encode("utf-8"))
                if remaining is not None:
                    remaining -= len(rows)
                    if remaining <= 0:
                        break
                if page.next_offset is None:
                    break
                page = gw.download(
                    ontology, model, version=page.version,
                    offset=page.next_offset,
                    limit=page_rows if remaining is None
                    else min(remaining, page_rows))
            self._write_chunk(b"}")
            self.wfile.write(b"0\r\n\r\n")           # chunked terminator
        except Exception:
            # headers are gone — the only honest signal left is a torn
            # chunked body, which every client treats as a failed fetch
            self.close_connection = True

    def _stream_job_result(self, gw: Gateway, route_params: Dict[str, str],
                           payload: Dict[str, Any]) -> None:
        """Chunked stream of a DONE job's result rows as one JSON array,
        walking the gateway's cursor pages — the bulk-analytics
        counterpart of ``_stream_download``. Same cursor semantics:
        ``offset`` starts the stream, an explicit ``limit`` caps total
        rows without the page clamp, peak memory is one page. Rows are
        immutable once the job is DONE, so no version pinning is needed;
        any non-DONE state surfaces as the gateway's structured error
        before headers go out."""
        known = set(_JOB_RESULT_DEFAULTS)
        unknown = sorted(set(payload) - known)
        if unknown:
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"unknown field(s) for job-result stream: "
                f"{', '.join(unknown)}",
                details={"unknown_fields": unknown}))
        clash = sorted(k for k in route_params
                       if k in payload and payload[k] != route_params[k])
        if clash:
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"payload field(s) conflict with route: {', '.join(clash)}",
                details={"conflicting_fields": clash}))
        job_id = route_params.get("job_id")
        cap = payload.get("limit")
        if cap is not None and (isinstance(cap, bool)
                                or not isinstance(cap, int) or cap < 1):
            return self._send_error(ApiError(
                "BAD_REQUEST",
                f"limit must be an integer >= 1, got {cap!r}",
                details={"field": "limit"}))
        page_rows = self.server.stream_page_rows
        try:
            page = gw.job_result(
                job_id, offset=payload.get("offset", 0),
                limit=page_rows if cap is None else min(cap, page_rows))
        except ApiError as e:
            return self._send_error(e)
        self.server._count("streams")
        self.send_response(200)
        self.send_header("Content-Type", "application/json")
        self.send_header("Transfer-Encoding", "chunked")
        self.send_header("X-Bio-KGvec2go-Job", page.job_id)
        self.send_header("X-Bio-KGvec2go-Kind", page.kind)
        self.send_header("X-Bio-KGvec2go-Total", str(page.total))
        self.end_headers()
        try:
            self._write_chunk(b"[")
            first = True
            remaining = cap
            while True:
                rows = page.rows if remaining is None \
                    else page.rows[:remaining]
                parts = []
                for row in rows:
                    parts.append(("" if first else ", ") + json.dumps(row))
                    first = False
                if parts:
                    self._write_chunk("".join(parts).encode("utf-8"))
                if remaining is not None:
                    remaining -= len(rows)
                    if remaining <= 0:
                        break
                if page.next_offset is None:
                    break
                page = gw.job_result(
                    job_id, offset=page.next_offset,
                    limit=page_rows if remaining is None
                    else min(remaining, page_rows))
            self._write_chunk(b"]")
            self.wfile.write(b"0\r\n\r\n")           # chunked terminator
        except Exception:
            self.close_connection = True

    def _write_chunk(self, data: bytes) -> None:
        if not data:
            return                     # empty chunk would terminate early
        self.server._observe_chunk(len(data))
        self.wfile.write(f"{len(data):X}\r\n".encode("ascii"))
        self.wfile.write(data)
        self.wfile.write(b"\r\n")

    # ----------------------------- replies ----------------------------- #
    #: error codes whose responses advise the client when to come back
    _RETRY_CODES = frozenset(("OVERLOADED", "SHUTTING_DOWN"))

    def _send_json(self, status: int, obj: Dict[str, Any],
                   headers: Tuple[Tuple[str, str], ...] = ()) -> None:
        if obj.get("type") == "error" and obj.get("code") in self._RETRY_CODES:
            # 429/503 carry Retry-After (RFC 6585 / RFC 9110): the
            # scheduler's reject details hold a sub-second hint derived
            # from the flush cadence; the header is whole seconds, so
            # round up and never advise less than 1
            retry = (obj.get("details") or {}).get("retry_after_s")
            try:
                secs = max(1, math.ceil(float(retry)))
            except (TypeError, ValueError):
                secs = 1
            headers = (*headers, ("Retry-After", str(secs)))
        body = json.dumps(obj).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        if self.close_connection:
            # tell the client (framing-hygiene 400s drop the connection;
            # without this header an HTTP/1.1 client would reuse it and
            # see a reset on its next request)
            self.send_header("Connection", "close")
        for k, v in headers:
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _send_error(self, e: ApiError) -> None:
        self._send_json(e.status, e.to_wire())


class GatewayHTTPServer(ThreadingHTTPServer):
    """Threaded HTTP server bound to one :class:`Gateway`.

    One daemon thread per live connection; all of them funnel into the
    gateway's shared scheduler, so concurrent HTTP clients coalesce into
    micro-batched kernel calls exactly like in-process threads do.
    """

    daemon_threads = True
    allow_reuse_address = True
    #: accept backlog: 16+ clients connecting in the same instant must
    #: not overflow the default backlog of 5 (a dropped SYN costs the
    #: client a ~1s retransmit — it dominated p99 in bench_http)
    request_queue_size = 128

    def __init__(self, gateway: Gateway,
                 address: Tuple[str, int] = ("127.0.0.1", 0), *,
                 stream_page_rows: int = 2048, verbose_log: bool = False,
                 sock: Optional[socket.socket] = None,
                 stats_hook: Optional[
                     Callable[[Dict[str, Any]], Dict[str, Any]]] = None):
        if sock is None:
            super().__init__(address, GatewayHTTPHandler)
        else:
            # adopt an externally-created listening socket (the worker
            # pool's SO_REUSEPORT socket, or a listener inherited across
            # fork): skip bind, keep the rest of the server machinery
            super().__init__(address, GatewayHTTPHandler,
                             bind_and_activate=False)
            self.socket.close()          # the unused fresh socket
            self.socket = sock
            self.server_address = sock.getsockname()
            host, port = self.server_address[:2]
            self.server_name = socket.getfqdn(host)
            self.server_port = port
            self.server_activate()       # listen() — idempotent
        self.gateway = gateway
        #: optional post-processor for /stats wire bodies (multi-process
        #: merge); data routes are never touched, so wire parity with the
        #: in-process gateway holds everywhere else
        self.stats_hook = stats_hook
        #: page size (rows) the streaming path requests per cursor step —
        #: the peak-memory bound of a streamed download
        self.stream_page_rows = stream_page_rows
        self.verbose_log = verbose_log
        self._stats_lock = threading.Lock()
        #: transport-level counters (the gateway never sees a 304)
        self.http_stats: Dict[str, int] = {
            "requests": 0, "not_modified": 0, "streams": 0,
            "internal_errors": 0, "max_chunk_bytes": 0}
        #: pre-dispatch 304 answer latency — these requests never reach
        #: the gateway's per-route histograms (satellite of the result
        #: cache work: cheap hits must still be observable)
        self.not_modified_latency = LatencyHistogram()
        self._thread: Optional[threading.Thread] = None
        #: set while serve_forever is on some thread's stack — close()
        #: must not call shutdown() otherwise (BaseServer.shutdown waits
        #: on an event only serve_forever sets: calling it when the
        #: accept loop never ran would block forever)
        self._serving = threading.Event()

    def serve_forever(self, *args, **kwargs) -> None:
        self._serving.set()
        try:
            super().serve_forever(*args, **kwargs)
        finally:
            self._serving.clear()

    # ------------------------------ stats ------------------------------ #
    def _count(self, key: str) -> None:
        with self._stats_lock:
            self.http_stats[key] += 1

    def _observe_chunk(self, nbytes: int) -> None:
        with self._stats_lock:
            if nbytes > self.http_stats["max_chunk_bytes"]:
                self.http_stats["max_chunk_bytes"] = nbytes

    def _observe_304(self, seconds: float) -> None:
        self.not_modified_latency.observe(seconds)

    def http_counts(self) -> Dict[str, int]:
        """Point-in-time copy of the transport counters.  The lock makes
        the snapshot consistent across counters — callers (the worker
        state dump, the pool-merged /stats) must use this instead of
        copying ``http_stats`` while request threads mutate it."""
        with self._stats_lock:
            return dict(self.http_stats)

    def http_snapshot(self) -> Dict[str, Any]:
        """Transport counters + 304 latency for /stats bodies (and the
        worker-pool state dumps — histograms merge across workers via
        ``LatencyHistogram.merge_snapshots``, never by naive dict-add)."""
        counts: Dict[str, Any] = self.http_counts()
        counts["latency_ms"] = {
            "not_modified": self.not_modified_latency.snapshot()}
        return counts

    # ---------------------------- lifecycle ---------------------------- #
    @property
    def port(self) -> int:
        return self.server_address[1]

    @property
    def url(self) -> str:
        host = self.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "GatewayHTTPServer":
        """Serve in a named daemon thread (idempotent)."""
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self.serve_forever, name="gateway-http", daemon=True)
            self._thread.start()
        return self

    def close(self, close_gateway: bool = False) -> None:
        """Stop accepting, join the serve thread, release the socket.
        Safe to call whether or not the accept loop ever ran. The
        gateway is left running unless ``close_gateway`` — it may be
        shared with in-process callers."""
        # shutdown() is only meaningful with a live accept loop; a
        # started thread counts (its serve_forever observes the shutdown
        # request on entry even if close() wins the startup race)
        if self._serving.is_set() or (
                self._thread is not None and self._thread.is_alive()):
            self.shutdown()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self.server_close()
        if close_gateway:
            self.gateway.close()

    def __enter__(self) -> "GatewayHTTPServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_http(gateway: Gateway, host: str = "127.0.0.1", port: int = 0, *,
               stream_page_rows: int = 2048, start: bool = True,
               verbose_log: bool = False,
               sock: Optional[socket.socket] = None,
               stats_hook=None) -> GatewayHTTPServer:
    """Stand up the HTTP front end over ``gateway``. ``port=0`` binds an
    ephemeral port (see ``server.port``/``server.url``). With ``start``
    (default) the accept loop runs in a daemon thread; pass
    ``start=False`` to drive ``serve_forever()`` yourself (e.g. the
    ``launch.serve --http`` foreground mode). ``sock`` adopts an
    externally-bound listener instead of binding (the worker pool's
    SO_REUSEPORT path); ``stats_hook`` post-processes /stats bodies
    (cross-worker merge)."""
    server = GatewayHTTPServer(gateway, (host, port),
                               stream_page_rows=stream_page_rows,
                               verbose_log=verbose_log, sock=sock,
                               stats_hook=stats_hook)
    if start:
        server.start()
    return server
