"""Async front end over the gateway — the PR 2 open item, closed.

``AsyncGateway`` exposes every gateway endpoint as an awaitable. The
similarity-shaped reads (``similarity`` / ``closest_concepts``) bridge
the scheduler's thread-resolved :class:`Ticket` into an
``asyncio.Future`` via ``Ticket.add_done_callback`` +
``loop.call_soon_threadsafe`` — the same loop-safe pattern as
``asyncio.wrap_future``, with zero polling and no executor thread
parked on a blocking ``result()``. Direct reads (download,
autocomplete, ops endpoints) run in the default executor so the event
loop never blocks on index builds or disk metadata.

    gw = Gateway(engine, flush_after_ms=2.0)
    ag = AsyncGateway(gw)
    a, b = await asyncio.gather(
        ag.closest_concepts("go", "transe", "GO:0000001"),
        ag.similarity("go", "transe", "GO:0000001", "GO:0000002"))

Concurrent coroutines coalesce exactly like concurrent threads do: each
``await`` submits a ticket and yields; the flush loop drains the queue
as one micro-batched kernel call.
"""
from __future__ import annotations

import asyncio
from typing import Any, Dict, List, Optional, Sequence

from ..core.serving import SchedulerError, Ticket
from .gateway import TICKET_ROUTES, Gateway, _error_from_ticket
from .schema import (ApiError, AutocompleteResponse, ClosestConceptsRequest,
                     ClosestConceptsResponse, DownloadPage, HealthResponse,
                     JobListResponse, JobResultPage, JobStatusResponse,
                     LineageResponse, SimilarityRequest, SimilarityResponse,
                     StatsResponse, VectorResponse, VersionsResponse)


def ticket_future(ticket: Ticket,
                  loop: Optional[asyncio.AbstractEventLoop] = None
                  ) -> "asyncio.Future":
    """Bridge a scheduler Ticket to an asyncio Future on ``loop``
    (default: the running loop). Resolution happens on the flush-loop
    thread; the callback posts the transition through
    ``call_soon_threadsafe``, which is the only loop-safe way in."""
    loop = loop or asyncio.get_running_loop()
    fut = loop.create_future()

    def on_done(t: Ticket) -> None:
        # compute the outcome here on the resolver thread; the loop
        # callback only settles the future (keeps flush-loop time and
        # event-loop time both minimal)
        try:
            outcome, is_err = t.result(timeout=0), False
        except SchedulerError as e:
            outcome, is_err = _error_from_ticket(e), True
        except Exception as e:                     # pragma: no cover
            outcome, is_err = e, True

        def settle() -> None:
            if fut.cancelled() or fut.done():      # timed out / cancelled
                return
            if is_err:
                fut.set_exception(outcome)
            else:
                fut.set_result(outcome)
        # shutdown race: the ticket may resolve (on the flush thread)
        # after the client's event loop has already closed — e.g. a
        # drain at interpreter exit, or a test tearing the loop down
        # while a straggler flush lands. call_soon_threadsafe raises
        # RuntimeError("Event loop is closed") then; without the guard
        # that escapes into Ticket._fire_callbacks on the flush thread.
        # The is_closed() pre-check skips the common case cheaply and
        # the except covers the close-after-check race; the future is
        # dead with its loop either way, so dropping the result is the
        # only correct outcome.
        if loop.is_closed():
            return
        try:
            loop.call_soon_threadsafe(settle)
        except RuntimeError:
            pass                                   # closed under our feet

    ticket.add_done_callback(on_done)
    return fut


class AsyncGateway:
    """Awaitable wrapper over a :class:`Gateway`.

    Requires the scheduler's flush loop (there is no caller thread to
    drive a synchronous ``flush()``); if it isn't running yet it is
    started with ``flush_after_ms``.
    """

    def __init__(self, gateway: Gateway, *, flush_after_ms: float = 2.0):
        self.gateway = gateway
        #: async implementations of every ticket-routed endpoint; the
        #: coverage assert makes a new TICKET_ROUTES entry fail loudly
        #: here instead of silently degrading to an executor thread
        #: parked on ticket.result()
        self._ticket_impls = {"sim": self._handle_sim_wire,
                              "closest-concepts": self._handle_closest_wire}
        missing = set(TICKET_ROUTES) - set(self._ticket_impls)
        assert not missing, f"no async impl for ticket routes: {missing}"
        if not gateway.scheduler.running():
            gateway.scheduler.start(flush_after_ms=flush_after_ms)

    def close(self) -> None:
        self.gateway.close()

    async def __aenter__(self) -> "AsyncGateway":
        return self

    async def __aexit__(self, *exc) -> None:
        await asyncio.get_running_loop().run_in_executor(None, self.close)

    # ------------------- scheduler-routed (ticket) --------------------- #
    async def _settle(self, ticket: Ticket,
                      timeout: Optional[float] = None):
        loop = asyncio.get_running_loop()
        fut = ticket_future(ticket, loop)
        if timeout is None:
            timeout = self.gateway.timeout_s

        def expire() -> None:
            if not fut.done():
                fut.set_exception(ApiError(
                    "TIMEOUT",
                    f"request unresolved after {timeout}s",
                    details={"ticket": ticket.id}))

        # a call_later timer instead of asyncio.wait_for: wait_for wraps
        # every await in an extra Task, which is measurable overhead at
        # micro-batch request rates (see bench_gateway)
        timer = loop.call_later(timeout, expire)
        try:
            return await fut
        finally:
            timer.cancel()

    async def _settle_counted(self, ticket: Ticket,
                              timeout: Optional[float] = None):
        """_settle + gateway error accounting: resolution-time failures
        happen outside the _run wrapper here (the submit returned before
        the ticket resolved), so count them explicitly — /stats must not
        undercount under async traffic."""
        try:
            return await self._settle(ticket, timeout=timeout)
        except ApiError as e:
            self.gateway._count_error(e)
            raise

    async def similarity(self, ontology: str, model: str, a: str, b: str, *,
                         fuzzy: bool = False,
                         version: Optional[str] = None) -> SimilarityResponse:
        gw = self.gateway
        req = SimilarityRequest(ontology, model, a, b, fuzzy, version)
        staged = gw._run("sim", req, gw._submit_similarity)
        if isinstance(staged, SimilarityResponse):
            return staged                      # result-cache hit at staging
        score = await self._settle_counted(
            staged, timeout=gw._route_budget("sim"))
        resp = gw._similarity_response(req, staged, score)
        gw._cache_store(gw._cache_key("sim", req), resp)
        return resp

    async def closest_concepts(self, ontology: str, model: str, query: str, *,
                               k: int = 10, fuzzy: bool = False,
                               version: Optional[str] = None
                               ) -> ClosestConceptsResponse:
        gw = self.gateway
        req = ClosestConceptsRequest(ontology, model, query, k, fuzzy, version)
        staged = gw._run("closest-concepts", req, gw._submit_closest)
        if isinstance(staged, ClosestConceptsResponse):
            return staged                      # result-cache hit at staging
        result = await self._settle_counted(
            staged, timeout=gw._route_budget("closest-concepts"))
        resp = gw._closest_response(req, staged, result)
        gw._cache_store(gw._cache_key("closest-concepts", req), resp)
        return resp

    # -------------------------- fan-out helpers ------------------------ #
    async def closest_concepts_many(
            self, requests: Sequence[ClosestConceptsRequest], *,
            return_exceptions: bool = False) -> List:
        """``asyncio.gather`` fan-out: submit every request concurrently
        so the flush loop coalesces them into micro-batches."""
        return await asyncio.gather(
            *(self.closest_concepts(r.ontology, r.model, r.query, k=r.k,
                                    fuzzy=r.fuzzy, version=r.version)
              for r in requests),
            return_exceptions=return_exceptions)

    async def similarity_many(self, requests: Sequence[SimilarityRequest], *,
                              return_exceptions: bool = False) -> List:
        return await asyncio.gather(
            *(self.similarity(r.ontology, r.model, r.a, r.b, fuzzy=r.fuzzy,
                              version=r.version) for r in requests),
            return_exceptions=return_exceptions)

    # ------------------- direct reads (executor) ----------------------- #
    async def _blocking(self, fn, *args, **kwargs):
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(
            None, lambda: fn(*args, **kwargs))

    async def get_vector(self, ontology: str, model: str, query: str, *,
                         fuzzy: bool = False,
                         version: Optional[str] = None) -> VectorResponse:
        return await self._blocking(self.gateway.get_vector, ontology, model,
                                    query, fuzzy=fuzzy, version=version)

    async def download(self, ontology: str, model: str, *,
                       version: Optional[str] = None, offset: int = 0,
                       limit: int = 1000) -> DownloadPage:
        return await self._blocking(self.gateway.download, ontology, model,
                                    version=version, offset=offset,
                                    limit=limit)

    async def autocomplete(self, ontology: str, model: str, prefix: str, *,
                           limit: int = 10, version: Optional[str] = None
                           ) -> AutocompleteResponse:
        return await self._blocking(self.gateway.autocomplete, ontology,
                                    model, prefix, limit=limit,
                                    version=version)

    async def health(self) -> HealthResponse:
        return await self._blocking(self.gateway.health)

    async def stats(self) -> StatsResponse:
        return await self._blocking(self.gateway.stats)

    async def versions(self, ontology: str) -> VersionsResponse:
        return await self._blocking(self.gateway.versions, ontology)

    async def lineage(self, ontology: str,
                      version: Optional[str] = None) -> LineageResponse:
        return await self._blocking(self.gateway.lineage, ontology, version)

    # --------------------------- batch jobs ---------------------------- #
    # submit/poll/result/cancel are thin executor hops: the manager's own
    # locking is cheap, but submit validates coordinates against the
    # store (disk metadata) and result_rows may read a rows file, so none
    # of them belong on the event loop.
    async def submit_job(self, kind: str, ontology: str, *,
                         model: Optional[str] = None,
                         version: Optional[str] = None,
                         version_b: Optional[str] = None,
                         classes: Optional[Sequence[str]] = None,
                         k: int = 10,
                         models: Optional[Sequence[str]] = None,
                         sample: Optional[int] = None) -> JobStatusResponse:
        return await self._blocking(
            self.gateway.submit_job, kind, ontology, model=model,
            version=version, version_b=version_b, classes=classes, k=k,
            models=models, sample=sample)

    async def job_status(self, job_id: str) -> JobStatusResponse:
        return await self._blocking(self.gateway.job_status, job_id)

    async def job_result(self, job_id: str, *, offset: int = 0,
                         limit: int = 1000) -> JobResultPage:
        return await self._blocking(self.gateway.job_result, job_id,
                                    offset=offset, limit=limit)

    async def job_cancel(self, job_id: str) -> JobStatusResponse:
        return await self._blocking(self.gateway.job_cancel, job_id)

    async def jobs_list(self) -> JobListResponse:
        return await self._blocking(self.gateway.jobs_list)

    async def job_wait(self, job_id: str, *, poll_s: float = 0.02,
                       timeout: Optional[float] = None) -> JobStatusResponse:
        """Poll until the job reaches a terminal state, yielding the
        event loop between polls (unlike the sync ``Gateway.job_wait``,
        which parks its thread)."""
        loop = asyncio.get_running_loop()
        deadline = None if timeout is None else loop.time() + timeout
        while True:
            status = await self.job_status(job_id)
            if status.state in ("DONE", "FAILED", "CANCELLED"):
                return status
            if deadline is not None and loop.time() >= deadline:
                raise ApiError(
                    "TIMEOUT", f"job {job_id} unfinished after {timeout}s",
                    details={"job_id": job_id, "state": status.state,
                             "progress": status.progress})
            await asyncio.sleep(poll_s)

    # ------------------------------ wire ------------------------------- #
    async def _handle_sim_wire(self, req: SimilarityRequest):
        return await self.similarity(req.ontology, req.model, req.a, req.b,
                                     fuzzy=req.fuzzy, version=req.version)

    async def _handle_closest_wire(self, req: ClosestConceptsRequest):
        return await self.closest_concepts(req.ontology, req.model,
                                           req.query, k=req.k,
                                           fuzzy=req.fuzzy,
                                           version=req.version)

    async def handle(self, route: str,
                     payload: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
        """Async ``Gateway.handle``: ticket routes (``TICKET_ROUTES``)
        await their future bridge, everything else runs in the executor.
        Never raises on request faults — errors come back as wire
        payloads. Parsing goes through the same ``_build_request`` as
        the sync entry point, so payload shape and route/payload-conflict
        rules are identical."""
        from .schema import to_wire
        try:
            name, handler, req = self.gateway._build_request(route, payload)
            impl = self._ticket_impls.get(name)
            if impl is None:
                # ops/direct read: reuse the already-parsed request via
                # the counted sync dispatcher, off the event loop
                return await self._blocking(
                    lambda: to_wire(self.gateway._run(name, req, handler)))
            return to_wire(await impl(req))
        except ApiError as e:
            self.gateway._count_error(e)
            return e.to_wire()
