"""Async batch-analytics job subsystem (PR 9).

``JobManager`` turns the gateway into a submit → poll/stream batch API:

* **Lifecycle** — ``PENDING → RUNNING → DONE | FAILED | CANCELLED``,
  with a monotone progress fraction published between work slabs.
* **Bounded intake** — at most ``max_queued`` PENDING jobs; beyond
  that, submit fast-rejects with ``OVERLOADED`` + ``retry_after_s``
  *before* any analytics work, mirroring the scheduler's admission
  control (429 + Retry-After on the wire).
* **Single executor thread** — jobs are pinned to the worker process
  that accepted them and run one at a time on a daemon thread; the
  workload's ``tick`` boundary (between kernel slabs) is where progress
  is published, cancellation observed, and ``yield_s`` of sleep handed
  back to interactive traffic so serve-path p99 stays flat.
* **Result retention** — a finished job's rows are immutable; the
  newest ``keep_finished`` finished jobs are kept (older ones are
  evicted and report ``JOB_NOT_FOUND``, like any unknown id).
* **Multi-process visibility** — with a shared ``state_dir`` (the
  worker pool passes one), every submit/transition mirrors the job's
  public status to ``job-<id>.json`` (rows to ``job-<id>.rows.json`` on
  DONE) via atomic writes, so *any* worker answers polls for *any* job.
  Cancels from a non-owner drop a ``job-<id>.cancel`` marker the owner
  observes at its next tick. If a poll finds a PENDING/RUNNING job
  whose owner pid no longer exists (SIGKILL'd worker), the job is
  reported — and rewritten — as FAILED instead of hanging pollers;
  liveness is judged only by ``os.kill(pid, 0)``, never by heartbeat
  staleness, so a slow-but-alive worker is never falsely failed.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from .schema import ApiError
from ..core import analytics

JOB_KINDS = ("knn-join", "drift", "compare")

#: status fields mirrored to the shared state file / returned to callers
_PUBLIC_FIELDS = ("job_id", "kind", "state", "progress", "ontology",
                  "model", "version", "version_b", "k", "submitted_at",
                  "wall_s", "total", "error", "summary", "owner_pid")


class JobCancelled(Exception):
    """Raised inside the executor when a cancel is observed mid-slab."""


class _Job:
    __slots__ = ("job_id", "kind", "spec", "state", "progress",
                 "submitted_at", "started_mono", "wall_s", "total",
                 "error", "summary", "rows", "owner_pid", "cancel_event",
                 "_last_persist")

    def __init__(self, job_id: str, kind: str, spec: Dict[str, Any]):
        self.job_id = job_id
        self.kind = kind
        self.spec = spec
        self.state = "PENDING"
        self.progress = 0.0
        self.submitted_at = time.time()
        self.started_mono: Optional[float] = None
        self.wall_s: Optional[float] = None
        self.total: Optional[int] = None
        self.error: Optional[str] = None
        self.summary: Optional[Dict[str, Any]] = None
        self.rows: Optional[List[List[Any]]] = None
        self.owner_pid = os.getpid()
        self.cancel_event = threading.Event()
        self._last_persist = 0.0

    def public(self) -> Dict[str, Any]:
        return {
            "job_id": self.job_id, "kind": self.kind, "state": self.state,
            "progress": round(self.progress, 6),
            "ontology": self.spec.get("ontology", ""),
            "model": self.spec.get("model"),
            "version": self.spec.get("version"),
            "version_b": self.spec.get("version_b"),
            "k": self.spec.get("k"),
            "submitted_at": self.submitted_at, "wall_s": self.wall_s,
            "total": self.total, "error": self.error,
            "summary": self.summary, "owner_pid": self.owner_pid,
        }


def _atomic_write(path: Path, payload: str) -> None:
    tmp = path.with_name(f"{path.name}.{os.getpid()}.tmp")
    tmp.write_text(payload)
    os.replace(tmp, path)


def _pid_alive(pid: int) -> bool:
    """Liveness by signal-0 probe only. PermissionError means the pid
    exists (owned by someone else) — alive."""
    try:
        os.kill(int(pid), 0)
    except ProcessLookupError:
        return False
    except (PermissionError, OSError):
        return True
    return True


class JobManager:
    """Submit/poll/cancel surface plus the background executor.

    ``engine`` is the gateway's ``ServingEngine``; analytics workloads
    go through its index cache, so jobs and interactive traffic share
    warm indexes.
    """

    def __init__(self, engine, *, max_queued: int = 8,
                 keep_finished: int = 64, yield_s: float = 0.002,
                 yield_duty: float = 1.0, slab: int = 64,
                 state_dir: Optional[str | Path] = None,
                 retry_after_s: float = 1.0,
                 persist_interval_s: float = 0.2):
        if max_queued < 1:
            raise ValueError(f"max_queued must be >= 1, got {max_queued}")
        self.engine = engine
        self.max_queued = int(max_queued)
        self.keep_finished = max(1, int(keep_finished))
        self.yield_s = float(yield_s)
        #: duty-cycle bound: each slab boundary sleeps at least
        #: ``yield_duty`` x the slab's own compute time, so a bulk job
        #: can never claim more than ``1/(1+duty)`` of the machine no
        #: matter how expensive its slabs are — the sleep scales with
        #: the contention the slab just caused. 1.0 caps a job at ~half
        #: the box; 0 falls back to the flat ``yield_s`` pause.
        self.yield_duty = max(0.0, float(yield_duty))
        self.slab = max(1, int(slab))
        self.retry_after_s = float(retry_after_s)
        self.persist_interval_s = float(persist_interval_s)
        self.state_dir = None if state_dir is None else Path(state_dir)
        if self.state_dir is not None:
            self.state_dir.mkdir(parents=True, exist_ok=True)
        self._jobs: Dict[str, _Job] = {}
        self._finished_order: List[str] = []
        self._queue: "queue.Queue[Optional[str]]" = queue.Queue()
        self._lock = threading.RLock()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._seq = 0
        self.counters: Dict[str, Any] = {
            "submitted": 0, "completed": 0, "failed": 0, "cancelled": 0,
            "rejected_overloaded": 0, "evicted": 0,
            "by_kind": {k: 0 for k in JOB_KINDS},
        }

    # ------------------------------------------------------------------ #
    # shared-state mirroring
    # ------------------------------------------------------------------ #
    def _state_path(self, job_id: str) -> Optional[Path]:
        if self.state_dir is None:
            return None
        return self.state_dir / f"job-{job_id}.json"

    def _persist(self, job: _Job, force: bool = False) -> None:
        path = self._state_path(job.job_id)
        if path is None:
            return
        now = time.monotonic()
        if not force and now - job._last_persist < self.persist_interval_s:
            return
        job._last_persist = now
        _atomic_write(path, json.dumps(job.public()))

    def _persist_rows(self, job: _Job) -> None:
        if self.state_dir is None or job.rows is None:
            return
        _atomic_write(self.state_dir / f"job-{job.job_id}.rows.json",
                      json.dumps(job.rows))

    def _read_shared(self, job_id: str) -> Optional[Dict[str, Any]]:
        """A *non-owner's* view of a job from the shared state dir, with
        the orphan rule applied: an in-flight job whose owner process is
        gone is rewritten and reported as FAILED."""
        path = self._state_path(job_id)
        if path is None or not path.exists():
            return None
        try:
            data = json.loads(path.read_text())
        except (OSError, ValueError):
            return None
        if data.get("state") in ("PENDING", "RUNNING") and \
                int(data.get("owner_pid", 0)) != os.getpid() and \
                not _pid_alive(int(data.get("owner_pid", 0))):
            data["state"] = "FAILED"
            data["error"] = (f"worker process {data.get('owner_pid')} "
                             f"died before finishing the job")
            _atomic_write(path, json.dumps(data))
        return data

    def _cancel_marker(self, job_id: str) -> Optional[Path]:
        if self.state_dir is None:
            return None
        return self.state_dir / f"job-{job_id}.cancel"

    # ------------------------------------------------------------------ #
    # public surface
    # ------------------------------------------------------------------ #
    def submit(self, kind: str, spec: Dict[str, Any]) -> Dict[str, Any]:
        """Enqueue one validated job spec. Raises OVERLOADED *before*
        any analytics work when the PENDING bound is hit."""
        with self._lock:
            if self._closed:
                raise ApiError("SHUTTING_DOWN", "job intake is closed")
            pending = sum(1 for j in self._jobs.values()
                          if j.state == "PENDING")
            if pending >= self.max_queued:
                self.counters["rejected_overloaded"] += 1
                raise ApiError(
                    "OVERLOADED",
                    f"job queue full ({pending} pending >= "
                    f"{self.max_queued}); retry later",
                    details={"retry_after_s": self.retry_after_s,
                             "pending": pending,
                             "max_queued": self.max_queued})
            self._seq += 1
            job_id = f"j{os.getpid()}-{self._seq}"
            job = _Job(job_id, kind, spec)
            self._jobs[job_id] = job
            self.counters["submitted"] += 1
            self.counters["by_kind"][kind] += 1
            self._persist(job, force=True)
            self._ensure_thread()
            self._queue.put(job_id)
            return job.public()

    def status(self, job_id: str) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None:
                return job.public()
        shared = self._read_shared(job_id)
        if shared is not None:
            return shared
        raise ApiError("JOB_NOT_FOUND", f"unknown job id {job_id!r}",
                       details={"job_id": job_id})

    def result_rows(self, job_id: str) -> Tuple[str, List[List[Any]]]:
        """``(kind, rows)`` of a DONE job; per-state errors otherwise."""
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None:
                return self._check_result_state(job.public(), job.rows)
        shared = self._read_shared(job_id)
        if shared is not None:
            rows = None
            if shared.get("state") == "DONE":
                rp = (self.state_dir / f"job-{job_id}.rows.json"
                      if self.state_dir else None)
                if rp is not None and rp.exists():
                    try:
                        rows = json.loads(rp.read_text())
                    except (OSError, ValueError):
                        rows = None
            return self._check_result_state(shared, rows)
        raise ApiError("JOB_NOT_FOUND", f"unknown job id {job_id!r}",
                       details={"job_id": job_id})

    @staticmethod
    def _check_result_state(pub: Dict[str, Any],
                            rows: Optional[List[List[Any]]]
                            ) -> Tuple[str, List[List[Any]]]:
        state = pub.get("state")
        if state == "CANCELLED":
            raise ApiError("JOB_CANCELLED",
                           f"job {pub['job_id']} was cancelled; "
                           f"no results were materialized",
                           details={"job_id": pub["job_id"]})
        if state == "FAILED":
            raise ApiError("BAD_REQUEST",
                           f"job {pub['job_id']} failed: {pub.get('error')}",
                           details={"job_id": pub["job_id"],
                                    "state": "FAILED",
                                    "error": pub.get("error")})
        if state != "DONE" or rows is None:
            raise ApiError("BAD_REQUEST",
                           f"job {pub['job_id']} is not finished "
                           f"(state {state})",
                           details={"job_id": pub["job_id"], "state": state,
                                    "progress": pub.get("progress")})
        return pub["kind"], rows

    def cancel(self, job_id: str) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None:
                if job.state == "PENDING":
                    job.state = "CANCELLED"
                    job.wall_s = 0.0
                    self.counters["cancelled"] += 1
                    self._note_finished_locked(job)
                    self._persist(job, force=True)
                    return job.public()
                if job.state == "RUNNING":
                    # observed at the executor's next slab boundary
                    job.cancel_event.set()
                    return job.public()
                raise ApiError(
                    "BAD_REQUEST",
                    f"cannot cancel job {job_id} in terminal state "
                    f"{job.state}",
                    details={"job_id": job_id, "state": job.state})
        shared = self._read_shared(job_id)
        if shared is not None:
            if shared.get("state") in ("PENDING", "RUNNING"):
                marker = self._cancel_marker(job_id)
                if marker is not None:
                    marker.touch()
                return shared
            raise ApiError(
                "BAD_REQUEST",
                f"cannot cancel job {job_id} in terminal state "
                f"{shared.get('state')}",
                details={"job_id": job_id, "state": shared.get("state")})
        raise ApiError("JOB_NOT_FOUND", f"unknown job id {job_id!r}",
                       details={"job_id": job_id})

    def list_jobs(self) -> List[Dict[str, Any]]:
        """This process's jobs, newest submission first."""
        with self._lock:
            jobs = sorted(self._jobs.values(),
                          key=lambda j: j.submitted_at, reverse=True)
            return [j.public() for j in jobs]

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out = {k: (dict(v) if isinstance(v, dict) else v)
                   for k, v in self.counters.items()}
            out["pending"] = sum(1 for j in self._jobs.values()
                                 if j.state == "PENDING")
            out["running"] = sum(1 for j in self._jobs.values()
                                 if j.state == "RUNNING")
            return out

    def close(self) -> None:
        with self._lock:
            self._closed = True
            for job in self._jobs.values():
                if job.state == "RUNNING":
                    job.cancel_event.set()
        self._queue.put(None)
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout=5.0)

    # ------------------------------------------------------------------ #
    # executor
    # ------------------------------------------------------------------ #
    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run_loop, name="job-executor", daemon=True)
            self._thread.start()

    def _run_loop(self) -> None:
        while True:
            job_id = self._queue.get()
            if job_id is None or self._closed:
                return
            with self._lock:
                job = self._jobs.get(job_id)
                if job is None or job.state != "PENDING":
                    continue  # cancelled while queued, or evicted
                job.state = "RUNNING"
                job.started_mono = time.monotonic()
                self._persist(job, force=True)
            try:
                rows, summary = self._execute(job)
            except JobCancelled:
                self._finish(job, "CANCELLED")
            except ApiError as e:
                self._finish(job, "FAILED", error=f"{e.code}: {e.message}")
            except Exception as e:  # noqa: BLE001 — executor must survive
                self._finish(job, "FAILED", error=f"{type(e).__name__}: {e}")
            else:
                # publish the result fields under the lock: status() reads
                # them through public() and must never see DONE-in-progress
                # state (e.g. progress 1.0 with rows still unset)
                with self._lock:
                    job.rows = rows
                    job.summary = summary
                    job.total = len(rows)
                    job.progress = 1.0
                self._persist_rows(job)
                self._finish(job, "DONE")

    def _finish(self, job: _Job, state: str,
                error: Optional[str] = None) -> None:
        with self._lock:
            if error is not None:
                job.error = error
            job.state = state
            if job.started_mono is not None:
                job.wall_s = round(time.monotonic() - job.started_mono, 4)
            key = {"DONE": "completed", "FAILED": "failed",
                   "CANCELLED": "cancelled"}[state]
            self.counters[key] += 1
            self._note_finished_locked(job)
            self._persist(job, force=True)
        marker = self._cancel_marker(job.job_id)
        if marker is not None and marker.exists():
            try:
                marker.unlink()
            except OSError:
                pass

    def _note_finished_locked(self, job: _Job) -> None:
        """Retention: keep the newest ``keep_finished`` finished jobs of
        this process; evict (memory + shared files) beyond that.  Caller
        holds ``self._lock`` (the ``_locked`` suffix is the BIO001
        contract for that)."""
        self._finished_order.append(job.job_id)
        while len(self._finished_order) > self.keep_finished:
            victim = self._finished_order.pop(0)
            self._jobs.pop(victim, None)
            self.counters["evicted"] += 1
            if self.state_dir is not None:
                for suffix in (".json", ".rows.json", ".cancel"):
                    try:
                        (self.state_dir / f"job-{victim}{suffix}").unlink()
                    except OSError:
                        pass

    def _tick(self, job: _Job, expected_total: int):
        """The slab-boundary callback handed to analytics workloads:
        publish progress (monotone), observe cancellation (in-process
        event or cross-worker marker file), persist throttled, and yield
        to interactive traffic — sleeping ``yield_duty`` x the slab's
        own compute time (floored at ``yield_s``), so the job's CPU
        share is duty-cycle bounded and interactive p99 stays flat
        regardless of how expensive one slab is."""
        marker = self._cancel_marker(job.job_id)
        last = [time.monotonic()]

        def tick(frac: float) -> None:
            if job.cancel_event.is_set() or \
                    (marker is not None and marker.exists()):
                raise JobCancelled(job.job_id)
            with self._lock:
                job.progress = max(job.progress, min(frac, 1.0))
                if expected_total and job.total is None:
                    job.total = expected_total
            self._persist(job)
            now = time.monotonic()
            pause = max(self.yield_s, (now - last[0]) * self.yield_duty)
            if pause > 0:
                time.sleep(pause)
            last[0] = time.monotonic()

        return tick

    def _execute(self, job: _Job):
        spec = job.spec
        engine = self.engine
        if job.kind == "knn-join":
            classes = spec["classes"]
            tick = self._tick(job, len(classes))
            try:
                return analytics.bulk_knn_join(
                    engine, spec["ontology"], spec["model"], classes,
                    k=spec["k"], version=spec["version"], slab=self.slab,
                    tick=tick)
            except analytics.UnknownClasses as e:
                raise ApiError(
                    "UNKNOWN_CLASS", str(e.args[0]),
                    details={"missing": e.missing[:100],
                             "n_missing": len(e.missing)})
        if job.kind == "drift":
            tick = self._tick(job, 0)
            try:
                return analytics.drift_report(
                    engine, spec["ontology"], spec["model"],
                    spec["version"], spec["version_b"], k=spec["k"],
                    classes=spec.get("classes"), slab=self.slab, tick=tick)
            except analytics.UnknownClasses as e:
                raise ApiError(
                    "UNKNOWN_CLASS", str(e.args[0]),
                    details={"missing": e.missing[:100],
                             "n_missing": len(e.missing)})
        if job.kind == "compare":
            models = spec["models"]
            tick = self._tick(job, len(models))
            return analytics.model_compare(
                engine, spec["ontology"], spec["version"], models,
                sample=spec.get("sample"), tick=tick)
        raise ApiError("BAD_REQUEST", f"unknown job kind {job.kind!r}")
