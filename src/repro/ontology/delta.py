"""Graph diffs between ontology releases — the incremental-update contract.

Consecutive GO/HP releases overlap almost entirely (Know2BIO reports >95%
entity survival month-over-month), so the updater should not pay full
retraining for a release that only adds a handful of terms. ``GraphDelta``
is the exact diff between two ``KnowledgeGraph`` versions that the update
policy consumes:

  * added / removed / relabeled entities (string identifiers),
  * added / removed relations,
  * added / removed string triples,
  * ``churn_fraction`` — the fraction of the combined entity universe that
    was touched by any of the above. The updater goes *incremental* when
    churn is below its threshold and *full* otherwise.

The delta is purely set-based over string identifiers, so it is stable
across the integer-id remapping that happens when entities are inserted
into the sorted entity list (an added term shifts every id above it; the
delta is unaffected).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List

from .graph import KnowledgeGraph, Triple


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """Exact diff between two releases of one ontology."""

    added_entities: List[str]
    removed_entities: List[str]
    relabeled_entities: List[str]
    added_relations: List[str]
    removed_relations: List[str]
    added_triples: List[Triple]
    removed_triples: List[Triple]
    #: |old entities ∪ new entities| — churn denominator
    n_universe: int

    # ------------------------------------------------------------------ #
    @classmethod
    def compute(cls, old: KnowledgeGraph, new: KnowledgeGraph) -> "GraphDelta":
        old_ents, new_ents = set(old.entities), set(new.entities)
        old_rels, new_rels = set(old.relations), set(new.relations)
        old_trips, new_trips = set(old.string_triples()), set(new.string_triples())

        relabeled = sorted(
            e for e in old_ents & new_ents
            if e in old.terms and e in new.terms
            and old.terms[e].label != new.terms[e].label
        )
        return cls(
            added_entities=sorted(new_ents - old_ents),
            removed_entities=sorted(old_ents - new_ents),
            relabeled_entities=relabeled,
            added_relations=sorted(new_rels - old_rels),
            removed_relations=sorted(old_rels - new_rels),
            added_triples=sorted(new_trips - old_trips),
            removed_triples=sorted(old_trips - new_trips),
            n_universe=len(old_ents | new_ents),
        )

    # ------------------------------------------------------------------ #
    @functools.cached_property
    def touched_entities(self) -> List[str]:
        """Every entity affected by the diff: added, removed, relabeled, or
        an endpoint of an added/removed triple. Cached — the delta is
        immutable and plan/stats/churn all consume this set."""
        touched = set(self.added_entities) | set(self.removed_entities)
        touched |= set(self.relabeled_entities)
        for h, _, t in self.added_triples:
            touched.add(h)
            touched.add(t)
        for h, _, t in self.removed_triples:
            touched.add(h)
            touched.add(t)
        return sorted(touched)

    @property
    def churn_fraction(self) -> float:
        """|touched entities| / |entity universe| — the policy signal."""
        if self.n_universe == 0:
            return 0.0
        return len(self.touched_entities) / self.n_universe

    @property
    def is_empty(self) -> bool:
        return not (self.added_entities or self.removed_entities
                    or self.relabeled_entities or self.added_relations
                    or self.removed_relations or self.added_triples
                    or self.removed_triples)

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, Any]:
        """Compact JSON-able summary for UpdateReport / lineage metadata."""
        return {
            "added_entities": len(self.added_entities),
            "removed_entities": len(self.removed_entities),
            "relabeled_entities": len(self.relabeled_entities),
            "added_relations": len(self.added_relations),
            "removed_relations": len(self.removed_relations),
            "added_triples": len(self.added_triples),
            "removed_triples": len(self.removed_triples),
            "touched_entities": len(self.touched_entities),
            "n_universe": self.n_universe,
            "churn_fraction": round(self.churn_fraction, 6),
        }
