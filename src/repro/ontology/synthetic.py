"""Synthetic GO-like / HP-like ontology generators with version evolution.

The container is offline, so the updater cannot download GO/HP releases.
These generators produce ontologies with the structural statistics the paper
relies on — scale-free ``is_a`` DAGs, GO's three namespaces with ``part_of``
and ``regulates`` side relations, HP's pure-``is_a`` hierarchy — and an
``evolve`` step that mimics a release cycle: new terms are added under
existing ones, a small fraction are obsoleted, and some relationships are
rewired ("reorganization of the relationship structure").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import KnowledgeGraph, TermMeta, Triple

GO_NAMESPACES = ("biological_process", "molecular_function", "cellular_component")

# Vocabulary for plausible-looking labels (labels matter: the serving API
# resolves them with case/whitespace normalization).
_ADJ = [
    "positive", "negative", "cellular", "nuclear", "mitochondrial", "membrane",
    "cytoplasmic", "embryonic", "abnormal", "delayed", "progressive", "recurrent",
    "proximal", "distal", "bilateral", "generalized", "focal", "chronic",
]
_NOUN = [
    "regulation", "transport", "binding", "signaling", "development",
    "morphogenesis", "differentiation", "metabolism", "biosynthesis",
    "phosphorylation", "seizure", "hypotonia", "atrophy", "dysplasia",
    "hypoplasia", "stenosis", "degeneration", "inflammation", "proliferation",
]
_OBJ = [
    "pathway", "process", "activity", "complex", "response", "channel",
    "receptor", "muscle", "cortex", "retina", "femur", "aorta", "kidney",
    "neuron", "axon", "synapse", "epithelium", "cartilage", "marrow",
]


def _label(rng: np.random.Generator) -> str:
    return (
        f"{_ADJ[rng.integers(len(_ADJ))]} {_NOUN[rng.integers(len(_NOUN))]}"
        f" of {_OBJ[rng.integers(len(_OBJ))]}"
    )


@dataclasses.dataclass
class OntologySpec:
    """Generator knobs for one ontology family."""

    prefix: str                      # "GO" or "HP"
    n_terms: int
    namespaces: Tuple[str, ...]      # GO: 3 roots; HP: 1
    side_relations: Tuple[str, ...]  # GO: (part_of, regulates); HP: ()
    side_rel_frac: float             # fraction of terms with an extra side edge
    multi_parent_frac: float         # fraction with a second is_a parent
    pref_attach: float               # preferential-attachment strength


GO_SPEC = OntologySpec(
    prefix="GO", n_terms=4000, namespaces=GO_NAMESPACES,
    side_relations=("part_of", "regulates"), side_rel_frac=0.25,
    multi_parent_frac=0.3, pref_attach=0.75,
)
HP_SPEC = OntologySpec(
    prefix="HP", n_terms=1800, namespaces=("human_phenotype",),
    side_relations=(), side_rel_frac=0.0,
    multi_parent_frac=0.25, pref_attach=0.75,
)


def generate(spec: OntologySpec, seed: int = 0, n_terms: Optional[int] = None) -> KnowledgeGraph:
    """Generate one ontology version.

    Parents are always lower-indexed → the is_a graph is a DAG by
    construction, like GO/HP.
    """
    rng = np.random.default_rng(seed)
    n = int(n_terms or spec.n_terms)
    n_roots = len(spec.namespaces)
    assert n > n_roots

    ids = [f"{spec.prefix}:{i:07d}" for i in range(n)]
    ns_of = np.empty(n, dtype=np.int64)
    ns_of[:n_roots] = np.arange(n_roots)

    terms: Dict[str, TermMeta] = {}
    triples: List[Triple] = []
    # child counts drive preferential attachment (GO's hub terms).
    weight = np.zeros(n, dtype=np.float64)
    weight[:n_roots] = 1.0

    for i in range(n_roots):
        terms[ids[i]] = TermMeta(ids[i], f"{spec.namespaces[i].replace('_', ' ')}", spec.namespaces[i])

    for i in range(n_roots, n):
        # pick a namespace, then a parent inside it with pref. attachment
        ns = int(rng.integers(n_roots))
        cand = np.nonzero(ns_of[:i] == ns)[0]
        w = weight[cand] ** spec.pref_attach
        parent = int(cand[rng.choice(len(cand), p=w / w.sum())])
        ns_of[i] = ns
        terms[ids[i]] = TermMeta(ids[i], _label(rng), spec.namespaces[ns])
        triples.append((ids[i], "is_a", ids[parent]))
        weight[parent] += 1.0
        weight[i] = 1.0
        if i > n_roots + 2 and rng.random() < spec.multi_parent_frac:
            p2 = int(cand[rng.choice(len(cand), p=w / w.sum())])
            if p2 != parent:
                triples.append((ids[i], "is_a", ids[p2]))
        if spec.side_relations and rng.random() < spec.side_rel_frac:
            rel = spec.side_relations[int(rng.integers(len(spec.side_relations)))]
            tgt = int(rng.integers(i))  # side edges may cross namespaces
            triples.append((ids[i], rel, ids[tgt]))

    return KnowledgeGraph.from_triples(triples, terms)


def evolve(
    kg: KnowledgeGraph,
    spec: OntologySpec,
    seed: int,
    add_frac: float = 0.04,
    obsolete_frac: float = 0.01,
    rewire_frac: float = 0.02,
    relabel_frac: float = 0.0,
) -> KnowledgeGraph:
    """Produce the next release: add terms, obsolete some, rewire edges,
    and optionally rename a fraction of surviving terms (GO curation fixes
    labels without touching the graph — a relabel-only delta).

    The fractions are the churn dials: tests and benchmarks tune them to
    generate release series with *known* ``GraphDelta`` composition (e.g.
    ≤10% churn for the warm-start benchmark).
    """
    rng = np.random.default_rng(seed)
    terms = dict(kg.terms)
    triples = kg.string_triples()

    # --- obsolete leaf-ish terms (never roots) -------------------------- #
    heads = {h for h, _, _ in triples}
    tails = {t for _, _, t in triples}
    leaves = [i for i in terms if i in heads and i not in tails and not terms[i].obsolete]
    n_obs = int(len(terms) * obsolete_frac)
    for ident in list(rng.permutation(leaves))[:n_obs]:
        meta = terms[ident]
        terms[ident] = TermMeta(meta.identifier, f"obsolete {meta.label}",
                                meta.namespace, True, meta.definition)
        triples = [t for t in triples if t[0] != ident and t[2] != ident]

    # --- rewire a fraction of is_a edges -------------------------------- #
    live = [i for i in terms if not terms[i].obsolete]
    ns_map = {i: terms[i].namespace for i in live}
    new_triples: List[Triple] = []
    for h, r, t in triples:
        if r == "is_a" and rng.random() < rewire_frac:
            same_ns = [c for c in live if ns_map[c] == ns_map.get(h) and c != h]
            if same_ns:
                t = same_ns[int(rng.integers(len(same_ns)))]
        new_triples.append((h, r, t))
    triples = new_triples

    # --- relabel surviving non-root terms (curation label fixes) -------- #
    n_relabel = int(len(terms) * relabel_frac)
    if n_relabel:
        n_roots = len(spec.namespaces)
        roots = {f"{spec.prefix}:{i:07d}" for i in range(n_roots)}
        candidates = [i for i in live if i not in roots]
        for ident in list(rng.permutation(candidates))[:n_relabel]:
            meta = terms[ident]
            terms[ident] = TermMeta(meta.identifier, _label(rng),
                                    meta.namespace, meta.obsolete,
                                    meta.definition)

    # --- add new terms under random live parents ------------------------ #
    n_add = int(len(terms) * add_frac)
    next_idx = 1 + max(int(i.split(":")[1]) for i in terms)
    for k in range(n_add):
        ident = f"{spec.prefix}:{next_idx + k:07d}"
        parent = live[int(rng.integers(len(live)))]
        ns = terms[parent].namespace
        terms[ident] = TermMeta(ident, _label(rng), ns)
        triples.append((ident, "is_a", parent))
        if spec.side_relations and rng.random() < spec.side_rel_frac:
            rel = spec.side_relations[int(rng.integers(len(spec.side_relations)))]
            triples.append((ident, rel, live[int(rng.integers(len(live)))]))

    return KnowledgeGraph.from_triples(triples, terms)


def release_series(
    spec: OntologySpec, n_versions: int, seed: int = 0,
    n_terms: Optional[int] = None, **evolve_kwargs,
) -> List[Tuple[str, KnowledgeGraph]]:
    """A dated series of releases, like GO's monthly channel.

    ``evolve_kwargs`` (add_frac, obsolete_frac, rewire_frac, relabel_frac)
    pass through to :func:`evolve`, so callers can dial the per-release
    churn — the warm-start benchmark uses a low-churn series.
    """
    out: List[Tuple[str, KnowledgeGraph]] = []
    kg = generate(spec, seed=seed, n_terms=n_terms)
    for v in range(n_versions):
        # paper: first version 2023, subsequent releases ~every six months
        year, month = 2023 + (v // 2), 1 + 6 * (v % 2)
        tag = f"{year}-{month:02d}-01"
        out.append((tag, kg))
        if v + 1 < n_versions:
            kg = evolve(kg, spec, seed=seed + 1000 + v, **evolve_kwargs)
    return out
