"""Synthetic GO-like / HP-like ontology generators with version evolution.

The container is offline, so the updater cannot download GO/HP releases.
These generators produce ontologies with the structural statistics the paper
relies on — scale-free ``is_a`` DAGs, GO's three namespaces with ``part_of``
and ``regulates`` side relations, HP's pure-``is_a`` hierarchy — and an
``evolve`` step that mimics a release cycle: new terms are added under
existing ones, a small fraction are obsoleted, and some relationships are
rewired ("reorganization of the relationship structure").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import KnowledgeGraph, TermMeta, Triple

GO_NAMESPACES = ("biological_process", "molecular_function", "cellular_component")

# Vocabulary for plausible-looking labels (labels matter: the serving API
# resolves them with case/whitespace normalization).
_ADJ = [
    "positive", "negative", "cellular", "nuclear", "mitochondrial", "membrane",
    "cytoplasmic", "embryonic", "abnormal", "delayed", "progressive", "recurrent",
    "proximal", "distal", "bilateral", "generalized", "focal", "chronic",
]
_NOUN = [
    "regulation", "transport", "binding", "signaling", "development",
    "morphogenesis", "differentiation", "metabolism", "biosynthesis",
    "phosphorylation", "seizure", "hypotonia", "atrophy", "dysplasia",
    "hypoplasia", "stenosis", "degeneration", "inflammation", "proliferation",
]
_OBJ = [
    "pathway", "process", "activity", "complex", "response", "channel",
    "receptor", "muscle", "cortex", "retina", "femur", "aorta", "kidney",
    "neuron", "axon", "synapse", "epithelium", "cartilage", "marrow",
]


def _label(rng: np.random.Generator) -> str:
    return (
        f"{_ADJ[rng.integers(len(_ADJ))]} {_NOUN[rng.integers(len(_NOUN))]}"
        f" of {_OBJ[rng.integers(len(_OBJ))]}"
    )


@dataclasses.dataclass
class OntologySpec:
    """Generator knobs for one ontology family."""

    prefix: str                      # "GO" or "HP"
    n_terms: int
    namespaces: Tuple[str, ...]      # GO: 3 roots; HP: 1
    side_relations: Tuple[str, ...]  # GO: (part_of, regulates); HP: ()
    side_rel_frac: float             # fraction of terms with an extra side edge
    multi_parent_frac: float         # fraction with a second is_a parent
    pref_attach: float               # preferential-attachment strength


GO_SPEC = OntologySpec(
    prefix="GO", n_terms=4000, namespaces=GO_NAMESPACES,
    side_relations=("part_of", "regulates"), side_rel_frac=0.25,
    multi_parent_frac=0.3, pref_attach=0.75,
)
HP_SPEC = OntologySpec(
    prefix="HP", n_terms=1800, namespaces=("human_phenotype",),
    side_relations=(), side_rel_frac=0.0,
    multi_parent_frac=0.25, pref_attach=0.75,
)


#: term count at which :func:`generate` switches to the chunked vectorized
#: generator — the per-term python loop is O(n²) in the candidate scan and
#: takes minutes at GO scale (100k)
FAST_GEN_THRESHOLD = 20_000


def _generate_fast(spec: OntologySpec, rng: np.random.Generator, n: int
                   ) -> KnowledgeGraph:
    """Chunked vectorized preferential-attachment generator for GO-scale
    term counts (seconds at 100k vs minutes for the per-term loop).

    Parents for a chunk are sampled from the *pre-chunk* prefix (weights
    frozen at the chunk boundary), so every parent index is strictly lower
    than its child — the is_a graph stays a DAG by construction.  Chunk
    sizes double from 256 up to 4096: early chunks stay small so the hub
    structure still forms.  This is a different (vectorized) draw sequence
    than the small-n loop — a new regime, not a replacement; small-n
    callers keep their historical streams.
    """
    n_roots = len(spec.namespaces)
    ids = [f"{spec.prefix}:{i:07d}" for i in range(n)]
    ns_of = np.empty(n, dtype=np.int64)
    ns_of[:n_roots] = np.arange(n_roots)
    weight = np.zeros(n, dtype=np.float64)
    weight[:n_roots] = 1.0

    terms: Dict[str, TermMeta] = {}
    for i in range(n_roots):
        terms[ids[i]] = TermMeta(
            ids[i], spec.namespaces[i].replace("_", " "), spec.namespaces[i])

    ns_of[n_roots:] = rng.integers(n_roots, size=n - n_roots)
    # vectorized labels, ordinal-suffixed: at 100k the base vocabulary
    # (~6.5k combos) would collide constantly, which is unlike GO/HP where
    # labels are (nearly) unique — the suffix keeps resolution/autocomplete
    # realistic at scale
    adj = rng.integers(len(_ADJ), size=n)
    noun = rng.integers(len(_NOUN), size=n)
    obj = rng.integers(len(_OBJ), size=n)

    heads: List[str] = []
    rels: List[str] = []
    tails: List[str] = []
    start = n_roots
    chunk = 256
    while start < n:
        size = min(chunk, n - start)
        idx = np.arange(start, start + size)
        chunk_ns = ns_of[idx]
        parent = np.empty(size, dtype=np.int64)
        second = np.full(size, -1, dtype=np.int64)
        want2 = rng.random(size) < spec.multi_parent_frac
        for ns in range(n_roots):
            m = chunk_ns == ns
            cnt = int(m.sum())
            if not cnt:
                continue
            cand = np.nonzero(ns_of[:start] == ns)[0]
            w = weight[cand] ** spec.pref_attach
            p = w / w.sum()
            parent[m] = cand[rng.choice(len(cand), size=cnt, p=p)]
            second[m] = np.where(want2[m],
                                 cand[rng.choice(len(cand), size=cnt, p=p)],
                                 -1)
        second[second == parent] = -1          # distinct second parent only
        for j, i in enumerate(idx):
            terms[ids[i]] = TermMeta(
                ids[i],
                f"{_ADJ[adj[i]]} {_NOUN[noun[i]]} of {_OBJ[obj[i]]} {i}",
                spec.namespaces[chunk_ns[j]])
            heads.append(ids[i]); rels.append("is_a")
            tails.append(ids[parent[j]])
            if second[j] >= 0:
                heads.append(ids[i]); rels.append("is_a")
                tails.append(ids[second[j]])
        np.add.at(weight, parent, 1.0)
        weight[idx] = 1.0
        if spec.side_relations:
            side = np.nonzero(rng.random(size) < spec.side_rel_frac)[0]
            if side.size:
                rel_i = rng.integers(len(spec.side_relations), size=side.size)
                tgt = rng.integers(0, idx[side])   # any lower index, any ns
                for j, ri, t in zip(side, rel_i, tgt):
                    heads.append(ids[idx[j]])
                    rels.append(spec.side_relations[ri])
                    tails.append(ids[t])
        start += size
        chunk = min(chunk * 2, 4096)

    triples = list(zip(heads, rels, tails))
    return KnowledgeGraph.from_triples(triples, terms)


def generate(spec: OntologySpec, seed: int = 0, n_terms: Optional[int] = None) -> KnowledgeGraph:
    """Generate one ontology version.

    Parents are always lower-indexed → the is_a graph is a DAG by
    construction, like GO/HP.  At ``FAST_GEN_THRESHOLD`` terms and above
    the chunked vectorized generator takes over (same structural
    invariants, different draw sequence — small-n streams are unchanged).
    """
    rng = np.random.default_rng(seed)
    n = int(n_terms or spec.n_terms)
    n_roots = len(spec.namespaces)
    assert n > n_roots
    if n >= FAST_GEN_THRESHOLD:
        return _generate_fast(spec, rng, n)

    ids = [f"{spec.prefix}:{i:07d}" for i in range(n)]
    ns_of = np.empty(n, dtype=np.int64)
    ns_of[:n_roots] = np.arange(n_roots)

    terms: Dict[str, TermMeta] = {}
    triples: List[Triple] = []
    # child counts drive preferential attachment (GO's hub terms).
    weight = np.zeros(n, dtype=np.float64)
    weight[:n_roots] = 1.0

    for i in range(n_roots):
        terms[ids[i]] = TermMeta(ids[i], f"{spec.namespaces[i].replace('_', ' ')}", spec.namespaces[i])

    for i in range(n_roots, n):
        # pick a namespace, then a parent inside it with pref. attachment
        ns = int(rng.integers(n_roots))
        cand = np.nonzero(ns_of[:i] == ns)[0]
        w = weight[cand] ** spec.pref_attach
        parent = int(cand[rng.choice(len(cand), p=w / w.sum())])
        ns_of[i] = ns
        terms[ids[i]] = TermMeta(ids[i], _label(rng), spec.namespaces[ns])
        triples.append((ids[i], "is_a", ids[parent]))
        weight[parent] += 1.0
        weight[i] = 1.0
        if i > n_roots + 2 and rng.random() < spec.multi_parent_frac:
            p2 = int(cand[rng.choice(len(cand), p=w / w.sum())])
            if p2 != parent:
                triples.append((ids[i], "is_a", ids[p2]))
        if spec.side_relations and rng.random() < spec.side_rel_frac:
            rel = spec.side_relations[int(rng.integers(len(spec.side_relations)))]
            tgt = int(rng.integers(i))  # side edges may cross namespaces
            triples.append((ids[i], rel, ids[tgt]))

    return KnowledgeGraph.from_triples(triples, terms)


def evolve(
    kg: KnowledgeGraph,
    spec: OntologySpec,
    seed: int,
    add_frac: float = 0.04,
    obsolete_frac: float = 0.01,
    rewire_frac: float = 0.02,
    relabel_frac: float = 0.0,
) -> KnowledgeGraph:
    """Produce the next release: add terms, obsolete some, rewire edges,
    and optionally rename a fraction of surviving terms (GO curation fixes
    labels without touching the graph — a relabel-only delta).

    The fractions are the churn dials: tests and benchmarks tune them to
    generate release series with *known* ``GraphDelta`` composition (e.g.
    ≤10% churn for the warm-start benchmark).
    """
    rng = np.random.default_rng(seed)
    terms = dict(kg.terms)
    triples = kg.string_triples()

    # --- obsolete leaf-ish terms (never roots) -------------------------- #
    # one-pass batch filter: the per-ident refilter was O(n_obs · |T|),
    # minutes at GO scale; the rng call pattern (one permutation) and the
    # surviving triple list are bit-identical
    heads = {h for h, _, _ in triples}
    tails = {t for _, _, t in triples}
    leaves = [i for i in terms if i in heads and i not in tails and not terms[i].obsolete]
    n_obs = int(len(terms) * obsolete_frac)
    doomed = set(list(rng.permutation(leaves))[:n_obs])
    for ident in doomed:
        meta = terms[ident]
        terms[ident] = TermMeta(meta.identifier, f"obsolete {meta.label}",
                                meta.namespace, True, meta.definition)
    if doomed:
        triples = [t for t in triples
                   if t[0] not in doomed and t[2] not in doomed]

    # --- rewire a fraction of is_a edges -------------------------------- #
    live = [i for i in terms if not terms[i].obsolete]
    ns_map = {i: terms[i].namespace for i in live}
    # precomputed per-namespace live lists replace the O(n) same_ns scan
    # per rewired edge.  ``same_ns`` excluded the head itself, so index j
    # into it maps to the namespace list with the head's slot skipped —
    # the draws, and therefore the releases, stay bit-identical
    by_ns: Dict[str, List[str]] = {}
    pos_in_ns: Dict[str, int] = {}
    for c in live:
        lst = by_ns.setdefault(ns_map[c], [])
        pos_in_ns[c] = len(lst)
        lst.append(c)
    new_triples: List[Triple] = []
    for h, r, t in triples:
        if r == "is_a" and rng.random() < rewire_frac:
            lst = by_ns.get(ns_map.get(h), [])
            n_same = len(lst) - (1 if h in pos_in_ns else 0)
            if n_same > 0:
                j = int(rng.integers(n_same))
                if h in pos_in_ns and j >= pos_in_ns[h]:
                    j += 1
                t = lst[j]
        new_triples.append((h, r, t))
    triples = new_triples

    # --- relabel surviving non-root terms (curation label fixes) -------- #
    n_relabel = int(len(terms) * relabel_frac)
    if n_relabel:
        n_roots = len(spec.namespaces)
        roots = {f"{spec.prefix}:{i:07d}" for i in range(n_roots)}
        candidates = [i for i in live if i not in roots]
        for ident in list(rng.permutation(candidates))[:n_relabel]:
            meta = terms[ident]
            terms[ident] = TermMeta(meta.identifier, _label(rng),
                                    meta.namespace, meta.obsolete,
                                    meta.definition)

    # --- add new terms under random live parents ------------------------ #
    n_add = int(len(terms) * add_frac)
    next_idx = 1 + max(int(i.split(":")[1]) for i in terms)
    for k in range(n_add):
        ident = f"{spec.prefix}:{next_idx + k:07d}"
        parent = live[int(rng.integers(len(live)))]
        ns = terms[parent].namespace
        terms[ident] = TermMeta(ident, _label(rng), ns)
        triples.append((ident, "is_a", parent))
        if spec.side_relations and rng.random() < spec.side_rel_frac:
            rel = spec.side_relations[int(rng.integers(len(spec.side_relations)))]
            triples.append((ident, rel, live[int(rng.integers(len(live)))]))

    return KnowledgeGraph.from_triples(triples, terms)


def release_series(
    spec: OntologySpec, n_versions: int, seed: int = 0,
    n_terms: Optional[int] = None, **evolve_kwargs,
) -> List[Tuple[str, KnowledgeGraph]]:
    """A dated series of releases, like GO's monthly channel.

    ``evolve_kwargs`` (add_frac, obsolete_frac, rewire_frac, relabel_frac)
    pass through to :func:`evolve`, so callers can dial the per-release
    churn — the warm-start benchmark uses a low-churn series.
    """
    out: List[Tuple[str, KnowledgeGraph]] = []
    kg = generate(spec, seed=seed, n_terms=n_terms)
    for v in range(n_versions):
        # paper: first version 2023, subsequent releases ~every six months
        year, month = 2023 + (v // 2), 1 + 6 * (v % 2)
        tag = f"{year}-{month:02d}-01"
        out.append((tag, kg))
        if v + 1 < n_versions:
            kg = evolve(kg, spec, seed=seed + 1000 + v, **evolve_kwargs)
    return out
