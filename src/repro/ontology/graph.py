"""Triple store and id-mapped knowledge graph.

The in-memory representation every other subsystem consumes: a list of
(head, relation, tail) string triples plus dense integer id maps, convertible
to a padded CSR adjacency for vectorized random walks and to jnp arrays for
KGE training.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

Triple = Tuple[str, str, str]


@dataclasses.dataclass(frozen=True)
class TermMeta:
    """Per-class metadata mirroring an OBO [Term] stanza."""

    identifier: str
    label: str
    namespace: str = ""
    obsolete: bool = False
    definition: str = ""


@dataclasses.dataclass
class KnowledgeGraph:
    """Id-mapped triple store.

    entities / relations are sorted for determinism; ``triples`` is an
    (M, 3) int64 array of (head_id, rel_id, tail_id).
    """

    entities: List[str]
    relations: List[str]
    triples: np.ndarray  # (M, 3) int64
    terms: Dict[str, TermMeta] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.entity_to_id: Dict[str, int] = {e: i for i, e in enumerate(self.entities)}
        self.relation_to_id: Dict[str, int] = {r: i for i, r in enumerate(self.relations)}

    # ------------------------------------------------------------------ #
    @classmethod
    def from_triples(
        cls,
        triples: Iterable[Triple],
        terms: Optional[Mapping[str, TermMeta]] = None,
    ) -> "KnowledgeGraph":
        trips = list(triples)
        ents = sorted({h for h, _, _ in trips} | {t for _, _, t in trips})
        rels = sorted({r for _, r, _ in trips})
        e2i = {e: i for i, e in enumerate(ents)}
        r2i = {r: i for i, r in enumerate(rels)}
        arr = np.asarray(
            [(e2i[h], r2i[r], e2i[t]) for h, r, t in trips], dtype=np.int64
        ).reshape(-1, 3)
        return cls(ents, rels, arr, dict(terms or {}))

    # ------------------------------------------------------------------ #
    @property
    def num_entities(self) -> int:
        return len(self.entities)

    @property
    def num_relations(self) -> int:
        return len(self.relations)

    @property
    def num_triples(self) -> int:
        return int(self.triples.shape[0])

    def string_triples(self) -> List[Triple]:
        return [
            (self.entities[h], self.relations[r], self.entities[t])
            for h, r, t in self.triples
        ]

    def label_of(self, identifier: str) -> str:
        meta = self.terms.get(identifier)
        return meta.label if meta is not None else identifier

    def find_by_label(self, label: str) -> Optional[str]:
        """Resolve a textual label to a class identifier.

        Mirrors the paper's 'automatic normalization of case and whitespace'.
        """
        norm = " ".join(label.strip().lower().split())
        for ident, meta in self.terms.items():
            if " ".join(meta.label.strip().lower().split()) == norm:
                return ident
        return None

    # ------------------------------------------------------------------ #
    def checksum(self) -> str:
        """Deterministic content hash — the updater's change detector."""
        h = hashlib.sha256()
        for trip in sorted(self.string_triples()):
            h.update("\t".join(trip).encode())
            h.update(b"\n")
        for ident in sorted(self.terms):
            m = self.terms[ident]
            h.update(
                json.dumps(
                    [m.identifier, m.label, m.namespace, m.obsolete],
                    sort_keys=True,
                ).encode()
            )
        return h.hexdigest()

    # ------------------------------------------------------------------ #
    def padded_csr(self, max_degree: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Dense padded adjacency for vectorized random walks.

        Returns (neighbors, edge_rels, degrees):
          neighbors  (N, D) int32 — tail ids, padded with self-loops
          edge_rels  (N, D) int32 — relation ids, padded with 0
          degrees    (N,)   int32 — true out-degree (0 rows walk in place)
        """
        n = self.num_entities
        adj: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
        for h, r, t in self.triples:
            adj[int(h)].append((int(t), int(r)))
        deg = np.asarray([len(a) for a in adj], dtype=np.int32)
        d = int(max_degree or max(1, deg.max(initial=1)))
        nbrs = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, d))
        rels = np.zeros((n, d), dtype=np.int32)
        for i, a in enumerate(adj):
            for j, (t, r) in enumerate(a[:d]):
                nbrs[i, j] = t
                rels[i, j] = r
        return nbrs, rels, np.minimum(deg, d)

    # ------------------------------------------------------------------ #
    def split(
        self, rng: np.random.Generator, valid_frac: float = 0.05, test_frac: float = 0.05
    ) -> Tuple["KnowledgeGraph", np.ndarray, np.ndarray]:
        """Train/valid/test split over triples (ids preserved).

        Returns (train_graph_with_same_id_maps, valid_triples, test_triples).
        """
        m = self.num_triples
        perm = rng.permutation(m)
        n_valid = int(m * valid_frac)
        n_test = int(m * test_frac)
        valid = self.triples[perm[:n_valid]]
        test = self.triples[perm[n_valid : n_valid + n_test]]
        train = self.triples[perm[n_valid + n_test :]]
        kg = KnowledgeGraph(self.entities, self.relations, train, self.terms)
        return kg, valid, test
