"""Minimal OBO flat-file parser / writer.

Covers the subset of the OBO 1.4 format that GO and HP releases actually use
for graph extraction: [Term] stanzas with id / name / namespace / is_a /
relationship / is_obsolete. The updater treats the serialized file as the
release artifact (checksummed byte-for-byte, like the paper's downloads).

Streaming (PR 8): the parser consumes any iterable of lines, so
``load_obo`` feeds it the open file handle directly — a GO-sized release
(100k+ terms, tens of MB) is never materialized as one string on the read
path.  ``save_obo`` streams the serialization line-by-line the same way;
``parse_obo``/``write_obo`` keep the whole-string API for small payloads
and byte-checksum callers.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Tuple, Union

from .graph import KnowledgeGraph, TermMeta, Triple


def parse_obo_stream(lines: Iterable[str]) -> KnowledgeGraph:
    """Parse an iterable of OBO lines (an open file handle, a generator, a
    ``splitlines()`` list) into a KnowledgeGraph — O(1) text held beyond
    the accumulating graph itself.

    Obsolete terms are kept in ``terms`` (so labels still resolve — the live
    ontologies keep deprecated ids around) but contribute no triples.
    """
    triples: List[Triple] = []
    terms: Dict[str, TermMeta] = {}

    cur: Dict[str, Union[str, bool, List[Tuple[str, str]]]] = {}
    in_term = False

    def flush() -> None:
        nonlocal cur
        if not cur.get("id"):
            cur = {}
            return
        ident = str(cur["id"])
        meta = TermMeta(
            identifier=ident,
            label=str(cur.get("name", ident)),
            namespace=str(cur.get("namespace", "")),
            obsolete=bool(cur.get("is_obsolete", False)),
            definition=str(cur.get("def", "")),
        )
        terms[ident] = meta
        if not meta.obsolete:
            for rel, target in cur.get("links", []):  # type: ignore[union-attr]
                triples.append((ident, rel, target))
        cur = {}

    for raw in lines:
        line = raw.strip()
        if line.startswith("["):
            flush()
            in_term = line == "[Term]"
            continue
        if not in_term or not line or line.startswith("!"):
            continue
        if ":" not in line:
            continue
        key, _, value = line.partition(":")
        key, value = key.strip(), value.split("!")[0].strip()
        if key == "id":
            cur["id"] = value
        elif key == "name":
            cur["name"] = value
        elif key == "namespace":
            cur["namespace"] = value
        elif key == "def":
            cur["def"] = value.strip('"')
        elif key == "is_obsolete":
            cur["is_obsolete"] = value.lower() == "true"
        elif key == "is_a":
            cur.setdefault("links", []).append(("is_a", value))  # type: ignore[union-attr]
        elif key == "relationship":
            parts = value.split()
            if len(parts) >= 2:
                cur.setdefault("links", []).append((parts[0], parts[1]))  # type: ignore[union-attr]
    flush()

    # Drop triples pointing at unknown targets (dangling imports in real OBO).
    known = set(terms)
    triples = [t for t in triples if t[2] in known]
    kg = KnowledgeGraph.from_triples(triples, terms)
    return kg


def parse_obo(text: str) -> KnowledgeGraph:
    """Parse OBO text (one string) — see :func:`parse_obo_stream`."""
    return parse_obo_stream(text.splitlines())


def iter_obo_lines(kg: KnowledgeGraph, header_version: str) -> Iterator[str]:
    """Yield the OBO serialization line by line (no full-text buffer)."""
    yield "format-version: 1.4"
    yield f"data-version: {header_version}"
    yield "ontology: repro-bio"
    yield ""
    by_head: Dict[str, List[Tuple[str, str]]] = {}
    for h, r, t in kg.string_triples():
        by_head.setdefault(h, []).append((r, t))
    for ident in sorted(kg.terms):
        meta = kg.terms[ident]
        yield "[Term]"
        yield f"id: {ident}"
        yield f"name: {meta.label}"
        if meta.namespace:
            yield f"namespace: {meta.namespace}"
        if meta.obsolete:
            yield "is_obsolete: true"
        for rel, target in sorted(by_head.get(ident, [])):
            if rel == "is_a":
                yield f"is_a: {target}"
            else:
                yield f"relationship: {rel} {target}"
        yield ""


def write_obo(kg: KnowledgeGraph, header_version: str) -> str:
    """Serialize a KnowledgeGraph to OBO text (the 'release artifact')."""
    return "\n".join(iter_obo_lines(kg, header_version))


def load_obo(path: Union[str, Path]) -> KnowledgeGraph:
    """Parse an OBO file, streaming from the handle — the release text is
    never held in memory as one string."""
    with open(path, "r") as fh:
        return parse_obo_stream(fh)


def save_obo(kg: KnowledgeGraph, path: Union[str, Path], header_version: str) -> None:
    """Stream the serialization to ``path``, byte-identical to writing
    ``write_obo(...)`` wholesale (separator-prefix framing, no trailing
    newline added beyond what the line stream carries)."""
    with open(path, "w") as fh:
        first = True
        for line in iter_obo_lines(kg, header_version):
            if not first:
                fh.write("\n")
            fh.write(line)
            first = False
