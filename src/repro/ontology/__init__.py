from .delta import GraphDelta
from .graph import KnowledgeGraph, TermMeta, Triple
from .obo import load_obo, parse_obo, save_obo, write_obo
from .synthetic import GO_SPEC, HP_SPEC, OntologySpec, evolve, generate, release_series

__all__ = [
    "GraphDelta", "KnowledgeGraph", "TermMeta", "Triple",
    "load_obo", "parse_obo", "save_obo", "write_obo",
    "GO_SPEC", "HP_SPEC", "OntologySpec", "evolve", "generate", "release_series",
]
