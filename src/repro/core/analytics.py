"""Batch analytics workloads behind the async job subsystem (PR 9).

Three long-running computations over published snapshots, each written
as slab-iterated host loops that report progress through a ``tick``
callback between slabs — the job executor uses that boundary to publish
progress fractions, observe cancellation, and yield the process to
interactive traffic:

* :func:`bulk_knn_join` — all-pairs top-k neighbors for a submitted
  class list, batched through the block-tiled streaming kernel
  (``kernels.ops.topk_cosine_join``) so peak device allocation stays
  O(query_slab · table_block + query_slab · k). Results are
  bit-identical to a serial per-query ``top_k`` loop.
* :func:`drift_report` — per-entity neighborhood churn (Jaccard over
  top-k neighbor-id sets) between two releases, plus a ``GraphDelta``
  summary and snapshot lineage when the parsed graphs are stored.
* :func:`model_compare` — per-model filtered-ranking metrics
  (MRR / mean rank / Hits@k from ``kge.eval``) for one published
  version, cached in the snapshot store (``eval.json``) so repeat
  requests are free. Models whose full params are stored with a vocab
  matching the graph get the exact KGE scoring path; everything else
  (rdf2vec token vocabularies, params-less snapshots) falls back to
  cosine ranking over the *served* embedding table — tagged in the
  output so the two methods are never silently compared.

This module is core-layer: it raises plain exceptions
(:class:`UnknownClasses`, ``KeyError``, ``ValueError``) and never
imports the api package; the jobs layer maps failures to ApiError codes.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

Tick = Optional[Callable[[float], None]]


class UnknownClasses(KeyError):
    """One or more submitted class names resolve to no table row.
    Carries the *full* missing list, not just the first."""

    def __init__(self, missing: Sequence[str]):
        self.missing = list(missing)
        shown = ", ".join(repr(m) for m in self.missing[:20])
        extra = "" if len(self.missing) <= 20 else \
            f" (+{len(self.missing) - 20} more)"
        super().__init__(f"unknown class(es): {shown}{extra}")


def _tick(tick: Tick, frac: float) -> None:
    if tick is not None:
        tick(min(1.0, max(0.0, frac)))


def _resolve_all(index, classes: Sequence[str]) -> List[int]:
    rows, missing = [], []
    for c in classes:
        r = index.resolve(c)
        if r is None:
            missing.append(c)
        else:
            rows.append(r)
    if missing:
        raise UnknownClasses(missing)
    return rows


# --------------------------------------------------------------------- #
# 1. bulk kNN join
# --------------------------------------------------------------------- #
def bulk_knn_join(engine, ontology: str, model: str, classes: Sequence[str],
                  k: int = 10, version: Optional[str] = None,
                  slab: int = 256, tick: Tick = None,
                  ) -> Tuple[List[List[Any]], Dict[str, Any]]:
    """All-pairs top-``k`` join for ``classes``. Row shape:
    ``[identifier, [[neighbor_id, score], ...]]`` in submission order
    (deduplicated by resolved table row is *not* applied — one output
    row per input class)."""
    index = engine._index(ontology, model, version)
    rows = _resolve_all(index, classes)
    out: List[List[Any]] = []
    t0 = time.perf_counter()
    n_slabs = 0
    for start, hits in index.knn_join_rows(rows, k, slab=slab):
        for qi, lst in enumerate(hits):
            ident = index.entity_ids[rows[start + qi]]
            out.append([ident, [[c.identifier, c.score] for c in lst]])
        n_slabs += 1
        _tick(tick, len(out) / max(1, len(rows)))
    summary = {
        "n_queries": len(rows),
        "k": int(k),
        "table_rows": int(index.embeddings.shape[0]),
        "slabs": n_slabs,
        "compute_s": round(time.perf_counter() - t0, 4),
    }
    return out, summary


# --------------------------------------------------------------------- #
# 2. cross-version drift report
# --------------------------------------------------------------------- #
def drift_report(engine, ontology: str, model: str, version_a: str,
                 version_b: str, k: int = 10,
                 classes: Optional[Sequence[str]] = None,
                 slab: int = 256, tick: Tick = None,
                 ) -> Tuple[List[List[Any]], Dict[str, Any]]:
    """Per-entity neighborhood churn between two releases.

    For every entity published in *both* versions (or the submitted
    ``classes`` subset), computes the Jaccard overlap of its top-``k``
    neighbor-id sets under ``version_a`` (older) and ``version_b``
    (newer). Row shape: ``[identifier, jaccard]``; 1.0 = unchanged
    neighborhood, 0.0 = fully churned. The summary folds in the exact
    ``GraphDelta`` between the stored parsed releases (when present)
    and the newer snapshot's lineage sidecar."""
    idx_a = engine._index(ontology, model, version_a)
    idx_b = engine._index(ontology, model, version_b)
    ids_a = set(idx_a.entity_ids)
    if classes is None:
        common = [i for i in idx_b.entity_ids if i in ids_a]
    else:
        # submitted subset: resolve against the *newer* release, then
        # keep those that also exist in the older one
        rows_b = _resolve_all(idx_b, classes)
        common = [idx_b.entity_ids[r] for r in rows_b
                  if idx_b.entity_ids[r] in ids_a]
    out: List[List[Any]] = []
    t0 = time.perf_counter()
    jac_sum = 0.0
    for start in range(0, len(common), slab):
        chunk = common[start:start + slab]
        rows_a = [idx_a.resolve(i) for i in chunk]
        rows_b = [idx_b.resolve(i) for i in chunk]
        hits_a = idx_a.top_k_rows(rows_a, k)
        hits_b = idx_b.top_k_rows(rows_b, k)
        for ident, ha, hb in zip(chunk, hits_a, hits_b):
            sa = {c.identifier for c in ha}
            sb = {c.identifier for c in hb}
            union = len(sa | sb)
            jac = 1.0 if union == 0 else len(sa & sb) / union
            jac_sum += jac
            out.append([ident, jac])
        _tick(tick, len(out) / max(1, len(common)))
    summary: Dict[str, Any] = {
        "version_a": version_a,
        "version_b": version_b,
        "k": int(k),
        "n_common": len(common),
        "only_a": len(ids_a) - len(set(common) & ids_a)
        if classes is None else None,
        "only_b": len(idx_b.entity_ids) - len(common)
        if classes is None else None,
        "mean_jaccard": round(jac_sum / len(common), 6) if common else None,
        "compute_s": round(time.perf_counter() - t0, 4),
    }
    store = engine.registry.store
    if store.has_graph(ontology, version_a) and \
            store.has_graph(ontology, version_b):
        from ..ontology.delta import GraphDelta
        delta = GraphDelta.compute(store.load_graph(ontology, version_a),
                                   store.load_graph(ontology, version_b))
        summary["graph_delta"] = delta.stats()
    try:
        summary["lineage"] = store.load_metadata(
            ontology, version_b, model).get("lineage")
    except (OSError, ValueError):
        summary["lineage"] = None
    return out, summary


# --------------------------------------------------------------------- #
# 3. per-model comparison (/compare)
# --------------------------------------------------------------------- #
def _filtered_metrics(score_tails, score_heads, eval_triples: np.ndarray,
                      all_triples: np.ndarray, n_entities: int,
                      tick: Tick, base: float, span: float,
                      batch: int = 64) -> Dict[str, float]:
    """Chunked both-sides filtered ranking (same contract as
    ``kge.eval.rank_based_eval``), yielding through ``tick`` between
    chunks. ``score_*`` map (h, r) / (r, t) index arrays to
    (b, n_entities) score matrices."""
    from ..kge.eval import _ranks
    known_tails: Dict[tuple, set] = {}
    known_heads: Dict[tuple, set] = {}
    for h, r, t in all_triples:
        known_tails.setdefault((int(h), int(r)), set()).add(int(t))
        known_heads.setdefault((int(r), int(t)), set()).add(int(h))
    ranks = []
    m = eval_triples.shape[0]
    for start in range(0, m, batch):
        part = eval_triples[start:start + batch]
        h, r, t = part[:, 0], part[:, 1], part[:, 2]
        tail_scores = score_tails(h, r)
        mask = np.zeros((part.shape[0], n_entities), dtype=bool)
        for i, (hh, rr) in enumerate(zip(h, r)):
            for tt in known_tails.get((int(hh), int(rr)), ()):
                mask[i, tt] = True
        ranks.append(_ranks(tail_scores, t, mask))
        head_scores = score_heads(r, t)
        mask = np.zeros((part.shape[0], n_entities), dtype=bool)
        for i, (rr, tt) in enumerate(zip(r, t)):
            for hh in known_heads.get((int(rr), int(tt)), ()):
                mask[i, hh] = True
        ranks.append(_ranks(head_scores, h, mask))
        _tick(tick, base + span * min(1.0, (start + batch) / max(1, m)))
    all_ranks = np.concatenate(ranks) if ranks else np.array([1.0])
    out = {"mrr": float(np.mean(1.0 / all_ranks)),
           "mean_rank": float(np.mean(all_ranks))}
    for kk in (1, 3, 10):
        out[f"hits@{kk}"] = float(np.mean(all_ranks <= kk))
    return out


def model_compare(engine, ontology: str, version: str,
                  models: Sequence[str], sample: Optional[int] = None,
                  tick: Tick = None,
                  ) -> Tuple[List[List[Any]], Dict[str, Any]]:
    """Per-model eval metrics for one published version. Row shape:
    ``[model, metrics_dict]`` where ``metrics_dict`` carries
    mrr/mean_rank/hits@{1,3,10} plus ``method`` ("kge" exact scoring
    from stored params, "cosine" ranking over the served table),
    ``sample`` (eval triples used) and ``cached`` — or ``None`` with a
    ``note`` when the version has no stored parsed graph to rank
    against. The eval split is a seeded permutation of the release's
    triples, so every model of a version ranks the same triples and the
    stored cache stays honest."""
    store = engine.registry.store
    sample = None if sample is None else max(1, int(sample))
    out: List[List[Any]] = []
    summary: Dict[str, Any] = {"version": version, "computed": 0,
                               "cached": 0, "skipped": 0}
    if not store.has_graph(ontology, version):
        for m in models:
            out.append([m, None])
        summary["skipped"] = len(models)
        summary["note"] = (f"no parsed graph stored for "
                           f"{ontology}/{version}: nothing to rank against")
        _tick(tick, 1.0)
        return out, summary
    kg = store.load_graph(ontology, version)
    n_eval = len(kg.triples) if sample is None else min(sample,
                                                        len(kg.triples))
    perm = np.random.default_rng(0).permutation(len(kg.triples))
    eval_triples = np.asarray(kg.triples)[perm[:n_eval]]
    all_triples = np.asarray(kg.triples)
    span = 1.0 / max(1, len(models))
    for mi, m in enumerate(models):
        base = mi * span
        cached = store.has_eval(ontology, version, m)
        if cached:
            entry = store.load_eval(ontology, version, m)
            if entry.get("sample") == n_eval:
                out.append([m, {**entry["metrics"],
                                "method": entry["method"],
                                "sample": entry["sample"],
                                "cached": True}])
                summary["cached"] += 1
                _tick(tick, base + span)
                continue
        metrics, method = _eval_one(engine, store, ontology, version, m,
                                    kg, eval_triples, all_triples,
                                    tick, base, span)
        store.save_eval(ontology, version, m,
                        {"metrics": metrics, "method": method,
                         "sample": n_eval, "seed": 0})
        out.append([m, {**metrics, "method": method, "sample": n_eval,
                        "cached": False}])
        summary["computed"] += 1
        _tick(tick, base + span)
    return out, summary


def _eval_one(engine, store, ontology: str, version: str, model_name: str,
              kg, eval_triples: np.ndarray, all_triples: np.ndarray,
              tick: Tick, base: float, span: float
              ) -> Tuple[Dict[str, float], str]:
    """One model's metrics: exact KGE scoring when the stored params
    vocab matches the graph, else cosine ranking over the served table."""
    if store.has_params(ontology, version, model_name):
        try:
            params, vocab = store.load_params(ontology, version, model_name)
            if vocab.get("entity") == list(kg.entities):
                import jax.numpy as jnp
                from ..kge.base import make_model
                meta = store.load_metadata(ontology, version, model_name)
                dim = int(meta.get("hyperparameters", {}).get(
                    "dim", next(iter(params.values())).shape[-1]))
                model = make_model(model_name, kg.num_entities,
                                   kg.num_relations, dim=dim)
                metrics = _filtered_metrics(
                    lambda h, r: np.asarray(model.score_all_tails(
                        params, jnp.asarray(h), jnp.asarray(r))),
                    lambda r, t: np.asarray(model.score_all_heads(
                        params, jnp.asarray(r), jnp.asarray(t))),
                    eval_triples, all_triples, kg.num_entities,
                    tick, base, span)
                return metrics, "kge"
        except (KeyError, ValueError, TypeError):
            pass  # fall through to the served-table ranking
    # cosine ranking over the served table, aligned to graph entity order
    index = engine._index(ontology, model_name, version)
    rows = [index.resolve(e) for e in kg.entities]
    if any(r is None for r in rows):
        raise ValueError(
            f"served table for {ontology}/{version}/{model_name} does not "
            f"cover the stored graph entities; cannot rank")
    unit = index.unit_rows(np.asarray(rows, dtype=np.int64))
    sims = lambda idx_arr: unit[np.asarray(idx_arr, dtype=np.int64)] @ unit.T
    metrics = _filtered_metrics(lambda h, r: sims(h), lambda r, t: sims(t),
                                eval_triples, all_triples,
                                len(kg.entities), tick, base, span)
    return metrics, "cosine"
