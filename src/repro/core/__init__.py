# The paper's primary contribution: versioned KGE production + serving.
from .provenance import prov_record, validate_prov
from .registry import EmbeddingRegistry
from .serving import (BatchScheduler, ClosestConcept, EmbeddingIndex,
                      LRUIndexCache, SchedulerError, ServingEngine,
                      SimRequest, Ticket, TopKRequest)
from .updater import (PAPER_MODELS, FileReleaseChannel, ReleaseChannel,
                      SyntheticReleaseChannel, UpdatePlan, UpdateReport,
                      Updater, poll_loop)

__all__ = [
    "prov_record", "validate_prov", "EmbeddingRegistry",
    "BatchScheduler", "ClosestConcept", "EmbeddingIndex", "LRUIndexCache",
    "SchedulerError", "ServingEngine", "SimRequest", "Ticket", "TopKRequest",
    "PAPER_MODELS", "FileReleaseChannel", "ReleaseChannel",
    "SyntheticReleaseChannel", "UpdatePlan", "UpdateReport", "Updater",
    "poll_loop",
]
