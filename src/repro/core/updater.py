"""The update pipeline — the paper's core freshness mechanism.

  "an automated update mechanism that periodically downloads ontology
   releases from predefined URLs, computes checksums, and compares them with
   those of previously stored versions. If a change is detected, all
   embeddings are recomputed and made available."

Offline adaptation: a *release channel* is any callable returning the latest
(version_tag, KnowledgeGraph). ``FileReleaseChannel`` polls a directory of
OBO files (what the cron job's download step would produce);
``SyntheticReleaseChannel`` wraps the synthetic evolution generator for
tests/examples.

Delta-aware staging (PR 3) — consecutive ontology releases overlap almost
entirely, so "recompute everything" wastes nearly all of its work. The
pipeline is now explicit:

  checksum → delta → policy → train → publish → invalidate

``Updater.plan`` diffs the new release against the persisted parent graph
(``GraphDelta``) and picks a mode: **full** when there is no warm-startable
parent or the ``churn_fraction`` is at/above ``churn_threshold``,
**incremental** otherwise. Incremental training remaps the parent version's
full params onto the new vocabulary (surviving rows carried, new rows fresh,
removed rows dropped — including rdf2vec's walk-token vocabulary) and runs
with a reduced step budget (``warm_frac``). Every publish persists full
params + the parsed graph + lineage metadata, so warm-starting works across
process restarts.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..checkpoint import version_sort_key
from ..kge import (KGETrainer, TrainConfig, make_model, vocab_remap,
                   PAPER_DIM, PAPER_EPOCHS)
from ..data import corpus, skipgram_pairs, token_vocab
from ..ontology import GraphDelta, KnowledgeGraph, load_obo
from .registry import EmbeddingRegistry
from .serving import ServingEngine

#: the paper's six models
PAPER_MODELS = ("transe", "transr", "distmult", "hole", "boxe", "rdf2vec")


class ReleaseChannel:
    """Abstract release source: returns (version_tag, graph) of the latest."""

    name: str

    def latest(self) -> Tuple[str, KnowledgeGraph]:
        raise NotImplementedError


class FileReleaseChannel(ReleaseChannel):
    """Polls a directory of ``<version>.obo`` files — the on-disk mirror of
    GO's https://release.geneontology.org/ channel."""

    def __init__(self, name: str, directory: str | Path):
        self.name = name
        self.directory = Path(directory)

    def latest(self) -> Tuple[str, KnowledgeGraph]:
        # natural/date-aware ordering: '2024-10' is newer than '2024-9',
        # which plain lexicographic sort gets backwards
        releases = sorted(self.directory.glob("*.obo"),
                          key=lambda p: version_sort_key(p.stem))
        if not releases:
            raise FileNotFoundError(f"no releases in {self.directory}")
        path = releases[-1]
        return path.stem, load_obo(path)


class SyntheticReleaseChannel(ReleaseChannel):
    """In-memory channel over synthetic (version, graph) releases — what
    the evolution generator produces for tests, examples and benchmarks.
    ``bump`` publishes the next release to pollers."""

    def __init__(self, name: str, version: Optional[str] = None,
                 kg: Optional[KnowledgeGraph] = None):
        self.name = name
        self._version = version
        self._kg = kg

    def bump(self, version: str, kg: KnowledgeGraph) -> None:
        self._version, self._kg = version, kg

    def latest(self) -> Tuple[str, KnowledgeGraph]:
        if self._kg is None:
            raise LookupError(f"channel {self.name!r} has no release yet")
        return self._version, self._kg


@dataclasses.dataclass
class UpdatePlan:
    """The staged decision for one polling round, before any training."""

    ontology: str
    version: str
    checksum: str
    changed: bool
    mode: str                              # "noop" | "full" | "incremental"
    parent_version: Optional[str] = None
    delta: Optional[GraphDelta] = None
    reason: str = ""


@dataclasses.dataclass
class UpdateReport:
    ontology: str
    version: str
    checksum: str
    changed: bool
    trained_models: List[str]
    wall_s: float
    details: Dict[str, Any]
    mode: str = "noop"
    parent_version: Optional[str] = None
    delta: Optional[Dict[str, Any]] = None
    reason: str = ""


class Updater:
    """checksum → delta → policy → train → publish → invalidate."""

    def __init__(
        self,
        registry: EmbeddingRegistry,
        engine: Optional[ServingEngine] = None,
        models: Sequence[str] = PAPER_MODELS,
        dim: int = PAPER_DIM,
        train_cfg: Optional[TrainConfig] = None,
        steps_override: Optional[int] = None,   # tests/examples: cap work
        walks_per_entity: int = 10,
        walk_length: int = 4,
        churn_threshold: float = 0.25,
        warm_frac: float = 0.3,
    ):
        self.registry = registry
        self.engine = engine
        self.models = tuple(models)
        self.dim = dim
        self.train_cfg = train_cfg or TrainConfig(epochs=PAPER_EPOCHS)
        self.steps_override = steps_override
        self.walks_per_entity = walks_per_entity
        self.walk_length = walk_length
        #: go incremental only below this GraphDelta.churn_fraction;
        #: churn_threshold=0.0 disables warm starts entirely
        self.churn_threshold = churn_threshold
        #: incremental step/epoch budget as a fraction of the full budget
        self.warm_frac = warm_frac

    # ------------------------------------------------------------------ #
    def check(self, channel: ReleaseChannel) -> Tuple[bool, str, str, KnowledgeGraph]:
        """Returns (changed, version, checksum, graph)."""
        version, kg = channel.latest()
        checksum = kg.checksum()
        published = self.registry.published_checksum(channel.name)
        return checksum != published, version, checksum, kg

    def plan(self, channel: ReleaseChannel) -> Tuple[UpdatePlan, KnowledgeGraph]:
        """Stages checksum → delta → policy; no training happens here."""
        changed, version, checksum, kg = self.check(channel)
        ont = channel.name
        if not changed:
            return UpdatePlan(ont, version, checksum, False, "noop",
                              reason="checksum unchanged"), kg
        parent = self.registry.store.latest_version(ont)
        if parent is None:
            return UpdatePlan(ont, version, checksum, True, "full",
                              reason="no parent version"), kg
        if not self.registry.store.has_graph(ont, parent):
            return UpdatePlan(ont, version, checksum, True, "full", parent,
                              reason="parent graph not persisted"), kg
        prev_kg = self.registry.store.load_graph(ont, parent)
        delta = GraphDelta.compute(prev_kg, kg)
        churn = delta.churn_fraction
        if churn >= self.churn_threshold:
            mode = "full"
            reason = f"churn {churn:.4f} >= threshold {self.churn_threshold}"
        else:
            mode = "incremental"
            reason = f"churn {churn:.4f} < threshold {self.churn_threshold}"
        return UpdatePlan(ont, version, checksum, True, mode, parent, delta,
                          reason), kg

    # ------------------------------------------------------------------ #
    def run_once(self, channel: ReleaseChannel, seed: int = 0) -> UpdateReport:
        t0 = time.perf_counter()
        plan, kg = self.plan(channel)
        if not plan.changed:
            # report the real check/parse cost so poll-loop monitoring sees
            # what an unchanged poll actually spends
            return UpdateReport(plan.ontology, plan.version, plan.checksum,
                                False, [], time.perf_counter() - t0,
                                {}, mode="noop", reason=plan.reason)

        delta_stats = plan.delta.stats() if plan.delta is not None else None
        lineage = {"parent_version": plan.parent_version, "mode": plan.mode,
                   "delta": delta_stats}
        details: Dict[str, Any] = {}   # strictly per-model entries
        trained: List[str] = []
        labels = [kg.label_of(e) for e in kg.entities]
        for model_name in self.models:
            emb, stats, hypers, params, vocab = self._train_one(
                model_name, kg, seed, plan)
            self.registry.publish(
                channel.name, plan.version, model_name,
                kg.entities, labels, emb,
                ontology_checksum=plan.checksum,
                hyperparameters=hypers,
                train_stats={k: v for k, v in stats.items() if k != "losses"},
                params=params,
                params_vocab=vocab,
                lineage={**lineage, "mode": stats["mode"]},
            )
            trained.append(model_name)
            details[model_name] = {
                "final_loss": stats.get("final_loss"),
                "triples_per_s": stats.get("triples_per_s"),
                "wall_s": stats.get("wall_s"),
                "steps": stats.get("steps"),
                "mode": stats["mode"],
                "budget_frac": stats["budget_frac"],
                "step_budget_ratio": stats["step_budget_ratio"],
                "carried_rows": stats.get("carried_rows"),
            }
        # persist the parsed release so the *next* update can diff against
        # it (exact GraphDelta) even after a process restart
        self.registry.store.save_graph(channel.name, plan.version, kg)
        # seal AFTER every model is on disk: cross-process snapshot
        # watchers adopt a version only once it is sealed, so a multi-model
        # publish never becomes visible half-written
        self.registry.seal(channel.name, plan.version)
        if self.engine is not None:
            # atomic latest-pointer swap: in-flight queries pinned to the
            # old version finish consistently; new queries see `version`
            self.engine.invalidate(channel.name, plan.version)
        return UpdateReport(channel.name, plan.version, plan.checksum, True,
                            trained, time.perf_counter() - t0, details,
                            mode=plan.mode, parent_version=plan.parent_version,
                            delta=delta_stats, reason=plan.reason)

    # ------------------------------------------------------------------ #
    def _budget(self, budget_frac: float) -> Tuple[Optional[int], Optional[int]]:
        """(steps, epochs) for one training run at ``budget_frac``."""
        if self.steps_override is not None:
            return max(1, int(round(self.steps_override * budget_frac))), None
        if budget_frac >= 1.0:
            return None, None              # trainer default: cfg.epochs
        return None, max(1, int(round(self.train_cfg.epochs * budget_frac)))

    def _warm_start(self, trainer: KGETrainer, model_name: str,
                    plan: UpdatePlan, new_entity_vocab: Sequence[str],
                    new_relation_vocab: Sequence[str], seed: int):
        """(params, opt_state, carried_rows) from the parent snapshot, or
        None when the parent has no warm-startable params."""
        try:
            prev_params, prev_vocab = self.registry.get_params(
                plan.ontology, model_name, plan.parent_version)
        except KeyError:
            return None
        e_map = vocab_remap(prev_vocab.get("entity", []), new_entity_vocab)
        r_map = vocab_remap(prev_vocab.get("relation", []), new_relation_vocab)
        params, opt_state, carry = trainer.warm_init(
            prev_params, e_map, r_map, seed)
        if carry["tables_carried"] == 0:
            return None                    # nothing survived (e.g. dim change)
        return params, opt_state, carry

    def _train_one(self, model_name: str, kg: KnowledgeGraph, seed: int,
                   plan: UpdatePlan):
        cfg = dataclasses.replace(self.train_cfg, seed=seed)
        hypers = {"dim": self.dim, "epochs": cfg.epochs, "optimizer": cfg.optimizer,
                  "lr": cfg.lr, "batch_size": cfg.batch_size, "num_negs": cfg.num_negs}
        if model_name == "rdf2vec":
            walks, vocab_size, pad = corpus(
                kg, jax.random.key(seed),
                walks_per_entity=self.walks_per_entity, walk_length=self.walk_length,
            )
            pairs = skipgram_pairs(walks, window=2, pad_token=pad, seed=seed)
            trips = np.stack(
                [pairs[:, 0], np.zeros(len(pairs), dtype=np.int32), pairs[:, 1]], axis=1
            )
            model = make_model("rdf2vec", vocab_size, 1, dim=self.dim)
            # warm-start vocabulary = walk tokens (entities + relation
            # tokens + pad), matched by name across versions
            entity_vocab: List[str] = token_vocab(kg)
            relation_vocab: List[str] = []
            hypers.update({"walks_per_entity": self.walks_per_entity,
                           "walk_length": self.walk_length, "window": 2})
        else:
            trips = kg.triples
            model = make_model(model_name, kg.num_entities, kg.num_relations,
                               dim=self.dim)
            entity_vocab = list(kg.entities)
            relation_vocab = list(kg.relations)

        trainer = KGETrainer(model, cfg)
        warm = None
        if plan.mode == "incremental":
            warm = self._warm_start(trainer, model_name, plan,
                                    entity_vocab, relation_vocab, seed)
        budget_frac = self.warm_frac if warm is not None else 1.0
        steps, epochs = self._budget(budget_frac)
        if warm is not None:
            params0, opt_state0, carry = warm
            params, _, stats = trainer.fit(trips, params=params0,
                                           opt_state=opt_state0,
                                           epochs=epochs, steps=steps)
            stats["mode"] = "incremental"
            stats["carried_rows"] = carry["entity_carried"]
        else:
            params, _, stats = trainer.fit(trips, epochs=epochs, steps=steps)
            stats["mode"] = "full"
            stats["carried_rows"] = 0
        stats["budget_frac"] = budget_frac
        # nominal compute reduction (full steps / steps run) — NOT measured
        # wall-clock speedup, which bench_update.py measures honestly
        stats["step_budget_ratio"] = round(1.0 / max(budget_frac, 1e-9), 3)

        if model_name == "rdf2vec":
            emb = np.asarray(model.entity_embeddings(params))[: kg.num_entities]
        else:
            emb = np.asarray(model.entity_embeddings(params))
        params_np = {k: np.asarray(v) for k, v in params.items()}
        vocab = {"entity": entity_vocab, "relation": relation_vocab}
        return emb, stats, hypers, params_np, vocab


def poll_loop(
    updater: Updater,
    channels: Sequence[ReleaseChannel],
    iterations: int,
    on_report: Optional[Callable[[UpdateReport], None]] = None,
    base_seed: int = 0,
) -> List[UpdateReport]:
    """The cron-job equivalent: N polling rounds over all channels.

    Each round trains with its own seed (``base_seed + round``) — a fixed
    seed would make every retraining round draw identical walks/negatives.
    """
    reports = []
    for it in range(iterations):
        for ch in channels:
            rep = updater.run_once(ch, seed=base_seed + it)
            reports.append(rep)
            if on_report:
                on_report(rep)
    return reports
