"""The update pipeline — the paper's core freshness mechanism.

  "an automated update mechanism that periodically downloads ontology
   releases from predefined URLs, computes checksums, and compares them with
   those of previously stored versions. If a change is detected, all
   embeddings are recomputed and made available."

Offline adaptation: a *release channel* is any callable returning the latest
(version_tag, KnowledgeGraph). ``FileReleaseChannel`` polls a directory of
OBO files (what the cron job's download step would produce);
``SyntheticReleaseChannel`` wraps the synthetic evolution generator for
tests/examples. The checksum → retrain → publish logic is identical to the
paper's.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..checkpoint import version_sort_key
from ..kge import KGETrainer, TrainConfig, make_model, PAPER_DIM, PAPER_EPOCHS
from ..data import corpus, skipgram_pairs
from ..ontology import KnowledgeGraph, load_obo
from .registry import EmbeddingRegistry
from .serving import ServingEngine

#: the paper's six models
PAPER_MODELS = ("transe", "transr", "distmult", "hole", "boxe", "rdf2vec")


class ReleaseChannel:
    """Abstract release source: returns (version_tag, graph) of the latest."""

    name: str

    def latest(self) -> Tuple[str, KnowledgeGraph]:
        raise NotImplementedError


class FileReleaseChannel(ReleaseChannel):
    """Polls a directory of ``<version>.obo`` files — the on-disk mirror of
    GO's https://release.geneontology.org/ channel."""

    def __init__(self, name: str, directory: str | Path):
        self.name = name
        self.directory = Path(directory)

    def latest(self) -> Tuple[str, KnowledgeGraph]:
        # natural/date-aware ordering: '2024-10' is newer than '2024-9',
        # which plain lexicographic sort gets backwards
        releases = sorted(self.directory.glob("*.obo"),
                          key=lambda p: version_sort_key(p.stem))
        if not releases:
            raise FileNotFoundError(f"no releases in {self.directory}")
        path = releases[-1]
        return path.stem, load_obo(path)


@dataclasses.dataclass
class UpdateReport:
    ontology: str
    version: str
    checksum: str
    changed: bool
    trained_models: List[str]
    wall_s: float
    details: Dict[str, Any]


class Updater:
    """checksum-compare → retrain all models → publish → invalidate caches."""

    def __init__(
        self,
        registry: EmbeddingRegistry,
        engine: Optional[ServingEngine] = None,
        models: Sequence[str] = PAPER_MODELS,
        dim: int = PAPER_DIM,
        train_cfg: Optional[TrainConfig] = None,
        steps_override: Optional[int] = None,   # tests/examples: cap work
        walks_per_entity: int = 10,
        walk_length: int = 4,
    ):
        self.registry = registry
        self.engine = engine
        self.models = tuple(models)
        self.dim = dim
        self.train_cfg = train_cfg or TrainConfig(epochs=PAPER_EPOCHS)
        self.steps_override = steps_override
        self.walks_per_entity = walks_per_entity
        self.walk_length = walk_length

    # ------------------------------------------------------------------ #
    def check(self, channel: ReleaseChannel) -> Tuple[bool, str, str, KnowledgeGraph]:
        """Returns (changed, version, checksum, graph)."""
        version, kg = channel.latest()
        checksum = kg.checksum()
        published = self.registry.published_checksum(channel.name)
        return checksum != published, version, checksum, kg

    def run_once(self, channel: ReleaseChannel, seed: int = 0) -> UpdateReport:
        t0 = time.perf_counter()
        changed, version, checksum, kg = self.check(channel)
        if not changed:
            return UpdateReport(channel.name, version, checksum, False, [], 0.0, {})

        details: Dict[str, Any] = {}
        trained: List[str] = []
        labels = [kg.label_of(e) for e in kg.entities]
        for model_name in self.models:
            emb, stats, hypers = self._train_one(model_name, kg, seed)
            self.registry.publish(
                channel.name, version, model_name,
                kg.entities, labels, emb,
                ontology_checksum=checksum,
                hyperparameters=hypers,
                train_stats=stats,
            )
            trained.append(model_name)
            details[model_name] = {"final_loss": stats.get("final_loss"),
                                   "triples_per_s": stats.get("triples_per_s")}
        if self.engine is not None:
            # atomic latest-pointer swap: in-flight queries pinned to the
            # old version finish consistently; new queries see `version`
            self.engine.invalidate(channel.name, version)
        return UpdateReport(channel.name, version, checksum, True, trained,
                            time.perf_counter() - t0, details)

    # ------------------------------------------------------------------ #
    def _train_one(self, model_name: str, kg: KnowledgeGraph, seed: int):
        cfg = dataclasses.replace(self.train_cfg, seed=seed)
        hypers = {"dim": self.dim, "epochs": cfg.epochs, "optimizer": cfg.optimizer,
                  "lr": cfg.lr, "batch_size": cfg.batch_size, "num_negs": cfg.num_negs}
        if model_name == "rdf2vec":
            walks, vocab, pad = corpus(
                kg, jax.random.key(seed),
                walks_per_entity=self.walks_per_entity, walk_length=self.walk_length,
            )
            pairs = skipgram_pairs(walks, window=2, pad_token=pad, seed=seed)
            trips = np.stack(
                [pairs[:, 0], np.zeros(len(pairs), dtype=np.int32), pairs[:, 1]], axis=1
            )
            model = make_model("rdf2vec", vocab, 1, dim=self.dim)
            trainer = KGETrainer(model, cfg)
            params, _, stats = trainer.fit(trips, steps=self.steps_override)
            emb = np.asarray(model.entity_embeddings(params))[: kg.num_entities]
            hypers.update({"walks_per_entity": self.walks_per_entity,
                           "walk_length": self.walk_length, "window": 2})
        else:
            model = make_model(model_name, kg.num_entities, kg.num_relations, dim=self.dim)
            trainer = KGETrainer(model, cfg)
            params, _, stats = trainer.fit(kg.triples, steps=self.steps_override)
            emb = np.asarray(model.entity_embeddings(params))
        return emb, stats, hypers


def poll_loop(
    updater: Updater,
    channels: Sequence[ReleaseChannel],
    iterations: int,
    on_report: Optional[Callable[[UpdateReport], None]] = None,
) -> List[UpdateReport]:
    """The cron-job equivalent: N polling rounds over all channels."""
    reports = []
    for _ in range(iterations):
        for ch in channels:
            rep = updater.run_once(ch)
            reports.append(rep)
            if on_report:
                on_report(rep)
    return reports
