"""Versioned embedding registry — the publication side of Bio-KGvec2go.

Wraps the SnapshotStore with the paper's semantics:
  * embeddings are keyed (ontology, version, model);
  * each snapshot carries the entity-id list, labels, PROV metadata and the
    source ontology checksum;
  * ``latest`` resolves to the most recent version (the similarity / top-k
    endpoints always serve the latest, per the paper);
  * ``to_json`` reproduces the *download* endpoint payload: one JSON object
    mapping each class to its 200-dim float array.
"""
from __future__ import annotations

import datetime as _dt
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..checkpoint import SnapshotStore
from .provenance import prov_record, validate_prov


class EmbeddingRegistry:
    def __init__(self, root: str | Path):
        self.store = SnapshotStore(root)

    # ---------------------------- publish ------------------------------ #
    def publish(
        self,
        ontology: str,
        version: str,
        model_name: str,
        entity_ids: Sequence[str],
        labels: Sequence[str],
        embeddings: np.ndarray,
        ontology_checksum: str,
        hyperparameters: Dict[str, Any],
        train_stats: Optional[Dict[str, Any]] = None,
        generated_at: Optional[str] = None,
        params: Optional[Dict[str, np.ndarray]] = None,
        params_vocab: Optional[Dict[str, Sequence[str]]] = None,
        lineage: Optional[Dict[str, Any]] = None,
    ) -> None:
        """Publish one (ontology, version, model) snapshot.

        ``params``/``params_vocab`` (optional) persist the full model param
        pytree plus its row-name vocabularies so the *next* release can
        warm-start from this one, even across a process restart.
        ``lineage`` (optional) records how this snapshot was produced:
        ``{"parent_version", "mode", "delta": {...}}``.
        """
        assert embeddings.ndim == 2 and embeddings.shape[0] == len(entity_ids)
        generated_at = generated_at or _dt.datetime.now(_dt.timezone.utc).isoformat()
        prov = prov_record(
            ontology, version, ontology_checksum, model_name,
            hyperparameters, generated_at, train_stats,
        )
        meta = {
            "ontology": ontology,
            "version": version,
            "model": model_name,
            "dim": int(embeddings.shape[1]),
            "num_entities": int(embeddings.shape[0]),
            "ontology_checksum": ontology_checksum,
            "generated_at": generated_at,
            "prov": prov,
        }
        if lineage is not None:
            meta["lineage"] = lineage
        arrays = {
            "embeddings": np.asarray(embeddings, dtype=np.float32),
            "entity_ids": np.asarray(entity_ids, dtype=np.str_),
            "labels": np.asarray(labels, dtype=np.str_),
        }
        self.store.save(ontology, version, model_name, arrays, meta)
        if params is not None:
            self.store.save_params(ontology, version, model_name,
                                   {k: np.asarray(v) for k, v in params.items()},
                                   {k: list(v) for k, v in (params_vocab or {}).items()})

    # ----------------------------- read -------------------------------- #
    def get(
        self, ontology: str, model_name: str, version: Optional[str] = None
    ) -> Tuple[List[str], List[str], np.ndarray, Dict[str, Any]]:
        """Returns (entity_ids, labels, embeddings, metadata)."""
        version = version or self.store.latest_version(ontology)
        if version is None:
            raise KeyError(f"no published versions for ontology {ontology!r}")
        arrays, meta = self.store.load(ontology, version, model_name)
        if not validate_prov(meta.get("prov", {})):
            raise ValueError(f"corrupt PROV metadata for {ontology}/{version}/{model_name}")
        return (
            [str(x) for x in arrays["entity_ids"]],
            [str(x) for x in arrays["labels"]],
            arrays["embeddings"],
            meta,
        )

    def get_serving(
        self, ontology: str, model_name: str, version: Optional[str] = None
    ) -> Tuple[List[str], List[str], np.ndarray, np.ndarray, Dict[str, Any]]:
        """Serve-path load: ``(entity_ids, labels, table, norms, meta)``.

        When the raw mmap layout exists (every publish writes it), ``table``
        and ``norms`` are read-only ``np.memmap`` views — zero copies, pages
        shared across worker processes.  Pre-raw snapshots fall back to the
        ``.npz`` interchange format with norms computed on the spot; either
        way the (table, norms) pair is bit-identical."""
        version = version or self.store.latest_version(ontology)
        if version is None:
            raise KeyError(f"no published versions for ontology {ontology!r}")
        meta = self.store.load_metadata(ontology, version, model_name)
        if not validate_prov(meta.get("prov", {})):
            raise ValueError(
                f"corrupt PROV metadata for {ontology}/{version}/{model_name}")
        if self.store.has_raw(ontology, version, model_name):
            table, norms, header = self.store.open_table(
                ontology, version, model_name)
            if "sorted_labels" in header:
                # publish-time autocomplete sidecar: hand it to the index
                # so per-worker load skips the per-process label re-sort
                meta = dict(meta)
                meta["sorted_labels"] = header["sorted_labels"]
            return header["ids"], header["labels"], table, norms, meta
        arrays, _ = self.store.load(ontology, version, model_name)
        emb = np.asarray(arrays["embeddings"], dtype=np.float32)
        norms = np.linalg.norm(emb, axis=1).astype(np.float32)
        return ([str(x) for x in arrays["entity_ids"]],
                [str(x) for x in arrays["labels"]], emb, norms, meta)

    def seal(self, ontology: str, version: str) -> None:
        """Mark ``version`` fully published (all models written) — the
        atomic visibility point for cross-process snapshot watchers."""
        self.store.seal(ontology, version)

    def get_params(
        self, ontology: str, model_name: str, version: Optional[str] = None
    ) -> Tuple[Dict[str, np.ndarray], Dict[str, List[str]]]:
        """Full param pytree + row-name vocab of a published snapshot
        (raises if the snapshot was published without params)."""
        version = version or self.store.latest_version(ontology)
        if version is None or not self.store.has_params(ontology, version, model_name):
            raise KeyError(
                f"no warm-startable params for {ontology}/{version}/{model_name}")
        return self.store.load_params(ontology, version, model_name)

    def versions(self, ontology: str) -> List[str]:
        return self.store.versions(ontology)

    def models(self, ontology: str, version: Optional[str] = None) -> List[str]:
        version = version or self.store.latest_version(ontology)
        return [] if version is None else self.store.models(ontology, version)

    def published_checksum(self, ontology: str) -> Optional[str]:
        """Checksum of the ontology release behind the latest snapshots."""
        v = self.store.latest_version(ontology)
        if v is None:
            return None
        models = self.store.models(ontology, v)
        if not models:
            return None
        _, meta = self.store.load(ontology, v, models[0])
        return meta.get("ontology_checksum")

    # --------------------------- download ------------------------------ #
    def to_json(self, ontology: str, model_name: str, version: Optional[str] = None) -> str:
        """The paper's *download* payload: {class_id: [floats...]}, at
        full float32 precision — byte-identical to what ``get-vector``
        and the gateway's paginated/streamed download serve for the same
        class (the wire-fidelity contract; no endpoint-private rounding)."""
        ids, _, emb, _ = self.get(ontology, model_name, version)
        return json.dumps({i: [float(x) for x in v] for i, v in zip(ids, emb)})
