"""PROV-style metadata for published embedding snapshots.

The paper attaches PROV metadata to each Zenodo deposit 'describing the input
ontology, the KGE model used, and the corresponding hyperparameters'. We emit
a small PROV-JSON document (entity / activity / agent / wasGeneratedBy /
used) with exactly that content.
"""
from __future__ import annotations

from typing import Any, Dict

SOFTWARE_AGENT = "repro:bio-kgvec2go-jax"


def prov_record(
    ontology: str,
    ontology_version: str,
    ontology_checksum: str,
    model_name: str,
    hyperparameters: Dict[str, Any],
    generated_at: str,
    train_stats: Dict[str, Any] | None = None,
) -> Dict[str, Any]:
    ont_ent = f"repro:ontology/{ontology}/{ontology_version}"
    emb_ent = f"repro:embeddings/{ontology}/{ontology_version}/{model_name}"
    activity = f"repro:training/{ontology}/{ontology_version}/{model_name}"
    doc: Dict[str, Any] = {
        "prefix": {"repro": "https://bio.kgvec2go.org/repro#"},
        "entity": {
            ont_ent: {
                "prov:type": "repro:OntologyRelease",
                "repro:checksum_sha256": ontology_checksum,
                "repro:version": ontology_version,
            },
            emb_ent: {
                "prov:type": "repro:EmbeddingSnapshot",
                "repro:model": model_name,
                "repro:hyperparameters": hyperparameters,
            },
        },
        "activity": {
            activity: {
                "prov:type": "repro:KGETraining",
                "prov:endTime": generated_at,
            }
        },
        "agent": {SOFTWARE_AGENT: {"prov:type": "prov:SoftwareAgent"}},
        "wasGeneratedBy": {
            "_:g1": {"prov:entity": emb_ent, "prov:activity": activity}
        },
        "used": {"_:u1": {"prov:activity": activity, "prov:entity": ont_ent}},
        "wasAssociatedWith": {
            "_:a1": {"prov:activity": activity, "prov:agent": SOFTWARE_AGENT}
        },
    }
    if train_stats:
        doc["entity"][emb_ent]["repro:train_stats"] = {
            k: v for k, v in train_stats.items() if not isinstance(v, (list, dict))
        }
    return doc


def validate_prov(doc: Dict[str, Any]) -> bool:
    """Structural validation used by tests and the registry on load."""
    required = ("entity", "activity", "agent", "wasGeneratedBy", "used")
    if not all(k in doc for k in required):
        return False
    gen = next(iter(doc["wasGeneratedBy"].values()))
    used = next(iter(doc["used"].values()))
    return (
        gen["prov:entity"] in doc["entity"]
        and gen["prov:activity"] in doc["activity"]
        and used["prov:entity"] in doc["entity"]
        and used["prov:activity"] in doc["activity"]
    )
