"""Latency histograms for the serving/gateway metrics surface.

One fixed, log-spaced bucket layout shared by every histogram in the
process (Prometheus-style cumulative-friendly counts, but stored
per-bucket): upper bounds run 0.01 ms .. ~84 s at x2 per bucket, plus a
+Inf overflow bucket. Fixed buckets mean snapshots from different
routes, processes, or runs can be merged by adding counts, and p50/p99
are derivable from any snapshot without keeping raw samples.

Thread-safe: ``observe`` is called from gateway request threads and the
scheduler's flush loop concurrently.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence

#: bucket upper bounds in milliseconds: 0.01ms * 2^i, i = 0..23 (~84 s),
#: then +Inf. 25 integers per snapshot — cheap enough to ship in /stats.
BUCKET_BOUNDS_MS: List[float] = [0.01 * (2 ** i) for i in range(24)]


class LatencyHistogram:
    """Fixed log-spaced latency histogram with derivable percentiles."""

    __slots__ = ("_lock", "_counts", "count", "_sum_ms", "_min_ms", "_max_ms")

    def __init__(self):
        self._lock = threading.Lock()
        self._counts = [0] * (len(BUCKET_BOUNDS_MS) + 1)   # last = +Inf
        self.count = 0
        self._sum_ms = 0.0
        self._min_ms: Optional[float] = None
        self._max_ms: Optional[float] = None

    def observe(self, seconds: float) -> None:
        ms = max(seconds, 0.0) * 1e3
        i = 0
        for bound in BUCKET_BOUNDS_MS:
            if ms <= bound:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self.count += 1
            self._sum_ms += ms
            if self._min_ms is None or ms < self._min_ms:
                self._min_ms = ms
            if self._max_ms is None or ms > self._max_ms:
                self._max_ms = ms

    # ------------------------------------------------------------------ #
    @staticmethod
    def percentile_from(counts: Sequence[int], q: float) -> Optional[float]:
        """Derive the q-th percentile (0 < q < 100) from a bucket-count
        vector laid out like :data:`BUCKET_BOUNDS_MS` (+Inf tail). Linear
        interpolation inside the winning bucket; the overflow bucket
        reports its lower bound (the histogram's honest answer)."""
        total = sum(counts)
        if total == 0:
            return None
        target = total * q / 100.0
        seen = 0.0
        for i, c in enumerate(counts):
            if seen + c >= target and c > 0:
                if i >= len(BUCKET_BOUNDS_MS):          # +Inf bucket
                    return BUCKET_BOUNDS_MS[-1]
                lo = BUCKET_BOUNDS_MS[i - 1] if i else 0.0
                hi = BUCKET_BOUNDS_MS[i]
                frac = (target - seen) / c
                return lo + (hi - lo) * frac
            seen += c
        return BUCKET_BOUNDS_MS[-1]

    def percentile(self, q: float) -> Optional[float]:
        with self._lock:
            counts = list(self._counts)
        return self.percentile_from(counts, q)

    @staticmethod
    def merge_snapshots(snapshots: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
        """Merge ``snapshot()`` dicts from different histograms — routes,
        processes, or runs — into one snapshot of the union stream.  The
        fixed bucket layout is what makes this exact for counts and
        min/max/sum; p50/p99 are re-derived from the merged counts (bucket
        resolution, same as any single snapshot).  Empty input or
        all-empty snapshots merge to an all-zero snapshot."""
        counts = [0] * (len(BUCKET_BOUNDS_MS) + 1)
        count, sum_ms = 0, 0.0
        min_ms: Optional[float] = None
        max_ms: Optional[float] = None
        for s in snapshots:
            sc = s.get("bucket_counts") or []
            if len(sc) != len(counts):
                raise ValueError(
                    f"incompatible bucket layout: {len(sc)} buckets, "
                    f"expected {len(counts)}")
            for i, c in enumerate(sc):
                counts[i] += c
            count += s.get("count", 0)
            sum_ms += s.get("sum_ms") or 0.0
            for v in (s.get("min_ms"),):
                if v is not None and (min_ms is None or v < min_ms):
                    min_ms = v
            for v in (s.get("max_ms"),):
                if v is not None and (max_ms is None or v > max_ms):
                    max_ms = v
        out: Dict[str, Any] = {
            "count": count,
            "sum_ms": round(sum_ms, 4),
            "min_ms": None if min_ms is None else round(min_ms, 4),
            "max_ms": None if max_ms is None else round(max_ms, 4),
            "bucket_le_ms": [round(b, 5) for b in BUCKET_BOUNDS_MS] + ["inf"],
            "bucket_counts": counts,
        }
        for name, q in (("p50_ms", 50.0), ("p99_ms", 99.0)):
            p = LatencyHistogram.percentile_from(counts, q)
            out[name] = None if p is None else round(p, 4)
        return out

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able state: bucket bounds + counts (merge by adding
        counts), totals, and the derived p50/p99 for convenience."""
        with self._lock:
            counts = list(self._counts)
            out: Dict[str, Any] = {
                "count": self.count,
                "sum_ms": round(self._sum_ms, 4),
                "min_ms": None if self._min_ms is None
                else round(self._min_ms, 4),
                "max_ms": None if self._max_ms is None
                else round(self._max_ms, 4),
            }
        out["bucket_le_ms"] = [round(b, 5) for b in BUCKET_BOUNDS_MS] + ["inf"]
        out["bucket_counts"] = counts
        for name, q in (("p50_ms", 50.0), ("p99_ms", 99.0)):
            p = self.percentile_from(counts, q)
            out[name] = None if p is None else round(p, 4)
        return out
