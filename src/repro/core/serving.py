"""The Bio-KGvec2go serving subsystem.

Implements the paper's three API functionalities, in-process (the container
has no network; the Flask layer in the paper is a thin shim over exactly
these calls):

  * ``download``      — JSON payload of all class vectors for a version;
  * ``similarity``    — cosine similarity between two classes (ids or labels,
                        with case/whitespace normalization);
  * ``closest_concepts`` — top-k most similar classes, ranked table with
                        identifier, label, score and exploration URL.

Architecture (PR 1 hardening — see ROADMAP.md "Serving architecture"):

  ``EmbeddingIndex``   one (ontology, version, model) table, query-ready.
                       Top-k runs through the fused kernel dispatcher
                       (``repro.kernels.ops.topk_cosine``) with per-query
                       self-exclusion and k>N clamping *inside* the kernel —
                       sentinel rows are never surfaced.

  ``LRUIndexCache``    bounded LRU over built indices with hit/miss/eviction
                       counters, so a long-lived server over many
                       (ontology, model, version) combinations cannot OOM.

  ``ServingEngine``    resolves queries against an atomic per-ontology
                       *latest pointer*. Endpoints accept an optional
                       ``version`` for pinned reads; the updater's
                       ``invalidate`` swaps the pointer atomically, so
                       in-flight queries pinned to the old version finish
                       consistently while new queries see the new release.

  ``BatchScheduler``   groups concurrent top-k requests into micro-batches
                       per (ontology, model, version, k) with monotonically
                       increasing ticket IDs (never reset, so outstanding
                       tickets can't collide across flushes) and pads each
                       micro-batch to a power-of-two bucket so the kernel
                       retraces at most ~log2(max_batch) query shapes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from .registry import EmbeddingRegistry


def _norm_label(s: str) -> str:
    """The paper's 'automatic normalization of case and whitespace'."""
    return " ".join(s.strip().lower().split())


def _edit_distance_capped(a: str, b: str, cap: int) -> int:
    """Levenshtein with early exit once every band entry exceeds ``cap``."""
    if abs(len(a) - len(b)) > cap:
        return cap + 1
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        best = i
        for j, cb in enumerate(b, 1):
            c = min(prev[j] + 1, cur[j - 1] + 1,
                    prev[j - 1] + (ca != cb))
            cur.append(c)
            best = min(best, c)
        if best > cap:
            return cap + 1
        prev = cur
    return prev[-1]


@dataclasses.dataclass
class ClosestConcept:
    identifier: str
    label: str
    score: float
    url: str


class EmbeddingIndex:
    """One (ontology, version, model) embedding table, ready to query."""

    def __init__(self, entity_ids: Sequence[str], labels: Sequence[str],
                 embeddings: np.ndarray, url_prefix: str = "https://bio.kgvec2go.org/concept/",
                 use_pallas: Optional[bool] = None):
        self.entity_ids = list(entity_ids)
        self.labels = list(labels)
        self.url_prefix = url_prefix
        #: kernel backend: None = REPRO_USE_PALLAS env dispatch
        self.use_pallas = use_pallas
        emb = np.asarray(embeddings, dtype=np.float32)
        norms = np.linalg.norm(emb, axis=1, keepdims=True)
        self.embeddings = emb
        self.unit = emb / np.maximum(norms, 1e-12)
        # device-resident copy of the immutable table: converting (N, d)
        # per top-k call would dominate the serving hot path at paper scale
        self._unit_jnp = jnp.asarray(self.unit)
        self._id_to_row = {i: r for r, i in enumerate(self.entity_ids)}
        self._label_to_row: Dict[str, int] = {}
        for r, lbl in enumerate(self.labels):
            self._label_to_row.setdefault(_norm_label(lbl), r)
        #: sorted normalized labels for autocomplete (paper §6 future work)
        self._sorted_labels = sorted(self._label_to_row)

    @property
    def nbytes(self) -> int:
        return int(self.embeddings.nbytes + self.unit.nbytes)

    # ------------------------------------------------------------------ #
    def autocomplete(self, prefix: str, limit: int = 10) -> List[str]:
        """Concept labels starting with ``prefix`` (paper §6 future work)."""
        import bisect
        p = _norm_label(prefix)
        lo = bisect.bisect_left(self._sorted_labels, p)
        out = []
        for lbl in self._sorted_labels[lo:lo + max(limit * 4, limit)]:
            if not lbl.startswith(p):
                break
            out.append(self.labels[self._label_to_row[lbl]])
            if len(out) == limit:
                break
        return out

    def resolve_fuzzy(self, query: str, max_edits: int = 2
                      ) -> Optional[Tuple[int, str]]:
        """Typo-tolerant label match (paper §6 future work): the closest
        label within ``max_edits`` Levenshtein edits. Returns (row, label)
        or None. Exact matches short-circuit via resolve()."""
        q = _norm_label(query)
        best: Optional[Tuple[int, str]] = None
        best_d = max_edits + 1
        for lbl, row in self._label_to_row.items():
            # cheap pre-filters before the DP
            if abs(len(lbl) - len(q)) > max_edits:
                continue
            d = _edit_distance_capped(q, lbl, min(best_d - 1, max_edits))
            if d < best_d:
                best, best_d = (row, self.labels[row]), d
                if d == 1:
                    break
        return best

    # ------------------------------------------------------------------ #
    def resolve(self, query: str, fuzzy: bool = False) -> Optional[int]:
        if query in self._id_to_row:
            return self._id_to_row[query]
        row = self._label_to_row.get(_norm_label(query))
        if row is None and fuzzy:
            hit = self.resolve_fuzzy(query)
            return hit[0] if hit else None
        return row

    def vector(self, query: str) -> np.ndarray:
        row = self.resolve(query)
        if row is None:
            raise KeyError(f"unknown class {query!r}")
        return self.embeddings[row]

    def similarity(self, a: str, b: str) -> float:
        ra, rb = self.resolve(a), self.resolve(b)
        if ra is None or rb is None:
            missing = a if ra is None else b
            raise KeyError(f"unknown class {missing!r}")
        return float(np.dot(self.unit[ra], self.unit[rb]))

    def top_k(self, queries: Sequence[str], k: int = 10,
              exclude_self: bool = True) -> List[List[ClosestConcept]]:
        """Batched top-k closest concepts (the paper returns top 10)."""
        rows = []
        for q in queries:
            r = self.resolve(q)
            if r is None:
                raise KeyError(f"unknown class {q!r}")
            rows.append(r)
        return self.top_k_rows(rows, k, exclude_self=exclude_self)

    def top_k_rows(self, rows: Sequence[int], k: int = 10,
                   exclude_self: bool = True) -> List[List[ClosestConcept]]:
        """Top-k for already-resolved table rows.

        Self-exclusion and k>N clamping happen inside the kernel (per-query
        exclude operand + valid-count output), so results contain exactly
        ``min(k, N - exclude_self)`` real entries — no sentinel rows, no
        over-fetch-then-filter.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        rows = np.asarray(list(rows), dtype=np.int32)
        qvec = self.unit[rows]                                  # (Q, d)
        excl = rows if exclude_self else np.full(len(rows), -1, np.int32)
        from ..kernels import ops as kops
        scores, idx, valid = kops.topk_cosine(
            jnp.asarray(qvec), self._unit_jnp, int(k),
            exclude_rows=jnp.asarray(excl), use_pallas=self.use_pallas)
        scores, idx, valid = np.asarray(scores), np.asarray(idx), np.asarray(valid)
        out: List[List[ClosestConcept]] = []
        for qi in range(len(rows)):
            lst: List[ClosestConcept] = []
            for score, j in zip(scores[qi, :valid[qi]], idx[qi, :valid[qi]]):
                ident = self.entity_ids[int(j)]
                lst.append(ClosestConcept(ident, self.labels[int(j)],
                                          float(score), self.url_prefix + ident))
            out.append(lst)
        return out


class LRUIndexCache:
    """Bounded LRU of built ``EmbeddingIndex`` objects.

    Keyed (ontology, model, version). Each entry holds a full embedding
    table, so the bound is what keeps a long-lived server over many
    versions/models from growing without limit. Counters are cumulative.
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._data: "OrderedDict[Tuple[str, str, str], EmbeddingIndex]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Tuple[str, str, str]) -> Optional[EmbeddingIndex]:
        with self._lock:
            idx = self._data.get(key)
            if idx is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return idx

    def put(self, key: Tuple[str, str, str], index: EmbeddingIndex) -> None:
        with self._lock:
            self._data[key] = index
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key: Tuple[str, str, str]) -> bool:
        with self._lock:
            return key in self._data

    def keys(self):
        with self._lock:
            return list(self._data.keys())

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"size": len(self._data), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "bytes": sum(v.nbytes for v in self._data.values())}


class ServingEngine:
    """Serves published snapshots from an EmbeddingRegistry.

    Latest-version resolution goes through an atomic per-ontology pointer:
    ``invalidate`` (called by the updater after publishing) swaps the
    pointer, and already-built indices for the old version stay in the LRU
    until evicted — in-flight queries pinned to the old version finish
    consistently instead of racing a cache wipe.
    """

    def __init__(self, registry: EmbeddingRegistry, cache_capacity: int = 8,
                 use_pallas: Optional[bool] = None):
        self.registry = registry
        self.cache = LRUIndexCache(cache_capacity)
        self.use_pallas = use_pallas
        self._latest: Dict[str, str] = {}
        self._lock = threading.Lock()

    # ------------------------- version resolution ---------------------- #
    def latest_version(self, ontology: str) -> str:
        """The pinned latest version for ``ontology`` (resolved from the
        registry on first use, then only moved by ``invalidate``)."""
        with self._lock:
            v = self._latest.get(ontology)
            if v is None:
                v = self.registry.store.latest_version(ontology)
                if v is None:
                    raise KeyError(f"no published versions for {ontology!r}")
                self._latest[ontology] = v
            return v

    def _index(self, ontology: str, model: str,
               version: Optional[str] = None) -> EmbeddingIndex:
        version = version or self.latest_version(ontology)
        key = (ontology, model, version)
        idx = self.cache.get(key)
        if idx is None:
            ids, labels, emb, _ = self.registry.get(ontology, model, version)
            idx = EmbeddingIndex(ids, labels, emb, use_pallas=self.use_pallas)
            self.cache.put(key, idx)
        return idx

    def invalidate(self, ontology: str, new_version: Optional[str] = None
                   ) -> Optional[str]:
        """Atomic latest-pointer swap, called by the updater after a
        publish. Old-version indices are NOT dropped — version-pinned
        in-flight queries keep working; the LRU ages them out."""
        v = new_version or self.registry.store.latest_version(ontology)
        with self._lock:
            if v is None:
                self._latest.pop(ontology, None)
            else:
                self._latest[ontology] = v
        return v

    def cache_stats(self) -> Dict[str, int]:
        return self.cache.stats()

    # ------------------------- the three endpoints --------------------- #
    def download(self, ontology: str, model: str,
                 version: Optional[str] = None) -> str:
        return self.registry.to_json(ontology, model,
                                     version or self.latest_version(ontology))

    def similarity(self, ontology: str, model: str, a: str, b: str,
                   fuzzy: bool = False, version: Optional[str] = None) -> float:
        idx = self._index(ontology, model, version)
        if fuzzy:
            ra, rb = idx.resolve(a, fuzzy=True), idx.resolve(b, fuzzy=True)
            if ra is None or rb is None:
                raise KeyError(f"unknown class {a if ra is None else b!r}")
            return float(np.dot(idx.unit[ra], idx.unit[rb]))
        return idx.similarity(a, b)

    def closest_concepts(self, ontology: str, model: str, query: str,
                         k: int = 10, fuzzy: bool = False,
                         version: Optional[str] = None) -> List[ClosestConcept]:
        idx = self._index(ontology, model, version)
        if fuzzy:
            row = idx.resolve(query, fuzzy=True)
            if row is None:
                raise KeyError(f"unknown class {query!r}")
            query = idx.entity_ids[row]
        return idx.top_k([query], k)[0]

    # ---------------- paper §6 future work, implemented ---------------- #
    def autocomplete(self, ontology: str, model: str, prefix: str,
                     limit: int = 10, version: Optional[str] = None) -> List[str]:
        """Concept-label autocomplete."""
        return self._index(ontology, model, version).autocomplete(prefix, limit)


@dataclasses.dataclass
class TopKRequest:
    ontology: str
    model: str
    query: str
    k: int = 10
    version: Optional[str] = None    # None = pin to latest at submit time


def _bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch."""
    b = 1
    while b < n:
        b <<= 1
    return min(b, max_batch)


class BatchScheduler:
    """Groups concurrent top-k requests into micro-batched kernel calls.

    Replaces the seed's ``RequestBatcher`` with production semantics:

      * **monotonic tickets** — one global ``itertools.count``, never reset,
        so tickets held across flushes can't collide with new submissions
        (the old batcher restarted at 0 every flush);
      * **version pinning at submit** — each request resolves its serving
        version when enqueued, so an update landing between submit and
        flush doesn't change what an in-flight request sees;
      * **per-(ontology, model, version, k) queues** — each flushes as one
        or more batched kernel calls;
      * **power-of-two padding buckets** — micro-batches are padded up to
        the next power of two (≤ max_batch) by repeating the last query, so
        the jitted kernel sees at most ~log2(max_batch) distinct Q shapes
        instead of one per batch size;
      * **poison isolation** — an unknown query fails only its own ticket
        (recorded in ``errors``), not the whole batch.
    """

    def __init__(self, engine: ServingEngine, max_batch: int = 64,
                 max_errors: int = 1024):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        # buckets are powers of two capped at the caller's exact max_batch
        # (the cap bounds kernel batch memory; a non-power-of-two max_batch
        # costs at most one extra jitted shape for full batches)
        self.max_batch = max_batch
        self.max_errors = max_errors
        self._tickets = itertools.count()
        self._queues: Dict[Tuple[str, str, str, int],
                           List[Tuple[int, TopKRequest]]] = {}
        self._lock = threading.Lock()
        #: ticket -> error message for the most recent failed requests
        #: (bounded at ``max_errors``: oldest entries are dropped)
        self.errors: Dict[int, str] = {}
        self.stats = {"submitted": 0, "flushes": 0, "batches": 0,
                      "padded_queries": 0, "failed": 0}

    def _record_errors(self, errors: Dict[int, str]) -> None:
        """Merge under lock, keeping only the most recent max_errors."""
        self.errors.update(errors)
        self.stats["failed"] += len(errors)
        while len(self.errors) > self.max_errors:
            self.errors.pop(next(iter(self.errors)))

    def submit(self, req: TopKRequest) -> int:
        with self._lock:
            ticket = next(self._tickets)
            self.stats["submitted"] += 1
        try:
            version = req.version or self.engine.latest_version(req.ontology)
        except KeyError as e:
            # unknown ontology fails only this ticket, not the accept loop
            with self._lock:
                self._record_errors({ticket: str(e)})
            return ticket
        with self._lock:
            self._queues.setdefault(
                (req.ontology, req.model, version, req.k), []).append((ticket, req))
        return ticket

    def pending(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._queues.values())

    def flush(self) -> Dict[int, List[ClosestConcept]]:
        with self._lock:
            queues, self._queues = self._queues, {}
        results: Dict[int, List[ClosestConcept]] = {}
        errors: Dict[int, str] = {}
        n_batches = n_padded = 0
        for (ont, model, version, k), items in queues.items():
            # a broken queue (unpublished model, bad version, k < 1) fails
            # only its own tickets — other queues in this flush still serve
            try:
                index = self.engine._index(ont, model, version)
            except Exception as e:
                for ticket, _ in items:
                    errors[ticket] = str(e)
                continue
            for start in range(0, len(items), self.max_batch):
                chunk = items[start:start + self.max_batch]
                live: List[Tuple[int, int]] = []        # (ticket, row)
                for ticket, req in chunk:
                    row = index.resolve(req.query)
                    if row is None:
                        errors[ticket] = f"unknown class {req.query!r}"
                    else:
                        live.append((ticket, row))
                if not live:
                    continue
                rows = [r for _, r in live]
                bucket = _bucket_size(len(rows), self.max_batch)
                pad = bucket - len(rows)
                try:
                    batch_res = index.top_k_rows(rows + [rows[-1]] * pad, k)
                except Exception as e:
                    for ticket, _ in live:
                        errors[ticket] = str(e)
                    continue
                for (ticket, _), res in zip(live, batch_res):
                    results[ticket] = res
                n_batches += 1
                n_padded += pad
        with self._lock:
            self._record_errors(errors)
            self.stats["flushes"] += 1
            self.stats["batches"] += n_batches
            self.stats["padded_queries"] += n_padded
        return results
