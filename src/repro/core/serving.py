"""The Bio-KGvec2go serving subsystem.

Implements the paper's API functionalities, in-process (the container
has no network; the Flask layer in the paper is a thin shim over exactly
these calls):

  * ``download``      — JSON payload of all class vectors for a version;
  * ``similarity``    — cosine similarity between two classes (ids or labels,
                        with case/whitespace normalization);
  * ``closest_concepts`` — top-k most similar classes, ranked table with
                        identifier, label, score and exploration URL.

As of PR 4 the *public* surface is ``repro.api.Gateway``
(``engine.gateway()``): route dispatch, typed wire schema, structured
``ApiError`` codes, cursor-paginated download, and an async front end.
The ``ServingEngine`` endpoint methods below survive as thin deprecated
delegates; the scheduler additionally batches pair-similarity reads
(``SimRequest``) so the gateway's ``sim`` endpoint coalesces too.

Architecture (PR 1 hardening — see ROADMAP.md "Serving architecture"):

  ``EmbeddingIndex``   one (ontology, version, model) table, query-ready.
                       Top-k runs through the fused kernel dispatcher
                       (``repro.kernels.ops.topk_cosine``) with per-query
                       self-exclusion and k>N clamping *inside* the kernel —
                       sentinel rows are never surfaced.

  ``LRUIndexCache``    bounded LRU over built indices with hit/miss/eviction
                       counters, so a long-lived server over many
                       (ontology, model, version) combinations cannot OOM.

  ``ServingEngine``    resolves queries against an atomic per-ontology
                       *latest pointer*. Endpoints accept an optional
                       ``version`` for pinned reads; the updater's
                       ``invalidate`` swaps the pointer atomically, so
                       in-flight queries pinned to the old version finish
                       consistently while new queries see the new release.

  ``BatchScheduler``   the concurrent serving runtime (PR 2). ``submit``
                       returns a future-style ``Ticket``; a daemon flush
                       loop drains per-(ontology, model, version, k) queues
                       under a deadline policy — a queue flushes when its
                       oldest request has waited ``flush_after_ms`` OR it
                       reaches ``max_batch``, whichever comes first — so
                       many independent clients get cross-client batching
                       without any of them driving ``flush()`` themselves.
                       Ticket IDs stay monotonic (never reset), micro-
                       batches pad to power-of-two buckets, and a failed
                       request rejects only its own ticket.

  Device sharding      when built with a multi-device mesh, the index lays
                       its (N, d) table out ``P("data", None)`` across
                       devices and top-k runs through the sharded
                       kernel path (``kernels.ops.topk_cosine_sharded``):
                       local top-k per shard + global merge.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import itertools
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from .metrics import LatencyHistogram
from .registry import EmbeddingRegistry
# canonical normalization lives with the store so publish-time sidecars
# (sorted_labels) and serving agree; the old serving-local name survives
# for importers (tests, gateway helpers)
from ..checkpoint.store import norm_label as _norm_label


def _prefix_upper_bound(p: str) -> Optional[str]:
    """Smallest string greater than every string with prefix ``p`` — the
    exclusive upper bound of the prefix range in a sorted array.  None when
    no such string exists (p empty or all chars at the codepoint maximum),
    meaning the range extends to the end of the array."""
    for i in range(len(p) - 1, -1, -1):
        c = ord(p[i])
        if c < 0x10FFFF:
            return p[:i] + chr(c + 1)
    return None


def _edit_distance_capped(a: str, b: str, cap: int) -> int:
    """Levenshtein with early exit once every band entry exceeds ``cap``."""
    if abs(len(a) - len(b)) > cap:
        return cap + 1
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        best = i
        for j, cb in enumerate(b, 1):
            c = min(prev[j] + 1, cur[j - 1] + 1,
                    prev[j - 1] + (ca != cb))
            cur.append(c)
            best = min(best, c)
        if best > cap:
            return cap + 1
        prev = cur
    return prev[-1]


@dataclasses.dataclass
class ClosestConcept:
    identifier: str
    label: str
    score: float
    url: str


class EmbeddingIndex:
    """One (ontology, version, model) embedding table, ready to query.

    Zero-copy contract: ``embeddings`` may be a read-only ``np.memmap``
    view over the store's raw layout (``SnapshotStore.open_table``) and is
    kept as-is — never copied into a private array.  Normalization is
    lazy: per-row L2 norms come from the sidecar (``norms=``, also a
    memmap view) or are computed once here, and unit rows are produced on
    demand by ``unit_rows``.

    Scale-oblivious device residency (PR 8): top-k streams the host table
    through the kernel in fixed ``block_rows`` slabs with the norms folded
    into the in-kernel score (``kernels.ops.topk_cosine``), so there is no
    full-table device copy and *no* (N, d) unit array on either side —
    peak device allocation is O(block_rows·d + Q·k) regardless of N.  Host
    memory stays in the shared page cache, so worker processes serving the
    same snapshot pay for the table once.  With a multi-device mesh the
    raw rows + norms are laid out sharded instead (the residency there
    *is* the sharding) and each shard normalizes its blocks in-kernel.
    """

    def __init__(self, entity_ids: Sequence[str], labels: Sequence[str],
                 embeddings: np.ndarray, url_prefix: str = "https://bio.kgvec2go.org/concept/",
                 use_pallas: Optional[bool] = None, mesh=None,
                 norms: Optional[np.ndarray] = None,
                 block_rows: Optional[int] = None,
                 sorted_labels: Optional[Sequence[str]] = None):
        self.entity_ids = list(entity_ids)
        self.labels = list(labels)
        self.url_prefix = url_prefix
        #: kernel backend: None = REPRO_USE_PALLAS env dispatch
        self.use_pallas = use_pallas
        #: streaming slab size for the host→device top-k walk (None =
        #: kernels.ops.STREAM_BLOCK_ROWS)
        self.block_rows = block_rows
        emb = np.asarray(embeddings)
        if emb.dtype != np.float32:
            emb = emb.astype(np.float32)
        self.embeddings = emb
        if norms is None:
            norms = np.linalg.norm(emb, axis=1)
        self.norms = np.asarray(norms, dtype=np.float32)
        from ..kernels import ops as kops
        # only shard when the mesh actually has >1 device on the data axis;
        # otherwise the streaming host path below holds residency at
        # O(block) without any device table at all
        self.mesh = mesh if kops.mesh_data_shards(mesh) > 1 else None
        if self.mesh is not None:
            # raw rows + norms laid out P("data", …): each device holds an
            # (N/devices, d) block it normalizes in-kernel per tile —
            # no unit copy exists on any device
            (self._table_sharded, self._norms_sharded,
             self._n_real) = kops.shard_table_raw(emb, self.norms, self.mesh)
        else:
            self._table_sharded = self._norms_sharded = None
            self._n_real = emb.shape[0]
        self._id_to_row = {i: r for r, i in enumerate(self.entity_ids)}
        self._label_to_row: Dict[str, int] = {}
        for r, lbl in enumerate(self.labels):
            self._label_to_row.setdefault(_norm_label(lbl), r)
        #: sorted normalized labels for autocomplete (paper §6 future work).
        #: ``sorted_labels`` is the publish-time sidecar (store header);
        #: accepted only when consistent with this table's label set so a
        #: stale sidecar can never corrupt autocomplete.
        if (sorted_labels is not None
                and len(sorted_labels) == len(self._label_to_row)):
            self._sorted_labels = list(sorted_labels)
        else:
            self._sorted_labels = sorted(self._label_to_row)

    @property
    def nbytes(self) -> int:
        """Host bytes addressed by this index (table + norms). With an
        mmap-backed table these pages are shared and reclaimable, so this
        is an upper bound on private memory, not a measure of it."""
        return int(self.embeddings.nbytes + self.norms.nbytes)

    def unit_rows(self, rows) -> np.ndarray:
        """L2-normalized rows, computed on demand: bit-identical to
        slicing the eagerly-normalized full table (division is
        elementwise), without ever materializing a second (N, d) array on
        the host for the common small-batch case."""
        sub = np.asarray(self.embeddings[rows], dtype=np.float32)
        n = np.asarray(self.norms[rows], dtype=np.float32)
        return sub / np.maximum(n[..., None], 1e-12)

    @property
    def unit(self) -> np.ndarray:
        """Full normalized table, materialized per call — kept for
        callers/tests that want the whole matrix; hot paths use
        ``unit_rows`` or the streaming/sharded kernel paths."""
        return self.unit_rows(slice(None))

    def device_table_bytes(self) -> int:
        """Bytes of *table* data pinned on devices by this index: 0 on the
        streaming host path (the scale invariant the bench asserts — only
        transient O(block) slabs ever land on device), table + norms bytes
        when mesh-sharded (residency there is the sharding itself)."""
        if self._table_sharded is None:
            return 0
        return int(self._table_sharded.nbytes + self._norms_sharded.nbytes)

    # ------------------------------------------------------------------ #
    def autocomplete(self, prefix: str, limit: int = 10) -> List[str]:
        """Concept labels starting with ``prefix`` (paper §6 future work).

        Pure bisect range lookup on the sorted normalized labels: the
        matches are exactly ``[bisect_left(p), bisect_left(upper_bound(p))``
        — no scan, no window cap, O(log n + limit)."""
        p = _norm_label(prefix)
        lo = bisect.bisect_left(self._sorted_labels, p)
        ub = _prefix_upper_bound(p)
        hi = (len(self._sorted_labels) if ub is None
              else bisect.bisect_left(self._sorted_labels, ub, lo))
        return [self.labels[self._label_to_row[lbl]]
                for lbl in self._sorted_labels[lo:min(hi, lo + limit)]]

    def resolve_fuzzy(self, query: str, max_edits: int = 2
                      ) -> Optional[Tuple[int, str]]:
        """Typo-tolerant label match (paper §6 future work): the closest
        label within ``max_edits`` Levenshtein edits. Returns (row, label)
        or None. Exact matches short-circuit via resolve()."""
        q = _norm_label(query)
        best: Optional[Tuple[int, str]] = None
        best_d = max_edits + 1
        for lbl, row in self._label_to_row.items():
            # cheap pre-filters before the DP
            if abs(len(lbl) - len(q)) > max_edits:
                continue
            d = _edit_distance_capped(q, lbl, min(best_d - 1, max_edits))
            if d < best_d:
                best, best_d = (row, self.labels[row]), d
                if d == 1:
                    break
        return best

    # ------------------------------------------------------------------ #
    def resolve(self, query: str, fuzzy: bool = False) -> Optional[int]:
        if query in self._id_to_row:
            return self._id_to_row[query]
        row = self._label_to_row.get(_norm_label(query))
        if row is None and fuzzy:
            hit = self.resolve_fuzzy(query)
            return hit[0] if hit else None
        return row

    def vector(self, query: str) -> np.ndarray:
        row = self.resolve(query)
        if row is None:
            raise KeyError(f"unknown class {query!r}")
        return self.embeddings[row]

    def similarity(self, a: str, b: str) -> float:
        ra, rb = self.resolve(a), self.resolve(b)
        if ra is None or rb is None:
            # report EVERY unresolvable name, not just the first: a client
            # fixing one typo at a time is the paper's UX anti-pattern
            missing = [q for q, r in ((a, ra), (b, rb)) if r is None]
            raise KeyError(
                "unknown class(es): " + ", ".join(repr(m) for m in missing))
        ua, ub = self.unit_rows([ra, rb])
        return float(np.dot(ua, ub))

    def top_k(self, queries: Sequence[str], k: int = 10,
              exclude_self: bool = True) -> List[List[ClosestConcept]]:
        """Batched top-k closest concepts (the paper returns top 10)."""
        rows = []
        for q in queries:
            r = self.resolve(q)
            if r is None:
                raise KeyError(f"unknown class {q!r}")
            rows.append(r)
        return self.top_k_rows(rows, k, exclude_self=exclude_self)

    def top_k_rows(self, rows: Sequence[int], k: int = 10,
                   exclude_self: bool = True) -> List[List[ClosestConcept]]:
        """Top-k for already-resolved table rows.

        Self-exclusion and k>N clamping happen inside the kernel (per-query
        exclude operand + valid-count output), so results contain exactly
        ``min(k, N - exclude_self)`` real entries — no sentinel rows, no
        over-fetch-then-filter.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        rows = np.asarray(list(rows), dtype=np.int32)
        qvec = self.unit_rows(rows)                             # (Q, d)
        excl = rows if exclude_self else np.full(len(rows), -1, np.int32)
        from ..kernels import ops as kops
        if self.mesh is not None:
            scores, idx, valid = kops.topk_cosine_sharded(
                jnp.asarray(qvec), self._table_sharded, int(k),
                exclude_rows=jnp.asarray(excl), mesh=self.mesh,
                n_valid=self._n_real, use_pallas=self.use_pallas,
                norms=self._norms_sharded)
        else:
            # streaming host path: the raw table (np/memmap) is walked in
            # O(block_rows) slabs, norms folded in-kernel — no device copy
            scores, idx, valid = kops.topk_cosine(
                qvec, self.embeddings, int(k),
                exclude_rows=excl, use_pallas=self.use_pallas,
                norms=self.norms, block_rows=self.block_rows)
        scores, idx, valid = np.asarray(scores), np.asarray(idx), np.asarray(valid)
        out: List[List[ClosestConcept]] = []
        for qi in range(len(rows)):
            lst: List[ClosestConcept] = []
            for score, j in zip(scores[qi, :valid[qi]], idx[qi, :valid[qi]]):
                ident = self.entity_ids[int(j)]
                lst.append(ClosestConcept(ident, self.labels[int(j)],
                                          float(score), self.url_prefix + ident))
            out.append(lst)
        return out

    def knn_join_rows(self, rows: Sequence[int], k: int = 10,
                      exclude_self: bool = True, slab: int = 256):
        """All-pairs kNN join as a generator of ``(start, hits)`` slabs.

        Walks ``rows`` in fixed ``slab``-sized query blocks through the
        slab-iterated join kernel (streaming table residency on the host
        path), yielding each block's ``List[List[ClosestConcept]]`` as
        soon as it is scored.  Results are bit-identical to calling
        :meth:`top_k_rows` one row at a time; the generator boundary is
        where long-running jobs publish progress, observe cancellation,
        and yield the process to interactive traffic.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        rows = np.asarray(list(rows), dtype=np.int32)
        excl = rows if exclude_self else np.full(len(rows), -1, np.int32)
        from ..kernels import ops as kops
        if self.mesh is not None:
            # sharded tables stay device-resident: reuse the sharded
            # batch path per slab (same merge contract, same results)
            for start in range(0, len(rows), slab):
                part = rows[start:start + slab]
                yield start, self.top_k_rows(
                    part, k, exclude_self=exclude_self)
            return
        qvec = self.unit_rows(rows)
        for start, scores, idx, valid in kops.topk_cosine_join(
                qvec, self.embeddings, int(k), exclude_rows=excl,
                norms=self.norms, use_pallas=self.use_pallas,
                query_block_rows=slab, block_rows=self.block_rows):
            out: List[List[ClosestConcept]] = []
            for qi in range(scores.shape[0]):
                lst: List[ClosestConcept] = []
                for score, j in zip(scores[qi, :valid[qi]],
                                    idx[qi, :valid[qi]]):
                    ident = self.entity_ids[int(j)]
                    lst.append(ClosestConcept(
                        ident, self.labels[int(j)], float(score),
                        self.url_prefix + ident))
                out.append(lst)
            yield start, out


class LRUIndexCache:
    """Bounded LRU of built ``EmbeddingIndex`` objects.

    Keyed (ontology, model, version). Each entry holds a full embedding
    table, so the bound is what keeps a long-lived server over many
    versions/models from growing without limit. Counters are cumulative.
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._data: "OrderedDict[Tuple[str, str, str], EmbeddingIndex]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Tuple[str, str, str]) -> Optional[EmbeddingIndex]:
        with self._lock:
            idx = self._data.get(key)
            if idx is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return idx

    def put(self, key: Tuple[str, str, str], index: EmbeddingIndex) -> None:
        with self._lock:
            self._data[key] = index
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def pop_where(self, pred) -> int:
        """Drop every entry whose key satisfies ``pred`` (not counted as
        evictions — this is deliberate invalidation, not pressure).
        Returns how many were dropped.  Dropping an mmap-backed index
        releases the map once in-flight queries holding row views finish,
        at which point the snapshot files can be unlinked."""
        with self._lock:
            doomed = [k for k in self._data if pred(k)]
            for k in doomed:
                del self._data[k]
            return len(doomed)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key: Tuple[str, str, str]) -> bool:
        with self._lock:
            return key in self._data

    def keys(self):
        with self._lock:
            return list(self._data.keys())

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"size": len(self._data), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "bytes": sum(v.nbytes for v in self._data.values())}


class ServingEngine:
    """Serves published snapshots from an EmbeddingRegistry.

    Latest-version resolution goes through an atomic per-ontology pointer:
    ``invalidate`` (called by the updater after publishing) swaps the
    pointer, and already-built indices for the old version stay in the LRU
    until evicted — in-flight queries pinned to the old version finish
    consistently instead of racing a cache wipe.
    """

    def __init__(self, registry: EmbeddingRegistry, cache_capacity: int = 8,
                 use_pallas: Optional[bool] = None, mesh=None):
        self.registry = registry
        self.cache = LRUIndexCache(cache_capacity)
        self.use_pallas = use_pallas
        #: optional jax Mesh with a "data" axis — indices built by this
        #: engine shard their tables across it (see EmbeddingIndex)
        self.mesh = mesh
        self._latest: Dict[str, str] = {}
        self._lock = threading.Lock()
        #: callbacks fired (outside the lock) after every latest-pointer
        #: swap — the gateway subscribes so versions/lineage caches track
        #: publishes immediately
        self._invalidate_listeners: List = []
        self._default_gateway = None
        self._gw_lock = threading.Lock()

    # ------------------------- version resolution ---------------------- #
    def latest_version(self, ontology: str) -> str:
        """The pinned latest version for ``ontology`` (resolved from the
        registry on first use, then only moved by ``invalidate``)."""
        with self._lock:
            v = self._latest.get(ontology)
            if v is None:
                v = self.registry.store.latest_version(ontology)
                if v is None:
                    raise KeyError(f"no published versions for {ontology!r}")
                self._latest[ontology] = v
            return v

    def _index(self, ontology: str, model: str,
               version: Optional[str] = None) -> EmbeddingIndex:
        version = version or self.latest_version(ontology)
        key = (ontology, model, version)
        idx = self.cache.get(key)
        if idx is None:
            # serve path: zero-copy mmap view + sidecar norms when the raw
            # layout exists; .npz fallback for pre-raw snapshots
            ids, labels, table, norms, meta = self.registry.get_serving(
                ontology, model, version)
            idx = EmbeddingIndex(ids, labels, table, norms=norms,
                                 use_pallas=self.use_pallas, mesh=self.mesh,
                                 sorted_labels=meta.get("sorted_labels"))
            self.cache.put(key, idx)
        return idx

    def invalidate(self, ontology: str, new_version: Optional[str] = None
                   ) -> Optional[str]:
        """Atomic latest-pointer swap, called by the updater after a
        publish. Old-version indices are NOT dropped — version-pinned
        in-flight queries keep working; the LRU ages them out. Registered
        invalidate listeners (the gateway's versions/lineage caches) are
        notified after the swap.

        Before the swap, the new version's indices are warm-built for
        every model this engine is currently serving (anything cached for
        the ontology), so the first post-publish query never pays the
        index build — it hits a cache that already has the new version."""
        v = new_version or self.registry.store.latest_version(ontology)
        if v is not None:
            warm = {m for (o, m, _) in self.cache.keys() if o == ontology}
            for m in sorted(warm):
                try:
                    self._index(ontology, m, v)
                except Exception:
                    # a model absent from the new version fails on first
                    # query exactly as it did before warm-building existed
                    pass
        with self._lock:
            if v is None:
                self._latest.pop(ontology, None)
            else:
                self._latest[ontology] = v
            listeners = list(self._invalidate_listeners)
        for fn in listeners:
            try:
                fn(ontology, v)
            except Exception:
                pass     # a broken listener must not break the updater
        return v

    def drop_version(self, ontology: str, version: str) -> int:
        """Release every cached index for (ontology, \\*, version) so their
        mmap references drop and the snapshot's files can be unlinked once
        any in-flight queries finish (the maps close on GC). If the latest
        pointer names the dropped version it is cleared and re-resolves
        from the registry on next use. Returns the number of indices
        dropped."""
        n = self.cache.pop_where(
            lambda key: key[0] == ontology and key[2] == version)
        with self._lock:
            if self._latest.get(ontology) == version:
                self._latest.pop(ontology, None)
        return n

    def add_invalidate_listener(self, fn) -> None:
        """Register ``fn(ontology, new_version)`` to run after every
        latest-pointer swap."""
        with self._lock:
            self._invalidate_listeners.append(fn)

    def remove_invalidate_listener(self, fn) -> None:
        """Unregister a listener (no-op if absent) — a closed gateway
        must not stay reachable from, and mutated by, the engine."""
        with self._lock:
            try:
                self._invalidate_listeners.remove(fn)
            except ValueError:
                pass

    def cache_stats(self) -> Dict[str, int]:
        return self.cache.stats()

    # --------------------- the endpoints (deprecated) ------------------ #
    # These are thin delegates kept for pre-PR 4 callers. The public
    # surface is repro.api.Gateway — `engine.gateway()` — which routes
    # similarity-shaped reads through the BatchScheduler, returns typed
    # responses, and raises structured ApiErrors. The delegates translate
    # ApiError back to the legacy KeyError/ValueError contract.

    def gateway(self):
        """This engine's default :class:`repro.api.Gateway` (lazily
        built; synchronous flush mode — pair it with
        ``scheduler.start()`` or a dedicated Gateway for loop mode)."""
        gw = self._default_gateway
        if gw is None:
            from ..api.gateway import Gateway
            with self._gw_lock:
                if self._default_gateway is None:
                    self._default_gateway = Gateway(self)
                gw = self._default_gateway
        return gw

    def _legacy(self, call):
        from ..api.schema import ApiError
        try:
            return call()
        except ApiError as e:
            raise e.legacy() from None

    def download(self, ontology: str, model: str,
                 version: Optional[str] = None) -> str:
        """Full download payload as one JSON string.

        .. deprecated:: PR 4 — use ``engine.gateway().download(...)``,
           which is cursor-paginated and returns a typed ``DownloadPage``.
        """
        def run():
            gw = self.gateway()
            page = gw.download(ontology, model, version=version,
                               offset=0, limit=2048)
            rows = list(page.rows)
            while page.next_offset is not None:
                page = gw.download(ontology, model, version=page.version,
                                   offset=page.next_offset, limit=page.limit)
                rows.extend(page.rows)
            import json
            return json.dumps({ident: vec for ident, vec in rows})
        return self._legacy(run)

    def get_vector(self, ontology: str, model: str, query: str,
                   fuzzy: bool = False,
                   version: Optional[str] = None) -> np.ndarray:
        """The paper's ``get-vector`` endpoint (raw embedding row).

        .. deprecated:: PR 4 — use ``engine.gateway().get_vector(...)``,
           which returns a typed ``VectorResponse``.
        """
        return self._legacy(lambda: np.asarray(
            self.gateway().get_vector(ontology, model, query, fuzzy=fuzzy,
                                      version=version).vector,
            dtype=np.float32))

    def similarity(self, ontology: str, model: str, a: str, b: str,
                   fuzzy: bool = False, version: Optional[str] = None) -> float:
        """Cosine similarity between two classes.

        .. deprecated:: PR 4 — use ``engine.gateway().similarity(...)``.
           This delegate routes through the gateway (and therefore the
           BatchScheduler), then unwraps to the legacy float/KeyError
           contract.
        """
        return self._legacy(lambda: self.gateway().similarity(
            ontology, model, a, b, fuzzy=fuzzy, version=version).score)

    def closest_concepts(self, ontology: str, model: str, query: str,
                         k: int = 10, fuzzy: bool = False,
                         version: Optional[str] = None) -> List[ClosestConcept]:
        """Top-k closest concepts.

        .. deprecated:: PR 4 — use ``engine.gateway().closest_concepts``.
           This delegate routes through the gateway's batch-first path,
           then unwraps the typed response to the legacy list.
        """
        def run():
            resp = self.gateway().closest_concepts(
                ontology, model, query, k=k, fuzzy=fuzzy, version=version)
            return [ClosestConcept(h.identifier, h.label, h.score, h.url)
                    for h in resp.results]
        return self._legacy(run)

    def autocomplete(self, ontology: str, model: str, prefix: str,
                     limit: int = 10, version: Optional[str] = None) -> List[str]:
        """Concept-label autocomplete (paper §6 future work).

        .. deprecated:: PR 4 — use ``engine.gateway().autocomplete(...)``.
        """
        return self._legacy(lambda: self.gateway().autocomplete(
            ontology, model, prefix, limit=limit, version=version).completions)


@dataclasses.dataclass
class TopKRequest:
    ontology: str
    model: str
    query: str
    k: int = 10
    version: Optional[str] = None    # None = pin to latest at submit time
    fuzzy: bool = False              # typo-tolerant query resolution
    #: per-request deadline budget in seconds (None = no deadline). A
    #: ticket still queued past submit+budget is rejected at flush time
    #: *before* any kernel work — its client already gave up.
    budget_s: Optional[float] = None


@dataclasses.dataclass
class SimRequest:
    """A pair-similarity read routed through the scheduler (PR 4): many
    concurrent ``sim`` calls against the same (ontology, model, version)
    coalesce into one vectorized pairwise-dot batch instead of each
    taking a private index lookup."""
    ontology: str
    model: str
    a: str
    b: str
    fuzzy: bool = False
    version: Optional[str] = None
    budget_s: Optional[float] = None  # same semantics as TopKRequest


#: queue-key slot marking pair-similarity queues (top-k queues use their
#: real k >= 1, so -1 can never collide)
_SIM_K = -1


def _bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch."""
    b = 1
    while b < n:
        b <<= 1
    return min(b, max_batch)


class SchedulerError(RuntimeError):
    """Raised by ``Ticket.result()`` when the request failed (unknown
    query/ontology/model/version, bad k, or a kernel error).

    ``code`` / ``details`` carry the structured cause when the scheduler
    knows it (stable ApiError codes — see ``repro.api.schema``), e.g.
    ``code="UNKNOWN_CLASS", details={"missing": [...]}`` with *every*
    unresolvable name; both are None/{} for unclassified faults.
    """

    def __init__(self, message: str, code: Optional[str] = None,
                 details: Optional[Dict] = None):
        super().__init__(message)
        self.code = code
        self.details = dict(details or {})


@functools.total_ordering
class Ticket:
    """Future-style handle for one submitted top-k request.

    Resolved exactly once, by whichever flush (background loop or a manual
    ``flush()``) executes its batch. Interoperates with plain ints — hash,
    equality and ordering go through ``id`` — so the ticket-id-keyed dicts
    returned by ``flush()`` and ``scheduler.errors`` accept Ticket objects
    directly as keys.
    """

    __slots__ = ("id", "version", "created", "deadline", "_event", "_result",
                 "_error", "_error_code", "_error_details", "_cb_lock",
                 "_callbacks")

    def __init__(self, tid: int, version: Optional[str] = None):
        self.id = tid
        #: serving version pinned at submit time (None if submit failed
        #: before the version could be resolved)
        self.version = version
        #: monotonic submit timestamp — the anchor for the scheduler's
        #: submit->resolve latency histogram
        self.created = time.monotonic()
        #: absolute monotonic deadline (None = no budget): past it the
        #: flush loop rejects instead of executing — see TopKRequest.budget_s
        self.deadline: Optional[float] = None
        self._event = threading.Event()
        self._result = None          # List[ClosestConcept] or float (sim)
        self._error: Optional[str] = None
        self._error_code: Optional[str] = None
        self._error_details: Optional[Dict] = None
        self._cb_lock = threading.Lock()
        self._callbacks: List = []

    # --------------------------- future API ---------------------------- #
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until resolved; raises SchedulerError if the request
        failed, TimeoutError if unresolved after ``timeout`` seconds."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket {self.id} unresolved after {timeout}s")
        if self._error is not None:
            raise SchedulerError(self._error, self._error_code,
                                 self._error_details)
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[str]:
        """Block until resolved; the error message, or None on success."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket {self.id} unresolved after {timeout}s")
        return self._error

    def add_done_callback(self, fn) -> None:
        """Call ``fn(self)`` once the ticket resolves — immediately if it
        already has. Fires on whichever thread resolves the ticket, so
        callbacks must be cheap and loop-safe (the async front end posts
        through ``loop.call_soon_threadsafe``). Exceptions are swallowed:
        a broken callback must not poison the flush loop."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        self._run_callback(fn)

    def _run_callback(self, fn) -> None:
        try:
            fn(self)
        except Exception:
            # swallowing is the add_done_callback contract: a broken
            # callback must not poison the flush loop that resolved us
            pass

    def _fire_callbacks(self) -> None:
        with self._cb_lock:
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            self._run_callback(fn)

    # --------------------- scheduler-internal ----------------------- #
    def _resolve(self, result) -> bool:
        """Returns False if the ticket was already resolved (never expected;
        the stress suite asserts the resolved counter stays exact)."""
        if self._event.is_set():
            return False
        self._result = result
        with self._cb_lock:
            self._event.set()
        self._fire_callbacks()
        return True

    def _reject(self, message: str, code: Optional[str] = None,
                details: Optional[Dict] = None) -> bool:
        if self._event.is_set():
            return False
        self._error = message
        self._error_code = code
        self._error_details = details
        with self._cb_lock:
            self._event.set()
        self._fire_callbacks()
        return True

    # ---------------------------- int interop --------------------------- #
    def __int__(self) -> int:
        return self.id

    __index__ = __int__

    def __hash__(self) -> int:
        return hash(self.id)

    def __eq__(self, other):
        if isinstance(other, Ticket):
            return self.id == other.id
        if isinstance(other, int):
            return self.id == other
        return NotImplemented

    def __lt__(self, other):
        if isinstance(other, Ticket):
            return self.id < other.id
        if isinstance(other, int):
            return self.id < other
        return NotImplemented

    def __repr__(self) -> str:
        if not self.done():
            state = "pending"
        else:
            state = "failed" if self._error is not None else "done"
        return f"Ticket({self.id}, {state})"


class BatchScheduler:
    """The concurrent serving runtime: groups top-k requests from many
    client threads into micro-batched kernel calls.

    ``submit`` returns a future-style ``Ticket``; results come back either
    through the background flush loop (``flush_after_ms``/``start``) with
    clients blocking on ``ticket.result()``, or through a caller-driven
    synchronous ``flush()`` — both resolve every drained ticket exactly
    once. Semantics:

      * **monotonic tickets** — one global ``itertools.count``, never reset,
        so tickets held across flushes can't collide with new submissions;
      * **version pinning at submit** — each request resolves its serving
        version when enqueued, so an update landing between submit and
        flush doesn't change what an in-flight request sees;
      * **per-(ontology, model, version, k) queues** — each flushes as one
        or more batched kernel calls;
      * **deadline policy** — with the flush loop running, a queue is
        drained when its oldest request has waited ``flush_after_ms`` OR
        the queue has reached ``max_batch`` queries, whichever comes
        first: full batches flush immediately, stragglers wait at most one
        deadline;
      * **power-of-two padding buckets** — micro-batches are padded up to
        the next power of two (≤ max_batch) by repeating the last query, so
        the jitted kernel sees at most ~log2(max_batch) distinct Q shapes
        instead of one per batch size;
      * **poison isolation** — a failed request (unknown query, broken
        queue, kernel error) rejects only its own ticket (recorded in
        ``errors``), never the whole batch.
    """

    def __init__(self, engine: ServingEngine, max_batch: int = 64,
                 max_errors: int = 1024,
                 flush_after_ms: Optional[float] = None,
                 max_pending: Optional[int] = None,
                 default_budget_s: Optional[float] = None,
                 overload_retry_after_s: Optional[float] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if flush_after_ms is not None and flush_after_ms < 0:
            raise ValueError(f"flush_after_ms must be >= 0, got {flush_after_ms}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.engine = engine
        #: admission control: once this many tickets are queued, further
        #: submits are fast-rejected with code OVERLOADED instead of
        #: growing the backlog without bound (None = unbounded intake)
        self.max_pending = max_pending
        #: deadline budget applied when the request carries none
        self.default_budget_s = default_budget_s
        #: retry hint attached to OVERLOADED rejects; default derives from
        #: the flush cadence (a couple of flush periods usually clears a
        #: bounded backlog)
        self.overload_retry_after_s = overload_retry_after_s
        # buckets are powers of two capped at the caller's exact max_batch
        # (the cap bounds kernel batch memory; a non-power-of-two max_batch
        # costs at most one extra jitted shape for full batches)
        self.max_batch = max_batch
        self.max_errors = max_errors
        self.flush_after_ms = flush_after_ms
        self._tickets = itertools.count()
        self._queues: Dict[Tuple[str, str, str, int],
                           List[Tuple[Ticket, TopKRequest]]] = {}
        #: first-enqueue monotonic time per live queue (deadline anchor)
        self._born: Dict[Tuple[str, str, str, int], float] = {}
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        #: ticket id -> error message for the most recent failed requests
        #: (bounded at ``max_errors``: oldest entries are dropped)
        self.errors: Dict[int, str] = {}
        #: submit->resolve latency over every ticket (success or reject) —
        #: the serving-side histogram the gateway ships in /stats
        self.latency = LatencyHistogram()
        self.stats = {"submitted": 0, "resolved": 0, "flushes": 0,
                      "loop_flushes": 0, "deadline_flushes": 0,
                      "full_flushes": 0, "batches": 0, "sim_batches": 0,
                      "padded_queries": 0, "failed": 0,
                      # admission control / deadline accounting:
                      # rejected_overloaded = fast-rejects at intake,
                      # expired = deadline passed while queued (rejected at
                      # flush, zero kernel work), skipped_resolved = already
                      # resolved when the flush reached them (also skipped)
                      "rejected_overloaded": 0, "expired": 0,
                      "skipped_resolved": 0}
        if flush_after_ms is not None:
            self.start()

    # ------------------------------ intake ------------------------------ #
    def _record_errors_locked(self, errors: Dict[int, str]) -> None:
        """Merge into the error ring, keeping only the most recent
        ``max_errors``.  Caller holds ``self._lock`` (the ``_locked``
        suffix is the BIO001 contract for that)."""
        self.errors.update(errors)
        self.stats["failed"] += len(errors)
        while len(self.errors) > self.max_errors:
            self.errors.pop(next(iter(self.errors)))

    def _observe_latency(self, ticket: Ticket) -> None:
        self.latency.observe(time.monotonic() - ticket.created)

    def _reject_at_submit(self, ticket: Ticket, msg: str,
                          code: Optional[str] = None,
                          details: Optional[Dict] = None) -> Ticket:
        with self._lock:
            self._record_errors_locked({ticket.id: msg})
            if ticket._reject(msg, code, details):
                self.stats["resolved"] += 1
                self._observe_latency(ticket)
        return ticket

    def submit(self, req) -> Ticket:
        """Enqueue a :class:`TopKRequest` or :class:`SimRequest`; returns
        its future-style Ticket (top-k tickets resolve to a ranked
        ``List[ClosestConcept]``, sim tickets to a float score)."""
        with self._lock:
            tid = next(self._tickets)
            self.stats["submitted"] += 1
            # admission control *before* any registry/index work: rejecting
            # must stay cheap precisely when the scheduler is busiest
            if self.max_pending is not None and \
                    sum(len(v) for v in self._queues.values()) \
                    >= self.max_pending:
                self.stats["rejected_overloaded"] += 1
                overloaded = True
            else:
                overloaded = False
        if overloaded:
            return self._reject_at_submit(
                Ticket(tid),
                f"scheduler at capacity ({self.max_pending} pending)",
                "OVERLOADED",
                {"max_pending": self.max_pending,
                 "retry_after_s": self._retry_after_s()})
        try:
            version = req.version or self.engine.latest_version(req.ontology)
        except Exception as e:
            # unknown ontology — or any registry fault — fails only this
            # ticket, not the accept loop (and keeps resolved == submitted)
            code = "UNKNOWN_ONTOLOGY" if isinstance(e, KeyError) else None
            return self._reject_at_submit(
                Ticket(tid), str(e), code,
                {"ontology": req.ontology} if code else None)
        ticket = Ticket(tid, version=version)
        budget = getattr(req, "budget_s", None)
        if budget is None:
            budget = self.default_budget_s
        if budget is not None:
            ticket.deadline = ticket.created + budget
        if isinstance(req, SimRequest):
            key = (req.ontology, req.model, version, _SIM_K)
        else:
            # validate k at intake: a k < 1 (especially k == _SIM_K) must
            # never reach the queue key space — it would land top-k
            # requests in a sim queue and poison its coalesced peers
            if isinstance(req.k, bool) or not isinstance(req.k, int) \
                    or req.k < 1:
                return self._reject_at_submit(
                    ticket, f"k must be >= 1, got {req.k!r}", "BAD_REQUEST")
            key = (req.ontology, req.model, version, req.k)
        with self._cond:
            if self._stopping:
                stopped = True       # reject outside the lock hold below
            else:
                stopped = False
                q = self._queues.setdefault(key, [])
                q.append((ticket, req))
                self._born.setdefault(key, time.monotonic())
                # wake the loop for a brand-new deadline or a full batch; a
                # queue that's merely growing keeps its existing wake-up time
                if self._thread is not None and (
                        len(q) == 1 or len(q) >= self.max_batch):
                    self._cond.notify()
        if stopped:
            # after stop() nothing drains the queues: enqueueing would
            # strand the ticket forever, so refuse it (executor-shutdown
            # semantics; start() re-opens intake)
            return self._reject_at_submit(ticket, "scheduler is stopped",
                                          "SHUTTING_DOWN")
        return ticket

    def _retry_after_s(self) -> float:
        """Retry hint for OVERLOADED rejects: the configured value, else a
        couple of flush periods (a bounded backlog clears in about one)."""
        if self.overload_retry_after_s is not None:
            return float(self.overload_retry_after_s)
        return max(0.05, 2.0 * (self.flush_after_ms or 50.0) / 1e3)

    def accepting(self) -> bool:
        """False once stop() has closed intake (start() re-opens it)."""
        with self._lock:
            return not self._stopping

    def pending(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._queues.values())

    # ----------------------------- execution ---------------------------- #
    def _run_queues(self, queues: Dict[Tuple[str, str, str, int],
                                       List[Tuple[Ticket, TopKRequest]]],
                    collect: bool = True) -> Dict[int, List[ClosestConcept]]:
        """Execute drained queues (no scheduler lock held): batch, call the
        kernel, resolve every ticket exactly once. Returns {ticket id:
        result} for the successful tickets — unless ``collect`` is False
        (the background loop's path, where clients read their Tickets and
        the dict would be allocated only to be discarded)."""
        results: Dict[int, List[ClosestConcept]] = {}
        errors: Dict[int, str] = {}
        n_batches = n_padded = n_resolved = n_sim = 0
        n_expired = n_skipped = 0

        def reject(ticket: Ticket, msg: str, code: Optional[str] = None,
                   details: Optional[Dict] = None) -> None:
            nonlocal n_resolved
            if ticket._reject(msg, code, details):
                errors[ticket.id] = msg
                n_resolved += 1
                self._observe_latency(ticket)

        for (ont, model, version, k), items in queues.items():
            # drop dead weight *before* index build or kernel work: tickets
            # already resolved elsewhere, and tickets whose deadline budget
            # expired while queued — their clients have already received
            # TIMEOUT (e.g. the AsyncGateway call_later expiry), so
            # executing them would burn kernel time on answers nobody reads
            now = time.monotonic()
            fresh: List[Tuple[Ticket, TopKRequest]] = []
            for ticket, req in items:
                if ticket.done():
                    n_skipped += 1
                elif ticket.deadline is not None and now >= ticket.deadline:
                    n_expired += 1
                    reject(ticket,
                           f"deadline budget exhausted after "
                           f"{now - ticket.created:.3f}s in queue", "TIMEOUT",
                           {"queued_s": now - ticket.created})
                else:
                    fresh.append((ticket, req))
            items = fresh
            if not items:
                continue
            # a broken queue (unpublished model, bad version, k < 1) fails
            # only its own tickets — other queues in this flush still serve
            try:
                index = self.engine._index(ont, model, version)
            except Exception as e:
                # can't distinguish unknown model from unknown version at
                # this depth — the gateway classifies both pre-submit
                for ticket, _ in items:
                    reject(ticket, str(e))
                continue
            try:
                if k == _SIM_K:
                    # pair-similarity queue: one vectorized pairwise-dot
                    # per chunk instead of a private lookup per request
                    for start in range(0, len(items), self.max_batch):
                        chunk = items[start:start + self.max_batch]
                        live: List[Tuple[Ticket, int, int]] = []
                        for ticket, req in chunk:
                            try:
                                ra = index.resolve(req.a, fuzzy=req.fuzzy)
                                rb = index.resolve(req.b, fuzzy=req.fuzzy)
                            except Exception as e:
                                reject(ticket,
                                       f"bad query pair ({req.a!r}, {req.b!r})"
                                       f": {e}", "BAD_REQUEST")
                                continue
                            missing = [q for q, r in ((req.a, ra), (req.b, rb))
                                       if r is None]
                            if missing:
                                # report the FULL list of unresolvable names
                                reject(ticket, "unknown class(es): " +
                                       ", ".join(repr(m) for m in missing),
                                       "UNKNOWN_CLASS", {"missing": missing})
                            else:
                                live.append((ticket, ra, rb))
                        if not live:
                            continue
                        ua = index.unit_rows([ra for _, ra, _ in live])
                        ub = index.unit_rows([rb for _, _, rb in live])
                        scores = np.einsum("ij,ij->i", ua, ub)
                        for (ticket, _, _), s in zip(live, scores):
                            if collect:
                                results[ticket.id] = float(s)
                            if ticket._resolve(float(s)):
                                n_resolved += 1
                                self._observe_latency(ticket)
                        n_batches += 1
                        n_sim += 1
                    continue
                for start in range(0, len(items), self.max_batch):
                    chunk = items[start:start + self.max_batch]
                    live: List[Tuple[Ticket, int]] = []     # (ticket, row)
                    for ticket, req in chunk:
                        # a malformed query (e.g. None) fails alone too
                        try:
                            row = index.resolve(req.query, fuzzy=req.fuzzy)
                        except Exception as e:
                            reject(ticket, f"bad query {req.query!r}: {e}",
                                   "BAD_REQUEST")
                            continue
                        if row is None:
                            reject(ticket, f"unknown class {req.query!r}",
                                   "UNKNOWN_CLASS", {"missing": [req.query]})
                        else:
                            live.append((ticket, row))
                    if not live:
                        continue
                    rows = [r for _, r in live]
                    bucket = _bucket_size(len(rows), self.max_batch)
                    pad = bucket - len(rows)
                    try:
                        batch_res = index.top_k_rows(rows + [rows[-1]] * pad, k)
                    except Exception as e:
                        code = "BAD_REQUEST" if isinstance(e, ValueError) \
                            else None
                        for ticket, _ in live:
                            reject(ticket, str(e), code)
                        continue
                    for (ticket, _), res in zip(live, batch_res):
                        if collect:
                            results[ticket.id] = res
                        if ticket._resolve(res):
                            n_resolved += 1
                            self._observe_latency(ticket)
                    n_batches += 1
                    n_padded += pad
            except Exception as e:
                # anything unexpected rejects this queue's still-pending
                # tickets instead of escaping into the drainer
                for ticket, _ in items:
                    reject(ticket, f"scheduler internal error: {e}")
        with self._lock:
            self._record_errors_locked(errors)
            self.stats["batches"] += n_batches
            self.stats["sim_batches"] += n_sim
            self.stats["padded_queries"] += n_padded
            self.stats["resolved"] += n_resolved
            self.stats["expired"] += n_expired
            self.stats["skipped_resolved"] += n_skipped
        return results

    def _drain(self, queues, collect: bool = True
               ) -> Dict[int, List[ClosestConcept]]:
        """_run_queues with a last-resort guard: a bug in batch execution
        must reject the drained tickets, never strand them (queues are
        already popped — there is no requeue) or kill the flush loop."""
        try:
            return self._run_queues(queues, collect=collect)
        except Exception as e:
            msg = f"scheduler internal error: {e}"
            dropped: Dict[int, str] = {}
            for items in queues.values():
                for ticket, _ in items:
                    if ticket._reject(msg):
                        dropped[ticket.id] = msg
                        self._observe_latency(ticket)
            with self._lock:
                self._record_errors_locked(dropped)
                self.stats["resolved"] += len(dropped)
            return {}

    def flush(self) -> Dict[int, List[ClosestConcept]]:
        """Synchronously drain and execute everything pending. Coexists
        with the flush loop: each queue is popped under the lock, so a
        ticket is only ever executed (and resolved) by one drainer."""
        with self._lock:
            queues, self._queues = self._queues, {}
            self._born.clear()
        results = self._drain(queues)
        with self._lock:
            self.stats["flushes"] += 1
        return results

    # ----------------------------- flush loop --------------------------- #
    def start(self, flush_after_ms: Optional[float] = None) -> None:
        """Start the daemon flush loop (idempotent while running)."""
        if flush_after_ms is not None:
            self.flush_after_ms = flush_after_ms
        if self.flush_after_ms is None:
            raise ValueError("flush_after_ms is required to start the loop")
        with self._cond:
            if self._thread is not None and self._thread.is_alive():
                # idempotent while running — and after a timed-out stop()
                # this re-adopts the still-draining loop: clearing
                # _stopping reopens intake and the thread resumes serving
                self._stopping = False
                self._cond.notify_all()
                return
            self._stopping = False
            self._thread = threading.Thread(
                target=self._loop, name="BatchScheduler-flush", daemon=True)
            self._thread.start()

    def stop(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the loop; by default drain what's still queued so every
        outstanding ticket resolves before this returns. Raises
        RuntimeError if an in-flight drain doesn't finish within
        ``timeout`` — the guarantee would be silently broken otherwise."""
        with self._cond:
            thread, self._thread = self._thread, None
            self._stopping = True
            self._cond.notify_all()
        if thread is not None:
            thread.join(timeout)
            if thread.is_alive():
                with self._lock:
                    if self._thread is None:     # don't clobber a racing
                        self._thread = thread    # start()'s fresh loop
                raise RuntimeError(
                    f"flush loop still draining after {timeout}s")
        if drain:
            self.flush()

    def running(self) -> bool:
        with self._lock:
            return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "BatchScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _due_keys(self, now: float, period_s: float) -> List[
            Tuple[str, str, str, int]]:
        """Queues past their deadline or at/over max_batch (lock held)."""
        return [key for key, born in self._born.items()
                if now - born >= period_s
                or len(self._queues[key]) >= self.max_batch]

    def _loop(self) -> None:
        # a loop thread serves only while it is the *registered* thread:
        # stop() deregisters (sets _thread None/new), and a stale thread
        # that wakes later exits instead of racing a replacement loop
        me = threading.current_thread()
        while True:
            take: Dict[Tuple[str, str, str, int],
                       List[Tuple[Ticket, TopKRequest]]] = {}
            with self._cond:
                while not self._stopping and self._thread is me:
                    # re-read the deadline each pass: start(flush_after_ms=)
                    # on a running loop takes effect immediately
                    period_s = self.flush_after_ms / 1e3
                    due = self._due_keys(time.monotonic(), period_s)
                    if due:
                        break
                    if self._born:
                        # sleep until the earliest queue's deadline; a
                        # submit that fills a batch (or opens a queue with
                        # an earlier deadline) notifies us awake sooner
                        timeout = max(
                            0.0, min(self._born.values()) + period_s
                            - time.monotonic())
                        self._cond.wait(timeout=timeout)
                    else:
                        self._cond.wait()
                if self._stopping or self._thread is not me:
                    return
                n_full = 0
                for key in due:
                    items = self._queues.pop(key)
                    self._born.pop(key, None)
                    take[key] = items
                    n_full += len(items) >= self.max_batch
            self._drain(take, collect=False)
            with self._lock:
                self.stats["loop_flushes"] += 1
                self.stats["full_flushes"] += n_full
                self.stats["deadline_flushes"] += len(take) - n_full

