"""The Bio-KGvec2go serving engine.

Implements the paper's three API functionalities, in-process (the container
has no network; the Flask layer in the paper is a thin shim over exactly
these calls):

  * ``download``      — JSON payload of all class vectors for a version;
  * ``similarity``    — cosine similarity between two classes (ids or labels,
                        with case/whitespace normalization), from the most
                        up-to-date version;
  * ``closest_concepts`` — top-k most similar classes, ranked table with
                        identifier, label, score and exploration URL.

Queries accept either class identifiers or textual labels. Top-k runs
through the fused Pallas kernel (``repro.kernels.ops.topk_cosine``).
A small request batcher groups concurrent top-k queries per (ontology,
model) into one kernel call — the serving hot path the paper runs
brute-force per request.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from .registry import EmbeddingRegistry


def _norm_label(s: str) -> str:
    """The paper's 'automatic normalization of case and whitespace'."""
    return " ".join(s.strip().lower().split())


def _edit_distance_capped(a: str, b: str, cap: int) -> int:
    """Levenshtein with early exit once every band entry exceeds ``cap``."""
    if abs(len(a) - len(b)) > cap:
        return cap + 1
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        best = i
        for j, cb in enumerate(b, 1):
            c = min(prev[j] + 1, cur[j - 1] + 1,
                    prev[j - 1] + (ca != cb))
            cur.append(c)
            best = min(best, c)
        if best > cap:
            return cap + 1
        prev = cur
    return prev[-1]


@dataclasses.dataclass
class ClosestConcept:
    identifier: str
    label: str
    score: float
    url: str


class EmbeddingIndex:
    """One (ontology, version, model) embedding table, ready to query."""

    def __init__(self, entity_ids: Sequence[str], labels: Sequence[str],
                 embeddings: np.ndarray, url_prefix: str = "https://bio.kgvec2go.org/concept/"):
        self.entity_ids = list(entity_ids)
        self.labels = list(labels)
        self.url_prefix = url_prefix
        emb = np.asarray(embeddings, dtype=np.float32)
        norms = np.linalg.norm(emb, axis=1, keepdims=True)
        self.embeddings = emb
        self.unit = emb / np.maximum(norms, 1e-12)
        self._id_to_row = {i: r for r, i in enumerate(self.entity_ids)}
        self._label_to_row: Dict[str, int] = {}
        for r, lbl in enumerate(self.labels):
            self._label_to_row.setdefault(_norm_label(lbl), r)
        #: sorted normalized labels for autocomplete (paper §6 future work)
        self._sorted_labels = sorted(self._label_to_row)

    # ------------------------------------------------------------------ #
    def autocomplete(self, prefix: str, limit: int = 10) -> List[str]:
        """Concept labels starting with ``prefix`` (paper §6 future work)."""
        import bisect
        p = _norm_label(prefix)
        lo = bisect.bisect_left(self._sorted_labels, p)
        out = []
        for lbl in self._sorted_labels[lo:lo + max(limit * 4, limit)]:
            if not lbl.startswith(p):
                break
            out.append(self.labels[self._label_to_row[lbl]])
            if len(out) == limit:
                break
        return out

    def resolve_fuzzy(self, query: str, max_edits: int = 2
                      ) -> Optional[Tuple[int, str]]:
        """Typo-tolerant label match (paper §6 future work): the closest
        label within ``max_edits`` Levenshtein edits. Returns (row, label)
        or None. Exact matches short-circuit via resolve()."""
        q = _norm_label(query)
        best: Optional[Tuple[int, str]] = None
        best_d = max_edits + 1
        for lbl, row in self._label_to_row.items():
            # cheap pre-filters before the DP
            if abs(len(lbl) - len(q)) > max_edits:
                continue
            d = _edit_distance_capped(q, lbl, min(best_d - 1, max_edits))
            if d < best_d:
                best, best_d = (row, self.labels[row]), d
                if d == 1:
                    break
        return best

    # ------------------------------------------------------------------ #
    def resolve(self, query: str, fuzzy: bool = False) -> Optional[int]:
        if query in self._id_to_row:
            return self._id_to_row[query]
        row = self._label_to_row.get(_norm_label(query))
        if row is None and fuzzy:
            hit = self.resolve_fuzzy(query)
            return hit[0] if hit else None
        return row

    def vector(self, query: str) -> np.ndarray:
        row = self.resolve(query)
        if row is None:
            raise KeyError(f"unknown class {query!r}")
        return self.embeddings[row]

    def similarity(self, a: str, b: str) -> float:
        ra, rb = self.resolve(a), self.resolve(b)
        if ra is None or rb is None:
            missing = a if ra is None else b
            raise KeyError(f"unknown class {missing!r}")
        return float(np.dot(self.unit[ra], self.unit[rb]))

    def top_k(self, queries: Sequence[str], k: int = 10,
              exclude_self: bool = True) -> List[List[ClosestConcept]]:
        """Batched top-k closest concepts (the paper returns top 10)."""
        rows = []
        for q in queries:
            r = self.resolve(q)
            if r is None:
                raise KeyError(f"unknown class {q!r}")
            rows.append(r)
        qvec = self.unit[np.asarray(rows)]                      # (Q, d)
        kk = k + 1 if exclude_self else k
        from ..kernels import ops as kops
        scores, idx = kops.topk_cosine(jnp.asarray(qvec), jnp.asarray(self.unit), kk)
        scores, idx = np.asarray(scores), np.asarray(idx)
        out: List[List[ClosestConcept]] = []
        for qi, row in enumerate(rows):
            lst: List[ClosestConcept] = []
            for score, j in zip(scores[qi], idx[qi]):
                if exclude_self and int(j) == row:
                    continue
                ident = self.entity_ids[int(j)]
                lst.append(ClosestConcept(ident, self.labels[int(j)], float(score),
                                          self.url_prefix + ident))
                if len(lst) == k:
                    break
            out.append(lst)
        return out


class ServingEngine:
    """Serves the latest published snapshots from an EmbeddingRegistry."""

    def __init__(self, registry: EmbeddingRegistry):
        self.registry = registry
        self._cache: Dict[Tuple[str, str, str], EmbeddingIndex] = {}

    def _index(self, ontology: str, model: str, version: Optional[str] = None) -> EmbeddingIndex:
        version = version or self.registry.store.latest_version(ontology)
        if version is None:
            raise KeyError(f"no published versions for {ontology!r}")
        key = (ontology, version, model)
        if key not in self._cache:
            ids, labels, emb, _ = self.registry.get(ontology, model, version)
            self._cache[key] = EmbeddingIndex(ids, labels, emb)
        return self._cache[key]

    def invalidate(self, ontology: str) -> None:
        """Called by the updater after publishing a new version."""
        self._cache = {k: v for k, v in self._cache.items() if k[0] != ontology}

    # ------------------------- the three endpoints --------------------- #
    def download(self, ontology: str, model: str, version: Optional[str] = None) -> str:
        return self.registry.to_json(ontology, model, version)

    def similarity(self, ontology: str, model: str, a: str, b: str,
                   fuzzy: bool = False) -> float:
        idx = self._index(ontology, model)
        if fuzzy:
            ra, rb = idx.resolve(a, fuzzy=True), idx.resolve(b, fuzzy=True)
            if ra is None or rb is None:
                raise KeyError(f"unknown class {a if ra is None else b!r}")
            import numpy as _np
            return float(_np.dot(idx.unit[ra], idx.unit[rb]))
        return idx.similarity(a, b)

    def closest_concepts(self, ontology: str, model: str, query: str,
                         k: int = 10, fuzzy: bool = False) -> List[ClosestConcept]:
        idx = self._index(ontology, model)
        if fuzzy:
            row = idx.resolve(query, fuzzy=True)
            if row is None:
                raise KeyError(f"unknown class {query!r}")
            query = idx.entity_ids[row]
        return idx.top_k([query], k)[0]

    # ---------------- paper §6 future work, implemented ---------------- #
    def autocomplete(self, ontology: str, model: str, prefix: str,
                     limit: int = 10) -> List[str]:
        """Concept-label autocomplete."""
        return self._index(ontology, model).autocomplete(prefix, limit)


@dataclasses.dataclass
class TopKRequest:
    ontology: str
    model: str
    query: str
    k: int = 10


class RequestBatcher:
    """Groups concurrent top-k requests per (ontology, model) and executes
    each group as ONE batched kernel call — amortizing the (N, d) scan."""

    def __init__(self, engine: ServingEngine, max_batch: int = 64):
        self.engine = engine
        self.max_batch = max_batch
        self._pending: List[Tuple[int, TopKRequest]] = []

    def submit(self, req: TopKRequest) -> int:
        ticket = len(self._pending)
        self._pending.append((ticket, req))
        return ticket

    def flush(self) -> Dict[int, List[ClosestConcept]]:
        groups: Dict[Tuple[str, str, int], List[Tuple[int, TopKRequest]]] = {}
        for ticket, req in self._pending:
            groups.setdefault((req.ontology, req.model, req.k), []).append((ticket, req))
        results: Dict[int, List[ClosestConcept]] = {}
        for (ont, model, k), items in groups.items():
            index = self.engine._index(ont, model)
            for start in range(0, len(items), self.max_batch):
                chunk = items[start : start + self.max_batch]
                batch_res = index.top_k([r.query for _, r in chunk], k)
                for (ticket, _), res in zip(chunk, batch_res):
                    results[ticket] = res
        self._pending.clear()
        return results
