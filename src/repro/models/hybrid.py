"""Hybrid recurrent/attention LM — recurrentgemma-2b (Griffin).

Layer pattern repeats (recurrent, recurrent, local-attention); every layer
is a temporal-mixing residual followed by an MLP residual. The full periods
run under one ``lax.scan`` (params stacked over periods); the remainder
layers (26 = 8*3 + 2) are unrolled.

Decode state: per recurrent layer an RG-LRU hidden (B, w) fp32 + conv tail;
per attention layer a rolling window KV cache (window 2048) — all constant
in sequence length => long_500k capable.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import blocks
from .config import ArchConfig
from .layers import apply_norm, mlp, mlp_init, norm_init, stacked_init
from .lm import BaseLM, maybe_remat

Params = Dict[str, Any]


def _rec_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
            "rec": blocks.rglru_init(k1, cfg),
            "ln2": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jdtype, cfg.act)}


def _attn_layer_init(key, cfg):
    return blocks.block_init(key, cfg)


class HybridLM(BaseLM):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        self.period = len(cfg.hybrid.pattern)              # 3
        self.n_periods = cfg.n_layers // self.period
        self.rem = tuple(cfg.hybrid.pattern[:cfg.n_layers % self.period])

    # ---------------- params ---------------- #
    def init_layers(self, key):
        cfg = self.cfg
        kp, kr = jax.random.split(key)

        def period_init(k):
            ks = jax.random.split(k, self.period)
            out = {}
            for i, kind in enumerate(cfg.hybrid.pattern):
                fn = _rec_layer_init if kind == "recurrent" else _attn_layer_init
                out[f"l{i}"] = fn(ks[i], cfg)
            return out

        p = {"periods": stacked_init(period_init, kp, self.n_periods)}
        krs = jax.random.split(kr, max(len(self.rem), 1))
        for i, kind in enumerate(self.rem):
            fn = _rec_layer_init if kind == "recurrent" else _attn_layer_init
            p[f"rem{i}"] = fn(krs[i], cfg)
        return p

    # ---------------- train ---------------- #
    def _apply_layer(self, kind: str, p, h):
        cfg = self.cfg
        if kind == "recurrent":
            h = h + blocks.rglru_apply(p["rec"], apply_norm(p["ln1"], h), cfg)
            h = h + mlp(p["mlp"], apply_norm(p["ln2"], h), cfg.act)
            return h
        return blocks.block_apply(p, h, cfg, window=cfg.hybrid.window)

    def backbone(self, params, x):
        cfg = self.cfg

        def period_body(p, h):
            for i, kind in enumerate(cfg.hybrid.pattern):
                h = self._apply_layer(kind, p[f"l{i}"], h)
            return h
        body = maybe_remat(period_body, cfg)

        def f(h, p):
            return body(p, h), None
        h, _ = jax.lax.scan(f, x, params["layers"]["periods"])
        for i, kind in enumerate(self.rem):
            h = self._apply_layer(kind, params["layers"][f"rem{i}"], h)
        return h, jnp.asarray(0.0, jnp.float32)

    # ---------------- prefill ---------------- #
    def _prefill_layer(self, kind, p, h):
        cfg = self.cfg
        if kind == "recurrent":
            y, hs, cs = blocks.rglru_apply(p["rec"], apply_norm(p["ln1"], h),
                                           cfg, return_state=True)
            h = h + y
            h = h + mlp(p["mlp"], apply_norm(p["ln2"], h), cfg.act)
            return h, (hs, cs)
        h, kc, vc = blocks.block_prefill(p, h, cfg, window=cfg.hybrid.window)
        return h, (kc, vc)

    def backbone_prefill(self, params, x, cache_len=None):
        cfg = self.cfg

        def f(h, p):
            states = []
            for i, kind in enumerate(cfg.hybrid.pattern):
                h, st = self._prefill_layer(kind, p[f"l{i}"], h)
                states.append(st)
            return h, tuple(states)
        h, period_states = jax.lax.scan(f, x, params["layers"]["periods"])
        cache = {"periods": period_states, "rem": []}
        rem_states = []
        for i, kind in enumerate(self.rem):
            h, st = self._prefill_layer(kind, params["layers"][f"rem{i}"], h)
            rem_states.append(st)
        cache["rem"] = tuple(rem_states)
        return h, cache

    # ---------------- decode ---------------- #
    def _decode_layer(self, kind, p, h, state, pos):
        cfg = self.cfg
        if kind == "recurrent":
            hs, cs = state
            y, hs, cs = blocks.rglru_decode(p["rec"], apply_norm(p["ln1"], h),
                                            hs, cs, cfg)
            h = h + y
            h = h + mlp(p["mlp"], apply_norm(p["ln2"], h), cfg.act)
            return h, (hs, cs)
        kc, vc = state
        h, kc, vc = blocks.block_decode(p, h, kc, vc, pos, cfg,
                                        window=cfg.hybrid.window)
        return h, (kc, vc)

    def backbone_decode(self, params, cache, x, pos):
        cfg = self.cfg

        def f(h, inp):
            p, states = inp
            new_states = []
            for i, kind in enumerate(cfg.hybrid.pattern):
                h, st = self._decode_layer(kind, p[f"l{i}"], h, states[i], pos)
                new_states.append(st)
            return h, tuple(new_states)
        h, period_states = jax.lax.scan(
            f, x, (params["layers"]["periods"], cache["periods"]))
        rem_states = []
        for i, kind in enumerate(self.rem):
            h, st = self._decode_layer(kind, params["layers"][f"rem{i}"], h,
                                       cache["rem"][i], pos)
            rem_states.append(st)
        return h, {"periods": period_states, "rem": tuple(rem_states)}

    # ---------------- specs ---------------- #
    def cache_spec(self, batch: int, seq: int):
        cfg = self.cfg
        w = cfg.hybrid.lru_width or cfg.d_model
        cw = cfg.hybrid.conv_width
        Sc = min(seq, cfg.hybrid.window)
        P = self.n_periods

        def rec_state(lead):
            return (jax.ShapeDtypeStruct(lead + (batch, w), jnp.float32),
                    jax.ShapeDtypeStruct(lead + (batch, cw - 1, w), cfg.jdtype))

        def attn_state(lead):
            shp = lead + (batch, cfg.groups, Sc, cfg.hd)
            return (jax.ShapeDtypeStruct(shp, cfg.jdtype),
                    jax.ShapeDtypeStruct(shp, cfg.jdtype))

        period = tuple(
            rec_state((P,)) if kind == "recurrent" else attn_state((P,))
            for kind in cfg.hybrid.pattern)
        rem = tuple(
            rec_state(()) if kind == "recurrent" else attn_state(())
            for kind in self.rem)
        return {"periods": period, "rem": rem}

    def supports_long_context(self) -> bool:
        return True
