"""Runtime context the pure model functions can't carry in configs:
the active mesh (for shard_map-based blocks). Set by the launcher
(dryrun/train) around lowering; None on single-device CPU runs."""
from __future__ import annotations

import contextlib
from typing import Optional

from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev
