"""Mixture-of-Experts decoder LM (olmoe-1b-7b, grok-1-314b)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import blocks
from .layers import stacked_init
from .lm import BaseLM, scan_layers, scan_prefill


class MoELM(BaseLM):
    def init_layers(self, key):
        return stacked_init(lambda k: blocks.moe_block_init(k, self.cfg),
                            key, self.cfg.n_layers)

    def backbone(self, params, x):
        def body(p, h):
            return blocks.moe_block_apply(p, h, self.cfg)
        h, aux = scan_layers(params["layers"], x, body, self.cfg, with_aux=True)
        return h, aux / self.cfg.n_layers

    def backbone_prefill(self, params, x, cache_len=None):
        def body(p, h):
            return blocks.moe_block_prefill(p, h, self.cfg)
        h, kcs, vcs = scan_prefill(params["layers"], x, body)
        if cache_len is not None:
            pad = cache_len - kcs.shape[3]
            if pad > 0:
                widths = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
                kcs, vcs = jnp.pad(kcs, widths), jnp.pad(vcs, widths)
        return h, {"k": kcs, "v": vcs}

    def backbone_decode(self, params, cache, x, pos):
        from .lm import loop_decode_inplace
        from .layers import apply_norm

        def body(p, h, kc, vc, layer):
            a, kc, vc = blocks.attn_decode_inplace(
                p["attn"], apply_norm(p["ln1"], h), kc, vc, layer, pos,
                self.cfg)
            h = h + a
            y, _ = blocks.moe_dispatch(p["moe"], apply_norm(p["ln2"], h),
                                       self.cfg)
            return h + y, kc, vc
        h, (kcs, vcs) = loop_decode_inplace(
            params["layers"], (cache["k"], cache["v"]), x, body)
        return h, {"k": kcs, "v": vcs}

    def cache_spec(self, batch: int, seq: int):
        cfg = self.cfg
        shp = (cfg.n_layers, batch, cfg.groups, seq, cfg.hd)
        return {"k": jax.ShapeDtypeStruct(shp, cfg.jdtype),
                "v": jax.ShapeDtypeStruct(shp, cfg.jdtype)}
