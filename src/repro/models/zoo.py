"""Model registry: family -> class, arch-id -> (config, model)."""
from __future__ import annotations

import importlib
from typing import Tuple

from .config import ArchConfig
from .dense import DenseLM
from .encdec import EncDecModel
from .hybrid import HybridLM
from .lm import BaseLM
from .moe import MoELM
from .ssm import MambaLM
from .vlm import VLM

FAMILIES = {
    "dense": DenseLM,
    "moe": MoELM,
    "ssm": MambaLM,
    "hybrid": HybridLM,
    "audio": EncDecModel,
    "vlm": VLM,
}

ARCH_IDS = (
    "llava_next_34b",
    "falcon_mamba_7b",
    "h2o_danube_1_8b",
    "mistral_large_123b",
    "whisper_base",
    "olmoe_1b_7b",
    "grok_1_314b",
    "qwen2_72b",
    "recurrentgemma_2b",
    "internlm2_20b",
)


def normalize_arch_id(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str, reduced: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{normalize_arch_id(arch)}")
    return mod.REDUCED if reduced else mod.CONFIG


def build(cfg: ArchConfig) -> BaseLM:
    return FAMILIES[cfg.family](cfg)


def get_model(arch: str, reduced: bool = False) -> Tuple[ArchConfig, BaseLM]:
    cfg = get_config(arch, reduced)
    return cfg, build(cfg)
