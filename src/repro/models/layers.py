"""Shared transformer building blocks (pure functions over param pytrees).

Params are plain dicts of jnp arrays. Every ``init_*`` returns a dict;
every ``apply`` function takes (params, inputs) -> outputs. Stacked-layer
params (leading ``L`` axis) are produced by ``jax.vmap`` over the init key,
and consumed by ``jax.lax.scan`` in the model modules.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    # preferred_element_type = input dtype: the matmul emits its own dtype
    # per shard, so Megatron-style partial-sum all-reduces move bf16, not
    # the f32 the partitioner would otherwise hoist above the downcast
    # (EXPERIMENTS.md §Perf dense iteration: ~2x collective traffic).
    w = p["w"]
    y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, dtype, kind: str = "rmsnorm") -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------- #
def mlp_init(key, d: int, ff: int, dtype, act: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, ff, dtype),
         "down": dense_init(ks[1], ff, d, dtype)}
    if act == "swiglu":
        p["gate"] = dense_init(ks[2], d, ff, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, act: str = "swiglu") -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    else:
        h = jax.nn.gelu(dense(p["up"], x))
    return dense(p["down"], h)


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def _rope_rotate(x: jnp.ndarray, pos: jnp.ndarray, theta: float,
                 head_axes: int) -> jnp.ndarray:
    """Rotate the trailing hd axis of x by position-dependent angles.

    x:   (B, S, <head_axes dims>, hd)
    pos: (S,) or (B, S)
    """
    freqs = rope_freqs(x.shape[-1], theta)                 # (hd/2,)
    p = pos if pos.ndim == 2 else pos[None, :]             # (B|1, S)
    ang = p[..., None].astype(jnp.float32) * freqs          # (B|1, S, hd/2)
    ang = ang.reshape(ang.shape[:2] + (1,) * head_axes + ang.shape[-1:])
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return o.reshape(x.shape).astype(x.dtype)


def rope_qk(q: jnp.ndarray, k: jnp.ndarray, q_pos: jnp.ndarray,
            k_pos: jnp.ndarray, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q: (B, Sq, G, H, hd); k: (B, Sk, G, hd)."""
    return (_rope_rotate(q, q_pos, theta, head_axes=2),
            _rope_rotate(k, k_pos, theta, head_axes=1))


def stacked_init(init_fn, key, n: int):
    """vmap an init over a split key -> params with leading (n,) axis."""
    return jax.vmap(init_fn)(jax.random.split(key, n))
