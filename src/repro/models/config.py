"""Architecture configuration.

One ``ArchConfig`` fully describes a model in the zoo. The 10 assigned
architectures each get a ``src/repro/configs/<id>.py`` exporting ``CONFIG``
(the exact published dims) and ``REDUCED`` (a 2-layer, d_model<=512 variant of
the same family for CPU smoke tests).

Head sharding
-------------
The production mesh has a fixed ``model`` axis of 16, but published head
counts (56, 10, 8, ...) don't always divide it. We therefore distinguish:

* ``n_heads`` / ``n_kv_heads`` — the published numbers (the math of the model);
* ``kv_groups``              — the number of KV "slots" the runtime carries
  (= model-axis size in production, = ``n_kv_heads`` on CPU). KV heads are
  ``jnp.repeat``-ed to ``kv_groups`` (the standard vLLM/TPU replication
  trick for GQA with kv < tensor-parallel degree);
* ``padded_heads()``         — q-heads padded *per KV group* with zero-output
  heads so (a) the padded count divides ``kv_groups`` shards and (b) every
  shard's q-heads all map to the KV slot resident on that shard. Padding
  heads have zero out-projection rows, so the function computed is identical
  (see tests/test_models_padding.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None     # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style: repeating (recurrent, recurrent, local-attn)."""
    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    lru_width: Optional[int] = None   # default d_model
    conv_width: int = 4
    window: int = 2048


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None      # default d_model // n_heads
    qkv_bias: bool = False              # qwen2 uses bias on QKV
    attention: str = "full"             # full | sliding_window | none
    window: int = 4096                  # for sliding_window
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "swiglu"                 # swiglu | gelu
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # --- VLM / audio frontend stubs -------------------------------------- #
    n_frontend_tokens: int = 0          # image-patch / audio-frame embeds
    dec_len_cap: int = 0                # enc-dec: max decoder length (whisper 448)
    # --- runtime ---------------------------------------------------------- #
    kv_groups: int = 0                  # 0 => n_kv_heads (no replication)
    moe_dp_blocks: int = 0              # MoE block-local dispatch blocks
                                        # (= data-axis size in production;
                                        # 0/1 = single global dispatch)
    moe_impl: str = "gspmd"             # gspmd | shard_map (explicit EP:
                                        # local dispatch to resident experts
                                        # + one token-shaped psum combine)
    moe_ff_split: int = 0               # split each expert's ff into r
                                        # virtual experts (E*r total) so
                                        # E*r divides the model axis =>
                                        # pure expert-parallelism, no ff-TP
                                        # psums (grok: 8e -> 16 virtual)
    seq_shard: bool = False             # sequence-shard the residual over
                                        # "model" between blocks (Megatron-SP
                                        # style; §Perf dense experiment)
    kv_cache_dtype: str = "model"       # model | int8 (quantized serving
                                        # cache: per-slot symmetric scales,
                                        # halves decode HBM traffic)
    dtype: str = "bfloat16"
    remat: str = "full"                 # full | none | dots
    source: str = ""                    # citation

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def groups(self) -> int:
        """KV slots carried at runtime."""
        return self.kv_groups or self.n_kv_heads

    def padded_heads(self) -> int:
        """q-heads padded per KV group so heads shard over ``groups``.

        g  = published q-heads per KV head
        m  = groups / gcd(groups, n_kv_heads)  (alignment quantum)
        g' = ceil(g / m) * m
        """
        if self.n_heads == 0:
            return 0
        g = self.n_heads // self.n_kv_heads
        m = self.groups // math.gcd(self.groups, self.n_kv_heads)
        gp = -(-g // m) * m
        return self.n_kv_heads * gp

    @property
    def heads_per_group(self) -> int:
        return self.padded_heads() // self.groups if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """vocab padded to a multiple of 256 so the logits shard cleanly."""
        return -(-self.vocab // 256) * 256

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ #
    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.padded_vocab
        hp, g, hd = self.padded_heads(), self.groups, self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            dtr = s.dt_rank or -(-d // 16)
            per_layer = (d * 2 * d_in          # in_proj
                         + s.d_conv * d_in      # conv
                         + d_in * (dtr + 2 * s.d_state) + dtr * d_in  # x/dt proj
                         + d_in * s.d_state     # A_log
                         + d_in                 # D
                         + d_in * d)            # out_proj
            return emb + L * (per_layer + d) + d
        attn = d * hp * hd + 2 * d * self.n_kv_heads * hd + hp * hd * d
        if self.qkv_bias:
            attn += hp * hd + 2 * self.n_kv_heads * hd
        mlp_mult = 3 if self.act == "swiglu" else 2
        if self.moe:
            mlp = self.moe.n_experts * mlp_mult * d * ff + d * self.moe.n_experts
        else:
            mlp = mlp_mult * d * ff
        if self.family == "hybrid":
            h = self.hybrid
            w = h.lru_width or d
            rec = (2 * d * w + h.conv_width * w + 2 * w * w + 3 * w + w * d)
            n_attn = sum(1 for i in range(L)
                         if h.pattern[i % len(h.pattern)] == "attention")
            per_layer_sum = n_attn * (attn + mlp) + (L - n_attn) * (rec + mlp)
            return emb + per_layer_sum + L * 2 * d + d
        if self.family == "audio":
            # enc-dec: encoder layer (self-attn+mlp) + decoder layer
            # (self-attn + cross-attn + mlp); n_layers counts each stack.
            enc = attn + mlp
            dec = 2 * attn + mlp
            return emb + self.n_layers * (enc + dec) + 4 * self.n_layers * d + 2 * d
        return emb + L * (attn + mlp + 2 * d) + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.moe:
            return self.n_params()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        full = self.n_params()
        mlp_mult = 3 if self.act == "swiglu" else 2
        inactive = L * (self.moe.n_experts - self.moe.top_k) * mlp_mult * d * ff
        return full - inactive
