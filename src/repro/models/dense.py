"""Dense decoder-only LM (mistral-large, qwen2, internlm2, h2o-danube).

h2o-danube uses sliding-window attention (cfg.attention == "sliding_window"),
which is also the beyond-paper long_500k override for other dense archs.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import blocks
from .layers import stacked_init
from .lm import BaseLM, scan_layers, scan_prefill


def _maybe_seq_shard(h, cfg):
    """Megatron-SP-style residual constraint: sequence-shard (B, S, d)
    over "model" between blocks, so XLA emits reduce-scatter + all-gather
    pairs around each block instead of all-reduces (\u00a7Perf dense
    experiment)."""
    if not cfg.seq_shard:
        return h
    from . import runtime
    mesh = runtime.get_mesh()
    if mesh is None:
        return h
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return jax.lax.with_sharding_constraint(h, P(dp, "model", None))


class DenseLM(BaseLM):
    @property
    def window(self):
        return self.cfg.window if self.cfg.attention == "sliding_window" else None

    def init_layers(self, key):
        return stacked_init(lambda k: blocks.block_init(k, self.cfg),
                            key, self.cfg.n_layers)

    def backbone(self, params, x):
        def body(p, h):
            h = blocks.block_apply(p, h, self.cfg, window=self.window)
            return _maybe_seq_shard(h, self.cfg)
        h = scan_layers(params["layers"], x, body, self.cfg)
        return h, jnp.asarray(0.0, jnp.float32)

    @property
    def quantized_cache(self):
        return self.cfg.kv_cache_dtype == "int8"

    def backbone_prefill(self, params, x, cache_len=None):
        def body(p, h):
            return blocks.block_prefill(p, h, self.cfg, window=self.window)
        h, kcs, vcs = scan_prefill(params["layers"], x, body)
        if cache_len is not None and self.window is None:
            pad = cache_len - kcs.shape[3]
            if pad > 0:
                widths = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
                kcs, vcs = jnp.pad(kcs, widths), jnp.pad(vcs, widths)
        if self.quantized_cache:
            kcs, ks = blocks.quantize_kv(kcs)
            vcs, vs = blocks.quantize_kv(vcs)
            return h, {"k": kcs, "v": vcs, "k_scale": ks, "v_scale": vs}
        return h, {"k": kcs, "v": vcs}

    def backbone_decode(self, params, cache, x, pos):
        from .lm import loop_decode_inplace
        quant = self.quantized_cache

        def body(p, h, kc, vc, *rest):
            *scales, layer = rest
            out = blocks.attn_decode_inplace(
                p["attn"], blocks.apply_norm(p["ln1"], h), kc, vc, layer,
                pos, self.cfg, window=self.window,
                k_scale=scales[0] if quant else None,
                v_scale=scales[1] if quant else None)
            a, *caches = out
            h = h + a
            h = h + blocks.mlp(p["mlp"], blocks.apply_norm(p["ln2"], h),
                               self.cfg.act)
            return (h, *caches)

        names = ("k", "v", "k_scale", "v_scale") if quant else ("k", "v")
        h, caches = loop_decode_inplace(
            params["layers"], tuple(cache[n] for n in names), x, body)
        return h, dict(zip(names, caches))

    def cache_spec(self, batch: int, seq: int):
        cfg = self.cfg
        Sc = min(seq, cfg.window) if self.window is not None else seq
        shp = (cfg.n_layers, batch, cfg.groups, Sc, cfg.hd)
        if self.quantized_cache:
            return {"k": jax.ShapeDtypeStruct(shp, jnp.int8),
                    "v": jax.ShapeDtypeStruct(shp, jnp.int8),
                    "k_scale": jax.ShapeDtypeStruct(shp[:-1], jnp.float32),
                    "v_scale": jax.ShapeDtypeStruct(shp[:-1], jnp.float32)}
        return {"k": jax.ShapeDtypeStruct(shp, cfg.jdtype),
                "v": jax.ShapeDtypeStruct(shp, cfg.jdtype)}

    def supports_long_context(self) -> bool:
        return self.cfg.attention == "sliding_window"
