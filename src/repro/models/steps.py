"""Step factories: train / prefill / serve, plus their dry-run input specs.

These are the functions the launcher jits. Shapes come from
``repro.configs.shapes``; shardings from ``repro.models.sharding``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..optim import adam
from .lm import BaseLM

Params = Dict[str, Any]


def make_train_step(model: BaseLM, lr: float = 3e-4) -> Tuple[Callable, Any]:
    """Returns (step, optimizer). step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    optimizer = adam(lr)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return step, optimizer


def make_prefill_step(model: BaseLM) -> Callable:
    def step(params, batch):
        return model.prefill(params, batch)
    return step


def make_serve_step(model: BaseLM) -> Callable:
    """ONE new token against an existing cache (the decode_32k/long_500k
    workload). Greedy-samples so the output is a token, not raw logits."""
    def step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache
    return step


# --------------------------------------------------------------------- #
# dry-run input specs (ShapeDtypeStruct stand-ins, zero allocation)
# --------------------------------------------------------------------- #
def train_specs(model: BaseLM, global_batch: int, seq: int):
    """(params, opt_state, batch) as ShapeDtypeStructs."""
    params = jax.eval_shape(model.init, jax.random.key(0))
    optimizer = adam(3e-4)
    opt_state = jax.eval_shape(optimizer.init, params)
    batch = model.batch_spec(global_batch, seq)
    return params, opt_state, batch


def prefill_specs(model: BaseLM, global_batch: int, seq: int):
    params = jax.eval_shape(model.init, jax.random.key(0))
    return params, model.batch_spec(global_batch, seq)


def serve_specs(model: BaseLM, global_batch: int, seq: int):
    params = jax.eval_shape(model.init, jax.random.key(0))
    cache = model.cache_spec(global_batch, seq)
    token = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return params, cache, token, pos
